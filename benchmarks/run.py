"""Benchmark driver: one module per paper table/figure + the roofline reader.

    PYTHONPATH=src python -m benchmarks.run [--full]

Each bench prints its CSV to stdout and writes benchmarks/out/<name>.csv.
"""
from __future__ import annotations

import argparse
import time
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full filter sweeps / all datasets (slower)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (cycle_model, energy_model, engine_compare,
                            kernel_bench, memory_table, quant_accuracy,
                            roofline)

    benches = [
        ("memory_table (Table A3)", memory_table.run, {}),
        ("cycle_model (Tables A4/A6)", cycle_model.run, {}),
        ("energy_model (Table A5)", energy_model.run, {}),
        ("kernel_bench (Sec 2/7)", kernel_bench.run, {}),
        ("engine_compare (Sec 6.2)", engine_compare.run, {}),
        ("quant_accuracy (Figs 5-10, App B)", quant_accuracy.run,
         {"quick": not args.full}),
        ("roofline (deliverable g)", roofline.run, {}),
    ]
    failures = []
    for name, fn, kw in benches:
        if args.only and args.only not in name:
            continue
        print(f"\n{'='*72}\n== {name}\n{'='*72}")
        t0 = time.time()
        try:
            fn(**kw)
            print(f"== done in {time.time()-t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"\nFAILED benches: {failures}")
        raise SystemExit(1)
    print("\nall benches ok")


if __name__ == "__main__":
    main()
