"""Serve bench: chunked-prefill vs stall-the-batch admission vs restart,
swept over the paper's deployment quantization variants.

    PYTHONPATH=src python -m benchmarks.serve_bench --smoke \\
        [--baseline benchmarks/baselines/serve_bench.json]

For each variant in {fp32, wq (int8 weights), qkv (int8 KV), wq_qkv} the same
mixed-arrival workload (long prompts + alternating short/long horizons — the
spread continuous batching exploits, and prompts long enough that one-shot
admission visibly stalls the batch) runs through

  * ``chunked``: the continuous-batching Scheduler with chunked-prefill
    admission (one fused mixed step per tick; serve/scheduler.py),
  * ``scheduler``: the same Scheduler with PR 2's one-shot admission (a
    stop-the-world batch-1 prefill per freed slot), and
  * the restart-the-batch lockstep baseline,

asserts the two admission policies emit token-identical streams, and writes
``benchmarks/out/serve_bench.json`` with steady tok/s, occupancy, p50/p99
latency in steps AND wall milliseconds (both scheduler policies run with
``time_ticks=True``: virtual time cannot see a stop-the-world prefill, wall
time can; wall metrics are best-of-3 repeats — contention only adds time),
jit-compile counts, chunk/stall counters, peak cache bytes and speedups.

A second sweep (``bench_paged``) compares the **paged KV cache** against the
dense per-slot slabs on a mixed short/long-prompt workload:

  * *identity*: a paged engine at dense parity (same slots, pool =
    ``slots * ceil(max_len/page_size)`` pages) must emit token-identical
    streams to the dense engine (fp32 and int8 KV) — asserted, not gated;
  * *capacity*: a paged engine holding the **same KV pool tokens** but more
    slots must reach >= ``--min-capacity-ratio`` (default 1.5) times the
    dense run's peak concurrent requests (``peak_live_slots``) — short
    requests reserve pages for their own extent instead of a full
    ``max_len`` slab, which is the whole point of paging.

A third sweep (``bench_shared``) measures **prefix sharing over the paged
pool**: N requests spread over K distinct system prompts, with divergent
per-request suffixes and continuations.  Token identity of the shared run
vs the unshared paged run vs dense is asserted (fp32 and int8 KV), then at
an equal (tight) pool the shared run must admit ``--min-shared-ratio``
times the unshared run's peak concurrent requests, or hold >= 30% fewer
peak pages at the roomy parity pool (``check_shared``).

A fourth sweep (``bench_oversub``) measures **oversubscription**: at the
same tight pool, lazy decode-page growth + mid-decode preemption
(``oversubscribe=True``, both ``recompute`` and ``swap`` policies) vs
up-front worst-case reservation.  Preempt+resume token identity vs the
dense run is asserted (fp32 and int8 KV) along with ``preemptions > 0``;
the gate is >= ``--min-oversub-ratio`` times the up-front peak concurrent
requests at equal pool bytes (``check_oversub``), with p99 TTFT reported
for both admission modes.

A fifth sweep (``bench_burst``) measures the **ragged one-forward-per-tick
step with multi-lane prefill**: N prompts arriving in a single tick, ragged
(``ragged=True, prefill_lanes=L``) vs the single-lane mixed step at the
same token budget.  Token identity vs the dense run is asserted (fp32 and
int8 KV); the gate (``check_burst``) is p99 TTFT in deterministic
virtual-time steps — the mixed step admits one chunk per tick however
large the budget, the ragged step drains the burst ``lanes``-wide.

A sixth sweep (``bench_chaos``) drills the **hardening stack**: the
oversubscribed swap workload re-runs with per-request deadlines, a bounded
admission queue and the every-tick pool/state auditor, first fault-free and
then under an injected :class:`FaultPlan` (pool-exhaustion ticks, swap-area
refusals, an admission stall, one NaN-logit tick).  Asserted in-run: the
faulted run completes without raising, every request lands a terminal
status, exactly the NaN-poisoned request fails (its tokens a clean prefix
of its reference stream), and every non-faulted request is token-identical
to the fault-free reference — injected faults may reorder the schedule,
never the streams.  The gate (``check_chaos``) requires non-faulted
completion rate == 1.0.  ``--chaos-only`` runs just this sweep (the CI
chaos lane's entry point, cheap enough for interpreted-kernel mode).

A seventh sweep (``bench_hetero``) covers the **non-KV slot-state
adapters** (serve/slot_state.py): a long-encoder EncDec workload served
with per-slot cross-attention K/V caching (``CrossAttnState`` — project
once at admission) vs per-step recomputation, token identity asserted
in-run and the steady tok/s ratio gated >= 1.15x (``check_hetero``); plus
recurrent (mamba) bytes-per-slot vs an equal-config transformer KV slab at
two ``max_len`` geometries — constant vs linear in sequence length,
constancy asserted in-run.

CI-enforced gates (all deterministic or same-run relative):

  * the same-run relative gate — chunked must beat one-shot on p99
    wall latency and steady tok/s (``check_relative``; ratios are immune to
    runner weather);
  * the paged capacity gate (``check_paged``) — deterministic for a
    fixed seed, so effectively exact;
  * the shared-prefix capacity gate (``check_shared``) — deterministic too;
  * the oversubscription capacity gate (``check_oversub``) — deterministic
    too;
  * the heterogeneous-state gate (``check_hetero``) — same-run cached vs
    recomputed cross-attn K/V tok/s ratio, best-of-N both sides.

With ``--baseline``, steady tok/s and p99 latency are also compared against
the checked-in ``benchmarks/baselines/serve_bench.json`` at --tolerance —
**warn-only by default** (absolute wall-clock numbers vary across machine
classes far beyond any sane tolerance; the relative/capacity gates above
are the enforced signals).  ``--strict-baseline`` restores the hard gate.

To refresh the baseline after an intentional perf change, copy the new
out-file over it (see README "Serving" / docs/serving.md).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_config
from repro.serve import (FaultPlan, Request, ServeEngine,
                         run_restart_batching)

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

VARIANTS = {
    "fp32": {},
    "wq": {"weight_quant": True},
    "qkv": {"quantized_kv": True},
    "wq_qkv": {"weight_quant": True, "quantized_kv": True},
}

_POLICY_KEYS = ("steady_tok_s", "compile_s", "occupancy",
                "p50_latency_steps", "p99_latency_steps",
                "p50_latency_ms", "p99_latency_ms",
                "peak_cache_bytes", "num_jit_compiles")


def make_workload(n_requests, prompt_len, short_new, long_new, spacing, vocab,
                  seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(rid=i,
                prompt=rng.integers(0, vocab, size=prompt_len, dtype=np.int32),
                max_new=short_new if i % 2 == 0 else long_new,
                arrival=i * spacing)
        for i in range(n_requests)
    ]


def _best_summary(stats_list):
    """Summary of the best (lowest-p99) repeat, with each wall-sensitive
    metric replaced by its best across repeats.  On a contended shared box
    noise only ever *adds* time — single runs swing ±50%, medians still
    wobble under multi-repeat contention bursts — so best-of-N is the
    cleanest estimator of the true cost, for both policies alike.
    ``compile_s`` comes from the FIRST repeat: later repeats hit warm jit
    caches and would record ~0."""
    first = stats_list[0].summary()
    summaries = sorted((st.summary() for st in stats_list),
                       key=lambda s: s["p99_latency_ms"])
    out = dict(summaries[0])
    out["compile_s"] = first["compile_s"]
    out["steady_tok_s"] = max(s["steady_tok_s"] for s in summaries)
    for key in ("p50_latency_ms", "p99_latency_ms"):
        out[key] = min(s[key] for s in summaries)
    return out


def bench_variant(model, params, kw, workload, *, max_len, slots, chunk,
                  seed=0, repeats=3):
    engine = ServeEngine(model=model, params=params, max_len=max_len,
                         batch_slots=slots, **kw)
    sched_p, chunk_p = engine.scheduler(), engine.scheduler(chunk_size=chunk)
    # interleave the policies' repeats so box-level noise hits both alike
    # (jits are cached after the first run, so repeats time pure steady state)
    sched_stats, chunk_stats = [], []
    sched_res = chunk_res = None
    for _ in range(repeats):
        sched_res, st = sched_p.run(workload, seed=seed, time_ticks=True)
        sched_stats.append(st)
        chunk_res, st = chunk_p.run(workload, seed=seed, time_ticks=True)
        chunk_stats.append(st)
    restart_res, restart = run_restart_batching(engine, workload, seed=seed)
    for res in (sched_res, chunk_res, restart_res):
        assert sorted(res) == sorted(r.rid for r in workload)
    # acceptance bar: chunked admission is token-identical to one-shot
    for r in workload:
        assert chunk_res[r.rid].tokens == sched_res[r.rid].tokens, (
            f"chunked/one-shot token divergence on rid {r.rid}")
    s, c = _best_summary(sched_stats), _best_summary(chunk_stats)
    rs = restart.summary()
    return {
        "scheduler": {**{k: s[k] for k in _POLICY_KEYS},
                      "admission_stalls": s["admission_stalls"]},
        "chunked": {**{k: c[k] for k in _POLICY_KEYS},
                    "prefill_chunks": c["prefill_chunks"],
                    "stalled_chunks": c["stalled_chunks"]},
        "restart_tok_s": rs["steady_tok_s"],
        "restart_occupancy": rs["occupancy"],
        "speedup_vs_restart": round(s["steady_tok_s"]
                                    / max(rs["steady_tok_s"], 1e-9), 3),
        "chunked_p99_speedup": round(s["p99_latency_ms"]
                                     / max(c["p99_latency_ms"], 1e-9), 3),
    }


def weight_payload_bytes(params) -> dict:
    """Serving weight-byte accounting for the frontier artifact.

    ``kernel_bytes`` is the GEMM weight *payload* (container bytes: int8 =
    1 byte/element, packed int4 = exactly half for even K); ``table_bytes``
    the embedding tables (int8 containers in every quantized format);
    ``scale_bytes`` the exponent grids (int32 each), kept separate so the
    packed formats' sub-int8 payload claim is measured on the payload alone;
    ``float_bytes`` everything left in float (norms, biases, ...).
    """
    from repro.core.qformat import PackedQTensor, QTensor

    out = {"kernel_bytes": 0, "table_bytes": 0, "scale_bytes": 0,
           "float_bytes": 0}

    def rec(node, name):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, k)
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v, name)
        elif isinstance(node, PackedQTensor):
            out["kernel_bytes"] += node.nbytes_packed
            out["scale_bytes"] += int(np.prod(jnp.shape(node.n))) * 4
        elif isinstance(node, QTensor):
            key = "table_bytes" if name == "table" else "kernel_bytes"
            out[key] += int(np.prod(node.q.shape)) * node.q.dtype.itemsize
            out["scale_bytes"] += int(np.prod(jnp.shape(node.n))) * 4
        elif hasattr(node, "shape"):
            key = ("kernel_bytes" if name == "kernel"
                   else "table_bytes" if name == "table" else "float_bytes")
            out[key] += int(np.prod(node.shape)) * node.dtype.itemsize

    rec(params, "")
    return out


# Weight formats on the serving frontier: engine ``weight_quant`` specs.
WEIGHT_FORMATS = {
    "fp32": False,
    "int8": True,
    "int4": "int4-block",
}


def bench_weight_formats(model, params, vocab, *, smoke=True, seed=0,
                         weight_block=32):
    """Tok/s + weight-byte side of the quality-vs-throughput frontier.

    Each format in :data:`WEIGHT_FORMATS` serves the same workload through
    the chunked scheduler; the run is repeated once and asserted
    token-identical to itself (sub-int8 serving must stay deterministic).
    Accuracy joins in ``benchmarks.quant_accuracy.run_frontier``.
    """
    if smoke:
        wl = dict(n_requests=8, prompt_len=64, short_new=8, long_new=16,
                  spacing=2, slots=4, chunk=32)
    else:
        wl = dict(n_requests=16, prompt_len=256, short_new=8, long_new=32,
                  spacing=2, slots=4, chunk=64)
    workload = make_workload(wl["n_requests"], wl["prompt_len"],
                             wl["short_new"], wl["long_new"], wl["spacing"],
                             vocab, seed=seed)
    max_len = wl["prompt_len"] + wl["long_new"]
    out = {"workload": {**wl, "max_len": max_len,
                        "weight_block": weight_block}}
    for name, spec in WEIGHT_FORMATS.items():
        eng = ServeEngine(model=model, params=params, max_len=max_len,
                          batch_slots=wl["slots"], weight_quant=spec,
                          weight_block=weight_block)
        sched = eng.scheduler(chunk_size=wl["chunk"])
        res, st = sched.run(workload, seed=seed, time_ticks=True)
        res2, _ = eng.scheduler(chunk_size=wl["chunk"]).run(workload,
                                                            seed=seed)
        for r in workload:   # acceptance bar: a repeat is token-identical
            assert res2[r.rid].tokens == res[r.rid].tokens, (
                f"weight format {name}: non-deterministic stream on "
                f"rid {r.rid}")
        pb = weight_payload_bytes(eng.params)
        out[name] = {"tok_s": round(st.steady_tok_s, 2),
                     "repeat_identical": True, **pb}
        print(f"wfmt/{name:5s} {st.steady_tok_s:8.1f} tok/s | kernel payload "
              f"{pb['kernel_bytes']} B | scales {pb['scale_bytes']} B")
    return out


def bench_paged(model, params, vocab, *, smoke=True, seed=0):
    """Paged-vs-dense sweep: token identity at parity, capacity at equal
    KV pool bytes, over a mixed short/long-prompt workload (3 short : 1
    long — the spread where dense per-slot max_len reservation wastes the
    most memory)."""
    if smoke:
        wl = dict(n_requests=16, short_p=64, long_p=384, max_new=32,
                  spacing=2, slots=4, chunk=64, page=16, cap_slots=10)
    else:
        wl = dict(n_requests=32, short_p=128, long_p=768, max_new=48,
                  spacing=2, slots=8, chunk=128, page=16, cap_slots=20)
    max_len = wl["long_p"] + wl["max_new"]
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, vocab,
                        size=wl["long_p"] if i % 4 == 3 else wl["short_p"],
                        dtype=np.int32),
                    max_new=wl["max_new"], arrival=i * wl["spacing"])
            for i in range(wl["n_requests"])]
    parity_pages = wl["slots"] * (-(-max_len // wl["page"]))
    out = {"workload": {**wl, "max_len": max_len,
                        "pool_pages": parity_pages,
                        "pool_tokens": parity_pages * wl["page"]}}
    for name in ("fp32", "qkv"):
        kw = VARIANTS[name]
        dense = ServeEngine(model=model, params=params, max_len=max_len,
                            batch_slots=wl["slots"], **kw)
        d_res, d_st = dense.scheduler(chunk_size=wl["chunk"]).run(reqs,
                                                                  seed=seed)
        # parity: same slots, pool tokens == the dense slab's rows
        par = ServeEngine(model=model, params=params, max_len=max_len,
                          batch_slots=wl["slots"], paged_kv=True,
                          page_size=wl["page"], **kw)
        p_res, p_st = par.scheduler(chunk_size=wl["chunk"]).run(reqs,
                                                               seed=seed)
        for r in reqs:                       # acceptance bar: identity
            assert p_res[r.rid].tokens == d_res[r.rid].tokens, (
                f"paged/dense token divergence: variant {name} rid {r.rid}")
        # capacity: SAME pool tokens, 2.5x the slots — pages, not slots,
        # bound admission now
        cap = ServeEngine(model=model, params=params, max_len=max_len,
                          batch_slots=wl["cap_slots"], paged_kv=True,
                          page_size=wl["page"], kv_pool_pages=parity_pages,
                          **kw)
        c_res, c_st = cap.scheduler(chunk_size=wl["chunk"]).run(reqs,
                                                                seed=seed)
        assert sorted(c_res) == sorted(r.rid for r in reqs)
        ratio = c_st.peak_live_slots / max(d_st.peak_live_slots, 1)
        out[name] = {
            "tokens_identical": True,
            "dense_peak_live": d_st.peak_live_slots,
            "paged_parity_peak_live": p_st.peak_live_slots,
            "capacity_peak_live": c_st.peak_live_slots,
            "capacity_ratio": round(ratio, 3),
            "dense_tok_s": round(d_st.steady_tok_s, 2),
            "paged_tok_s": round(p_st.steady_tok_s, 2),
            "capacity_tok_s": round(c_st.steady_tok_s, 2),
            "dense_cache_bytes": d_st.peak_cache_bytes,
            "capacity_cache_bytes": c_st.peak_cache_bytes,
            "capacity_page_stalls": c_st.page_stalls,
            "capacity_page_occupancy": round(c_st.page_occupancy, 4),
            "capacity_peak_pages": c_st.peak_pages_in_use,
        }
        print(f"paged/{name:5s} identity ok | peak live dense "
              f"{d_st.peak_live_slots} vs paged {c_st.peak_live_slots} "
              f"at equal pool tokens ({ratio:.2f}x) | page stalls "
              f"{c_st.page_stalls} | fill {c_st.page_occupancy:.2f} | "
              f"tok/s dense {d_st.steady_tok_s:.1f} paged "
              f"{c_st.steady_tok_s:.1f}")
    return out


def bench_shared(model, params, vocab, *, smoke=True, seed=0):
    """Prefix-sharing sweep: N requests over K distinct system prompts.

    Three runs per variant at a roomy parity pool — dense, paged unshared,
    paged shared — must be token-identical (the suffixes diverge after the
    shared prefix, so this also pins COW and divergence-page handling).
    Then the tight-pool pair (equal pool bytes, sharing on vs off) yields
    the capacity ratio ``check_shared`` gates: shared admissions map the
    resident prefix instead of allocating it, so the same pool holds more
    concurrent requests.
    """
    if smoke:
        wl = dict(n_requests=12, n_prompts=2, sys_len=96, suffix=16,
                  max_new=16, spacing=1, slots=10, chunk=32, page=16,
                  tight_pages=28)
    else:
        wl = dict(n_requests=24, n_prompts=3, sys_len=192, suffix=32,
                  max_new=24, spacing=1, slots=16, chunk=64, page=16,
                  tight_pages=84)
    plen = wl["sys_len"] + wl["suffix"]
    max_len = plen + wl["max_new"]
    rng = np.random.default_rng(seed)
    sys_prompts = [rng.integers(0, vocab, size=wl["sys_len"], dtype=np.int32)
                   for _ in range(wl["n_prompts"])]
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_prompts[i % wl["n_prompts"]],
                         rng.integers(0, vocab, size=wl["suffix"],
                                      dtype=np.int32)]),
                    max_new=wl["max_new"], arrival=i * wl["spacing"])
            for i in range(wl["n_requests"])]
    parity_pages = wl["slots"] * (-(-max_len // wl["page"]))
    out = {"workload": {**wl, "prompt_len": plen, "max_len": max_len,
                        "parity_pages": parity_pages}}
    for name in ("fp32", "qkv"):
        kw = VARIANTS[name]
        dense = ServeEngine(model=model, params=params, max_len=max_len,
                            batch_slots=wl["slots"], **kw)
        d_res, _ = dense.scheduler(chunk_size=wl["chunk"]).run(reqs, seed=seed)
        par = ServeEngine(model=model, params=params, max_len=max_len,
                          batch_slots=wl["slots"], paged_kv=True,
                          page_size=wl["page"], **kw)
        u_res, u_st = par.scheduler(chunk_size=wl["chunk"],
                                    prefix_sharing=False).run(reqs, seed=seed)
        s_res, s_st = par.scheduler(chunk_size=wl["chunk"]).run(reqs,
                                                                seed=seed)
        for r in reqs:  # acceptance bar: identity incl. divergent suffixes
            assert s_res[r.rid].tokens == d_res[r.rid].tokens, (
                f"shared/dense token divergence: variant {name} rid {r.rid}")
            assert u_res[r.rid].tokens == d_res[r.rid].tokens, (
                f"unshared/dense token divergence: variant {name} "
                f"rid {r.rid}")
        assert s_st.prefix_hits > 0, "workload produced no prefix hits"
        # capacity: the SAME tight pool, sharing on vs off
        tight = ServeEngine(model=model, params=params, max_len=max_len,
                            batch_slots=wl["slots"], paged_kv=True,
                            page_size=wl["page"],
                            kv_pool_pages=wl["tight_pages"], **kw)
        cs_res, cs_st = tight.scheduler(chunk_size=wl["chunk"]).run(reqs,
                                                                    seed=seed)
        cu_res, cu_st = tight.scheduler(
            chunk_size=wl["chunk"], prefix_sharing=False).run(reqs, seed=seed)
        for r in reqs:   # tight pools reorder the schedule, not the tokens
            assert cs_res[r.rid].tokens == d_res[r.rid].tokens, (name, r.rid)
            assert cu_res[r.rid].tokens == d_res[r.rid].tokens, (name, r.rid)
        ratio = cs_st.peak_live_slots / max(cu_st.peak_live_slots, 1)
        page_cut = 1.0 - s_st.peak_pages_in_use / max(u_st.peak_pages_in_use,
                                                      1)
        out[name] = {
            "tokens_identical": True,
            "prefix_hits": s_st.prefix_hits,
            "shared_pages_mapped": s_st.shared_pages_mapped,
            "cow_copies": s_st.cow_copies,
            "parity_peak_pages_unshared": u_st.peak_pages_in_use,
            "parity_peak_pages_shared": s_st.peak_pages_in_use,
            "parity_page_reduction": round(page_cut, 3),
            "tight_peak_live_shared": cs_st.peak_live_slots,
            "tight_peak_live_unshared": cu_st.peak_live_slots,
            "shared_capacity_ratio": round(ratio, 3),
            "tight_page_stalls_shared": cs_st.page_stalls,
            "tight_page_stalls_unshared": cu_st.page_stalls,
            "shared_tok_s": round(cs_st.steady_tok_s, 2),
            "unshared_tok_s": round(cu_st.steady_tok_s, 2),
        }
        print(f"shared/{name:5s} identity ok | tight-pool peak live "
              f"{cu_st.peak_live_slots} -> {cs_st.peak_live_slots} "
              f"({ratio:.2f}x) | parity peak pages "
              f"{u_st.peak_pages_in_use} -> {s_st.peak_pages_in_use} "
              f"(-{page_cut:.0%}) | hits {s_st.prefix_hits} "
              f"cow {s_st.cow_copies}")
    return out


def bench_oversub(model, params, vocab, *, smoke=True, seed=0):
    """Oversubscription sweep: lazy decode-page growth + preemption vs
    up-front worst-case reservation, at the SAME tight pool.

    Every request carries a long decode horizon (max_new ~= 0.75x prompt),
    so up-front admission reserves almost half its pages for rows that do
    not exist yet; lazy admission reserves only the prompt extent and grows
    one page per crossed boundary, preempting (recompute or swap) when the
    pool runs dry.  Token identity of both policies vs the dense run is
    asserted (fp32 and int8 KV); the gate (``check_oversub``) is peak
    concurrent requests, lazy vs up-front, at equal pool bytes.
    """
    if smoke:
        wl = dict(n_requests=10, plen=64, max_new=48, spacing=1, slots=10,
                  chunk=32, page=16, pool_pages=21)
    else:
        wl = dict(n_requests=20, plen=128, max_new=96, spacing=1, slots=20,
                  chunk=64, page=16, pool_pages=42)
    max_len = wl["plen"] + wl["max_new"]
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=wl["plen"],
                                        dtype=np.int32),
                    max_new=wl["max_new"], arrival=i * wl["spacing"])
            for i in range(wl["n_requests"])]
    out = {"workload": {**wl, "max_len": max_len,
                        "pool_tokens": wl["pool_pages"] * wl["page"]}}
    for name in ("fp32", "qkv"):
        kw = VARIANTS[name]
        dense = ServeEngine(model=model, params=params, max_len=max_len,
                            batch_slots=wl["slots"], **kw)
        d_res, _ = dense.scheduler(chunk_size=wl["chunk"],
                                   prefix_sharing=False).run(reqs, seed=seed)
        tight = ServeEngine(model=model, params=params, max_len=max_len,
                            batch_slots=wl["slots"], paged_kv=True,
                            page_size=wl["page"],
                            kv_pool_pages=wl["pool_pages"], **kw)
        u_res, u_st = tight.scheduler(
            chunk_size=wl["chunk"], prefix_sharing=False).run(reqs, seed=seed)
        for r in reqs:
            assert u_res[r.rid].tokens == d_res[r.rid].tokens, (
                f"upfront/dense token divergence: variant {name} rid {r.rid}")
        out[name] = {"upfront_peak_live": u_st.peak_live_slots,
                     "upfront_page_stalls": u_st.page_stalls,
                     "upfront_page_occupancy": round(u_st.page_occupancy, 4),
                     "upfront_p99_ttft_steps":
                         u_st.summary()["p99_ttft_steps"]}
        for policy in ("recompute", "swap"):
            o_res, o_st = tight.scheduler(
                chunk_size=wl["chunk"], prefix_sharing=False,
                oversubscribe=True, preempt_policy=policy).run(reqs,
                                                               seed=seed)
            # acceptance bar: preempt+resume is token-invisible
            for r in reqs:
                assert o_res[r.rid].tokens == d_res[r.rid].tokens, (
                    f"oversub({policy})/dense token divergence: variant "
                    f"{name} rid {r.rid}")
            assert o_st.preemptions > 0, (
                f"oversub({policy})/{name}: pool never ran dry — the "
                f"workload no longer exercises preemption")
            ratio = o_st.peak_live_slots / max(u_st.peak_live_slots, 1)
            osum = o_st.summary()
            out[name][policy] = {
                "tokens_identical": True,
                "peak_live": o_st.peak_live_slots,
                "oversub_ratio": round(ratio, 3),
                "grown_pages": o_st.grown_pages,
                "preemptions": o_st.preemptions,
                "resumes": o_st.resumes,
                "swapped_pages": o_st.swapped_pages,
                "swap_peak_bytes": o_st.swap_peak_bytes,
                "page_occupancy": round(o_st.page_occupancy, 4),
                "p99_ttft_steps": osum["p99_ttft_steps"],
                "tok_s": round(o_st.steady_tok_s, 2),
            }
            print(f"oversub/{name:5s} {policy:9s} identity ok | peak live "
                  f"{u_st.peak_live_slots} -> {o_st.peak_live_slots} "
                  f"({ratio:.2f}x at equal pool bytes) | grown "
                  f"{o_st.grown_pages} preempt {o_st.preemptions} "
                  f"resume {o_st.resumes} swapped {o_st.swapped_pages} | "
                  f"fill {o_st.page_occupancy:.2f} | p99 ttft "
                  f"{osum['p99_ttft_steps']} vs "
                  f"{out[name]['upfront_p99_ttft_steps']} steps")
    return out


def bench_burst(model, params, vocab, *, smoke=True, seed=0):
    """Burst-arrival sweep: N prompts landing in ONE tick, ragged multi-lane
    prefill vs the single-lane mixed step at the same token budget.

    The mixed step is structurally capped at one C-token chunk per tick no
    matter the budget, so a burst drains serially: request i waits ~i full
    prompts before its first token.  The ragged step flattens up to
    ``prefill_lanes`` chunks into its one forward and spends the whole
    token budget per tick, so the burst drains ``~lanes``-wide.  Token
    identity of all three runs (dense mixed reference, paged mixed, paged
    ragged) is asserted in-run; the gate (``check_burst``) is p99 TTFT in
    deterministic virtual-time steps, mixed vs ragged, >= 1.2x in CI.
    """
    if smoke:
        wl = dict(n_requests=8, plen=96, max_new=8, slots=8, chunk=16,
                  lanes=4, budget=64, page=16)
    else:
        wl = dict(n_requests=16, plen=192, max_new=16, slots=16, chunk=32,
                  lanes=4, budget=160, page=16)
    max_len = wl["plen"] + wl["max_new"]
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=wl["plen"],
                                        dtype=np.int32),
                    max_new=wl["max_new"], arrival=0)
            for i in range(wl["n_requests"])]
    out = {"workload": {**wl, "max_len": max_len}}
    for name in ("fp32", "qkv"):
        kw = VARIANTS[name]
        dense = ServeEngine(model=model, params=params, max_len=max_len,
                            batch_slots=wl["slots"], **kw)
        d_res, _ = dense.scheduler(chunk_size=wl["chunk"],
                                   token_budget=wl["budget"]).run(reqs,
                                                                  seed=seed)
        paged = ServeEngine(model=model, params=params, max_len=max_len,
                            batch_slots=wl["slots"], paged_kv=True,
                            page_size=wl["page"], **kw)
        m_res, m_st = paged.scheduler(
            chunk_size=wl["chunk"], token_budget=wl["budget"]).run(reqs,
                                                                   seed=seed)
        r_res, r_st = paged.scheduler(
            chunk_size=wl["chunk"], token_budget=wl["budget"], ragged=True,
            prefill_lanes=wl["lanes"]).run(reqs, seed=seed)
        for r in reqs:   # acceptance bar: the ragged forward is a pure
            #              batching change — streams must not move
            assert m_res[r.rid].tokens == d_res[r.rid].tokens, (
                f"paged-mixed/dense token divergence: variant {name} "
                f"rid {r.rid}")
            assert r_res[r.rid].tokens == d_res[r.rid].tokens, (
                f"ragged/dense token divergence: variant {name} rid {r.rid}")
        msum, rsum = m_st.summary(), r_st.summary()
        ratio = msum["p99_ttft_steps"] / max(rsum["p99_ttft_steps"], 1e-9)
        out[name] = {
            "tokens_identical": True,
            "mixed_p99_ttft_steps": msum["p99_ttft_steps"],
            "ragged_p99_ttft_steps": rsum["p99_ttft_steps"],
            "burst_ttft_ratio": round(ratio, 3),
            "mixed_p50_ttft_steps": msum["p50_ttft_steps"],
            "ragged_p50_ttft_steps": rsum["p50_ttft_steps"],
            "mixed_decode_steps": m_st.decode_steps,
            "ragged_decode_steps": r_st.decode_steps,
            "mixed_tok_s": round(m_st.steady_tok_s, 2),
            "ragged_tok_s": round(r_st.steady_tok_s, 2),
            "mixed_jit_compiles": msum["num_jit_compiles"],
            "ragged_jit_compiles": rsum["num_jit_compiles"],
            "ragged_prefill_chunks": r_st.prefill_chunks,
            "ragged_stalled_chunks": r_st.stalled_chunks,
        }
        print(f"burst/{name:5s} identity ok | p99 TTFT mixed "
              f"{msum['p99_ttft_steps']:.0f} -> ragged "
              f"{rsum['p99_ttft_steps']:.0f} steps ({ratio:.2f}x, "
              f"{wl['lanes']} lanes, budget {wl['budget']}) | ticks "
              f"{m_st.decode_steps} -> {r_st.decode_steps} | jit shapes "
              f"{rsum['num_jit_compiles']}")
    return out


def bench_hetero(*, smoke=True, seed=0):
    """Heterogeneous-state sweep: the slot-state adapters' two new workload
    classes (serve/slot_state.py).

    **EncDec cross-attention cache**: the same whisper-style workload (long
    encoder context, decode-heavy requests) served with the per-slot xkv
    cache (``CrossAttnState``: K/V projected ONCE at admission) vs
    ``cross_attn_cache=False`` (every decode step re-projects ``enc``
    through every cross layer).  Token identity is asserted in-run; the
    gate (``check_hetero``) is steady tok/s, cached vs recomputed,
    best-of-3 same-process repeats so the ratio is noise-robust.

    **SSM bytes-per-slot**: ``state_bytes_per_slot`` over a mamba cache at
    two ``max_len`` geometries vs an equal-config transformer KV cache —
    recurrent state is constant in sequence length (asserted in-run) while
    the KV slab grows linearly; reported alongside a small served mamba
    workload's steady tok/s.
    """
    from repro.serve import state_bytes_per_slot

    if smoke:
        wl = dict(n_requests=16, plen=8, max_new=32, spacing=2, slots=8,
                  chunk=8, s_enc=768, d_model=128, repeats=3)
    else:
        wl = dict(n_requests=24, plen=16, max_new=64, spacing=2, slots=8,
                  chunk=16, s_enc=1280, d_model=128, repeats=3)
    import dataclasses as _dc

    # long encoder + widened d_model on the smoke skeleton: the cached-vs-
    # recomputed gap is the per-step K/V projection, O(S_enc * d^2) — at the
    # smoke config's d=64 it hides under fixed per-tick cost
    ecfg = _dc.replace(get_config("whisper-tiny-smoke"), enc_seq=wl["s_enc"],
                       d_model=wl["d_model"], n_heads=8, n_kv_heads=8,
                       d_ff=2 * wl["d_model"])
    emodel = ecfg.build(dtype=jnp.float32, remat="off")
    eparams = emodel.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    ctx_rng = jax.random.PRNGKey(seed + 1)
    from repro.nn.module import eval_context

    encs = []
    for i in range(wl["n_requests"]):
        ctx_rng, sub = jax.random.split(ctx_rng)
        embeds = 0.1 * jax.random.normal(
            sub, (1, wl["s_enc"], emodel.d_model), jnp.float32)
        encs.append(emodel.encode(eparams, embeds, eval_context()))
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, ecfg.vocab, size=wl["plen"],
                                        dtype=np.int32),
                    max_new=wl["max_new"], arrival=i * wl["spacing"],
                    enc=encs[i])
            for i in range(wl["n_requests"])]
    max_len = wl["plen"] + wl["max_new"]
    cached_eng = ServeEngine(model=emodel, params=eparams, max_len=max_len,
                             batch_slots=wl["slots"])
    recomp_eng = ServeEngine(model=emodel, params=eparams, max_len=max_len,
                             batch_slots=wl["slots"], cross_attn_cache=False)
    cached_p = cached_eng.scheduler(chunk_size=wl["chunk"])
    recomp_p = recomp_eng.scheduler(chunk_size=wl["chunk"])
    c_tok, r_tok = 0.0, 0.0
    c_res = r_res = None
    c_st = r_st = None
    for _ in range(wl["repeats"]):   # interleaved best-of-N: noise-robust
        c_res, c_st = cached_p.run(reqs, seed=seed)
        r_res, r_st = recomp_p.run(reqs, seed=seed)
        c_tok = max(c_tok, c_st.steady_tok_s)
        r_tok = max(r_tok, r_st.steady_tok_s)
    for r in reqs:    # acceptance bar: the cache is a FLOPs cut, not a
        #               semantics change
        assert c_res[r.rid].tokens == r_res[r.rid].tokens, (
            f"cached/recomputed cross-attn token divergence on rid {r.rid}")
    ratio = c_tok / max(r_tok, 1e-9)
    xkv_bytes = state_bytes_per_slot(
        emodel.init_cache(wl["slots"], max_len, per_slot_len=True,
                          kv_dtype=jnp.float32), wl["slots"])
    out = {"workload": {**wl, "max_len": max_len},
           "encdec": {
               "tokens_identical": True,
               "cached_tok_s": round(c_tok, 2),
               "recompute_tok_s": round(r_tok, 2),
               "cross_cache_ratio": round(ratio, 3),
               "cached_state_kinds": c_st.state_kinds,
               "recompute_state_kinds": r_st.state_kinds,
               "cross_bytes_per_slot": xkv_bytes["cross"],
           }}
    print(f"hetero/encdec identity ok | cached {c_tok:.1f} tok/s vs "
          f"recomputed {r_tok:.1f} ({ratio:.2f}x, S_enc {wl['s_enc']}) | "
          f"xkv {xkv_bytes['cross']} B/slot")

    # --- SSM: constant bytes/slot + a served workload -----------------------
    scfg = get_config("mamba-130m-smoke")
    smodel = scfg.build(dtype=jnp.float32, remat="off")
    sparams = smodel.init(jax.random.PRNGKey(seed))
    tcfg = get_config("smollm-135m-smoke")
    tmodel = tcfg.build(dtype=jnp.float32, remat="off")
    lens = (max_len, 2 * max_len)
    rec = [state_bytes_per_slot(
        smodel.init_cache(wl["slots"], n, per_slot_len=True,
                          kv_dtype=jnp.float32), wl["slots"]) for n in lens]
    kvb = [state_bytes_per_slot(
        tmodel.init_cache(wl["slots"], n, per_slot_len=True,
                          kv_dtype=jnp.float32), wl["slots"]) for n in lens]
    assert rec[0]["recurrent"] == rec[1]["recurrent"] > 0, (
        "recurrent bytes/slot moved with max_len — the state is no longer "
        "constant-size")
    sreqs = [Request(rid=i,
                     prompt=rng.integers(0, scfg.vocab, size=wl["plen"],
                                         dtype=np.int32),
                     max_new=wl["max_new"], arrival=i * wl["spacing"])
             for i in range(wl["n_requests"])]
    s_res, s_st = ServeEngine(
        model=smodel, params=sparams, max_len=max_len,
        batch_slots=wl["slots"]).scheduler(chunk_size=wl["chunk"]).run(
            sreqs, seed=seed)
    assert sorted(s_res) == sorted(r.rid for r in sreqs)
    assert all(r.status == "ok" for r in s_res.values())
    out["ssm"] = {
        "state_kinds": s_st.state_kinds,
        "tok_s": round(s_st.steady_tok_s, 2),
        "recurrent_bytes_per_slot": rec[0]["recurrent"],
        "recurrent_bytes_per_slot_2x_len": rec[1]["recurrent"],
        "kv_bytes_per_slot": kvb[0]["kv"],
        "kv_bytes_per_slot_2x_len": kvb[1]["kv"],
        "kv_over_recurrent": round(kvb[0]["kv"]
                                   / max(rec[0]["recurrent"], 1), 2),
    }
    print(f"hetero/ssm    {s_st.steady_tok_s:8.1f} tok/s "
          f"({s_st.state_kinds}) | recurrent {rec[0]['recurrent']} B/slot "
          f"constant across max_len {lens[0]}->{lens[1]} | transformer KV "
          f"{kvb[0]['kv']} -> {kvb[1]['kv']} B/slot (linear)")
    return out


def bench_chaos(model, params, vocab, *, smoke=True, seed=0):
    """Chaos sweep: the hardening stack under an injected fault schedule.

    The oversubscribed swap workload runs with generous per-request
    deadlines, a bounded admission queue and ``audit=True`` (the every-tick
    pool/state auditor + NaN sentinel), twice per variant at identical
    config: fault-free reference, then under a :class:`FaultPlan` mixing
    pool-exhaustion ticks, swap-area refusals, an admission stall and one
    NaN-logit event.  In-run assertions: the faulted run finishes without
    raising, every request gets a terminal status, exactly the NaN victim
    is ``failed`` (its tokens a clean prefix of its reference stream), no
    non-faulted request times out or is rejected, and every non-faulted
    stream is token-identical to the reference.  ``check_chaos`` gates the
    non-faulted completion rate at exactly 1.0.
    """
    if smoke:
        wl = dict(n_requests=10, plen=64, max_new=48, spacing=1, slots=10,
                  chunk=32, page=16, pool_pages=21, deadline=600,
                  max_queue=10)
        plan = FaultPlan(alloc_fail={6, 7}, swap_fail={6, 7, 9},
                         admit_stall={3}, nan={40: 2})
    else:
        wl = dict(n_requests=20, plen=128, max_new=96, spacing=1, slots=20,
                  chunk=64, page=16, pool_pages=42, deadline=1200,
                  max_queue=20)
        plan = FaultPlan(alloc_fail={10, 11}, swap_fail={10, 11, 14},
                         admit_stall={4}, nan={80: 3})
    max_len = wl["plen"] + wl["max_new"]
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=wl["plen"],
                                        dtype=np.int32),
                    max_new=wl["max_new"], arrival=i * wl["spacing"],
                    deadline_steps=wl["deadline"])
            for i in range(wl["n_requests"])]
    out = {"workload": {**wl, "max_len": max_len},
           "fault_plan": plan.to_json()}
    for name in ("fp32", "qkv"):
        kw = VARIANTS[name]
        eng = ServeEngine(model=model, params=params, max_len=max_len,
                          batch_slots=wl["slots"], paged_kv=True,
                          page_size=wl["page"],
                          kv_pool_pages=wl["pool_pages"], **kw)
        sched = lambda: eng.scheduler(  # noqa: E731
            chunk_size=wl["chunk"], prefix_sharing=False,
            oversubscribe=True, preempt_policy="swap", audit=True,
            max_queue=wl["max_queue"], reject_policy="reject")
        ref_res, ref_st = sched().run(reqs, seed=seed)
        assert all(r.status == "ok" for r in ref_res.values()), (
            f"chaos/{name}: fault-free reference run degraded")
        assert ref_st.audited_ticks > 0
        f_res, f_st = sched().run(reqs, seed=seed, fault_plan=plan)
        # terminal-status totality: nothing raised, nothing lost
        assert sorted(f_res) == sorted(r.rid for r in reqs)
        failed = sorted(r.rid for r in f_res.values()
                        if r.status == "failed")
        assert f_st.nan_evictions == 1 and len(failed) == 1, (
            f"chaos/{name}: expected exactly the NaN victim to fail, got "
            f"{failed} (nan_evictions {f_st.nan_evictions})")
        victim = failed[0]
        assert f_st.timeouts == 0 and f_st.rejections == 0, (
            f"chaos/{name}: non-faulted requests degraded (timeouts "
            f"{f_st.timeouts}, rejections {f_st.rejections})")
        vtoks = f_res[victim].tokens
        assert vtoks == ref_res[victim].tokens[:len(vtoks)], (
            f"chaos/{name}: NaN victim rid {victim} emitted a poisoned "
            f"token before eviction")
        for r in reqs:   # faults reorder the schedule, never the streams
            if r.rid == victim:
                continue
            assert f_res[r.rid].tokens == ref_res[r.rid].tokens, (
                f"chaos/{name}: token divergence under faults on "
                f"non-faulted rid {r.rid}")
        assert f_st.fault_events > 0 and f_st.audited_ticks > 0
        assert f_st.swap_refusals > 0, (
            f"chaos/{name}: the swap-refusal seam never fired — retune "
            f"the plan's swap_fail ticks to overlap a preemption")
        nonfaulted_ok = sum(1 for r in f_res.values()
                            if r.status == "ok")
        rate = nonfaulted_ok / max(len(reqs) - len(failed), 1)
        out[name] = {
            "tokens_identical": True,
            "statuses": {s: sum(1 for r in f_res.values()
                                if r.status == s)
                         for s in sorted({r.status
                                          for r in f_res.values()})},
            "nan_victim_rid": victim,
            "victim_clean_tokens": len(vtoks),
            "fault_events": f_st.fault_events,
            "nan_evictions": f_st.nan_evictions,
            "swap_refusals": f_st.swap_refusals,
            "preemptions": f_st.preemptions,
            "resumes": f_st.resumes,
            "deadlock_failures": f_st.deadlock_failures,
            "audited_ticks_faulted": f_st.audited_ticks,
            "audited_ticks_reference": ref_st.audited_ticks,
            "nonfaulted_completion_rate": round(rate, 4),
            "completion_rate": round(f_st.completion_rate, 4),
        }
        print(f"chaos/{name:5s} identity ok | {f_st.fault_events} fault "
              f"events ({f_st.swap_refusals} swap refusals) | NaN victim "
              f"rid {victim} failed after {len(vtoks)} clean tokens | "
              f"preempt {f_st.preemptions} resume {f_st.resumes} | audited "
              f"{f_st.audited_ticks} ticks clean | non-faulted completion "
              f"{rate:.2f}")
    return out


def run(smoke: bool = True, seed: int = 0, out_path: str = None):
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(seed))
    # Long prompts + alternating short/long horizons: the restart baseline
    # holds every slot for the batch's longest request, and one-shot
    # admission stalls every live slot for a full prompt prefill per freed
    # slot — the chunked mixed step reclaims both.
    if smoke:
        wl_cfg = dict(n_requests=12, prompt_len=512, short_new=8, long_new=48,
                      spacing=4, slots=4, chunk=256)
    else:
        wl_cfg = dict(n_requests=32, prompt_len=1024, short_new=8, long_new=64,
                      spacing=6, slots=8, chunk=256)
    workload = make_workload(
        wl_cfg["n_requests"], wl_cfg["prompt_len"], wl_cfg["short_new"],
        wl_cfg["long_new"], wl_cfg["spacing"], cfg.vocab, seed=seed)
    max_len = wl_cfg["prompt_len"] + wl_cfg["long_new"]

    results = {"config": {"arch": "smollm-135m-smoke", "backend":
                          jax.default_backend(), **wl_cfg},
               "variants": {}}
    for name, kw in VARIANTS.items():
        results["variants"][name] = bench_variant(
            model, params, kw, workload, max_len=max_len,
            slots=wl_cfg["slots"], chunk=wl_cfg["chunk"], seed=seed)
        v = results["variants"][name]
        s, c = v["scheduler"], v["chunked"]
        print(f"{name:8s} chunked {c['steady_tok_s']:8.1f} tok/s "
              f"p99 {c['p99_latency_ms']:7.1f} ms ({c['num_jit_compiles']} "
              f"jit shapes) | one-shot {s['steady_tok_s']:8.1f} tok/s "
              f"p99 {s['p99_latency_ms']:7.1f} ms ({s['num_jit_compiles']}) "
              f"| p99 speedup {v['chunked_p99_speedup']:.2f}x | restart "
              f"{v['restart_tok_s']:7.1f} tok/s")

    results["paged"] = bench_paged(model, params, cfg.vocab, smoke=smoke,
                                   seed=seed)
    results["shared_prefix"] = bench_shared(model, params, cfg.vocab,
                                            smoke=smoke, seed=seed)
    results["oversub"] = bench_oversub(model, params, cfg.vocab, smoke=smoke,
                                       seed=seed)
    results["burst"] = bench_burst(model, params, cfg.vocab, smoke=smoke,
                                   seed=seed)
    results["chaos"] = bench_chaos(model, params, cfg.vocab, smoke=smoke,
                                   seed=seed)
    results["hetero"] = bench_hetero(smoke=smoke, seed=seed)

    out_path = out_path or os.path.join(OUT_DIR, "serve_bench.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")
    return results


def check_relative(results, *, min_p99_speedup: float = 1.0,
                   min_tok_ratio: float = 1.0) -> bool:
    """Same-run chunked-vs-one-shot gate — the noise-robust regression
    signal: box-level contention moves both policies together, so absolute
    wall metrics are weather but the ratio is signal.  Gated on the
    *geomean across variants*: a contention burst landing on one variant's
    repeats can still drag that single ratio below 1 (observed 0.6-0.7x
    outliers on a healthy build whose other variants read 1.2-1.8x), while
    a real chunked-path regression drags every variant — the geomean
    separates the two cleanly (healthy: >= 1.1 on every observed run;
    broken full-scan build: 0.93)."""
    p99s, toks = [], []
    for name, v in results["variants"].items():
        s, c = v["scheduler"], v["chunked"]
        ratio_p99 = s["p99_latency_ms"] / max(c["p99_latency_ms"], 1e-9)
        ratio_tok = c["steady_tok_s"] / max(s["steady_tok_s"], 1e-9)
        p99s.append(ratio_p99)
        toks.append(ratio_tok)
        print(f"   {name}: chunked vs one-shot p99 {ratio_p99:.2f}x, "
              f"tok/s {ratio_tok:.2f}x")
    gm = lambda xs: float(np.exp(np.mean(np.log(np.maximum(xs, 1e-9)))))
    gm_p99, gm_tok = gm(p99s), gm(toks)
    ok = True
    if gm_p99 < min_p99_speedup:
        print(f"REGRESSION: geomean chunked p99 speedup {gm_p99:.2f}x < "
              f"{min_p99_speedup:.2f}x — chunked no longer beats one-shot")
        ok = False
    if gm_tok < min_tok_ratio:
        print(f"REGRESSION: geomean chunked tok/s ratio {gm_tok:.2f} < "
              f"{min_tok_ratio:.2f} — chunked steady throughput regressed")
        ok = False
    if ok:
        print(f"ok relative gate: geomean p99 speedup {gm_p99:.2f}x, "
              f"tok/s ratio {gm_tok:.2f}x")
    return ok


def check_paged(results, *, min_capacity_ratio: float = 1.5) -> bool:
    """The paged capacity gate: at equal KV pool tokens, paged serving must
    hold >= ``min_capacity_ratio`` times the dense run's peak concurrent
    requests.  Deterministic for a fixed seed (peak_live_slots counts a
    virtual-time schedule), so there is no tolerance band — identity between
    the paged and dense token streams was already asserted inside the run."""
    ok = True
    for name, v in results.get("paged", {}).items():
        if name == "workload":
            continue
        r = v["capacity_ratio"]
        if r < min_capacity_ratio:
            print(f"REGRESSION paged/{name}: capacity ratio {r:.2f}x < "
                  f"{min_capacity_ratio:.2f}x (dense peak "
                  f"{v['dense_peak_live']}, paged {v['capacity_peak_live']})")
            ok = False
        else:
            print(f"ok paged/{name}: capacity {r:.2f}x "
                  f"({v['dense_peak_live']} -> {v['capacity_peak_live']} "
                  f"peak live at equal pool tokens)")
    return ok


def check_shared(results, *, min_shared_ratio: float = 1.5,
                 min_page_reduction: float = 0.30) -> bool:
    """The prefix-sharing gate: at equal (tight) pool bytes, sharing must
    admit >= ``min_shared_ratio`` times the unshared run's peak concurrent
    requests — or, at the roomy parity pool where both admit everything,
    hold >= ``min_page_reduction`` fewer peak pages.  Deterministic for a
    fixed seed; token identity was already asserted inside the run."""
    ok = True
    for name, v in results.get("shared_prefix", {}).items():
        if name == "workload":
            continue
        r, cut = v["shared_capacity_ratio"], v["parity_page_reduction"]
        if r >= min_shared_ratio or cut >= min_page_reduction:
            print(f"ok shared/{name}: capacity {r:.2f}x "
                  f"({v['tight_peak_live_unshared']} -> "
                  f"{v['tight_peak_live_shared']} peak live), parity pages "
                  f"-{cut:.0%}")
        else:
            print(f"REGRESSION shared/{name}: capacity ratio {r:.2f}x < "
                  f"{min_shared_ratio:.2f}x AND parity page reduction "
                  f"{cut:.0%} < {min_page_reduction:.0%}")
            ok = False
    return ok


def check_oversub(results, *, min_oversub_ratio: float = 1.3) -> bool:
    """The oversubscription gate: at equal pool bytes, lazy growth +
    preemption must hold >= ``min_oversub_ratio`` times the up-front
    reservation's peak concurrent requests, under BOTH preemption policies.
    Deterministic for a fixed seed; token identity (preempt+resume is
    stream-invisible, fp32 and int8 KV) was already asserted inside the
    run, as was preemptions > 0 (the workload must actually drain the
    pool)."""
    ok = True
    for name, v in results.get("oversub", {}).items():
        if name == "workload":
            continue
        for policy in ("recompute", "swap"):
            p = v[policy]
            r = p["oversub_ratio"]
            if r < min_oversub_ratio:
                print(f"REGRESSION oversub/{name}/{policy}: ratio {r:.2f}x "
                      f"< {min_oversub_ratio:.2f}x (upfront peak "
                      f"{v['upfront_peak_live']}, lazy {p['peak_live']})")
                ok = False
            else:
                print(f"ok oversub/{name}/{policy}: {r:.2f}x "
                      f"({v['upfront_peak_live']} -> {p['peak_live']} peak "
                      f"live at equal pool bytes; {p['preemptions']} "
                      f"preemptions)")
    return ok


def check_burst(results, *, min_burst_ttft_ratio: float = 1.2) -> bool:
    """The ragged burst gate: on an N-prompts-in-one-tick burst at the same
    token budget, ragged multi-lane prefill must cut p99 TTFT by >=
    ``min_burst_ttft_ratio`` vs the single-lane mixed step.  Deterministic
    for a fixed seed (TTFT counts virtual-time admission ticks); token
    identity of ragged vs mixed vs dense was already asserted inside the
    run."""
    ok = True
    for name, v in results.get("burst", {}).items():
        if name == "workload":
            continue
        r = v["burst_ttft_ratio"]
        if r < min_burst_ttft_ratio:
            print(f"REGRESSION burst/{name}: ragged p99 TTFT speedup "
                  f"{r:.2f}x < {min_burst_ttft_ratio:.2f}x (mixed "
                  f"{v['mixed_p99_ttft_steps']:.0f} steps, ragged "
                  f"{v['ragged_p99_ttft_steps']:.0f})")
            ok = False
        else:
            print(f"ok burst/{name}: ragged p99 TTFT {r:.2f}x better "
                  f"({v['mixed_p99_ttft_steps']:.0f} -> "
                  f"{v['ragged_p99_ttft_steps']:.0f} steps)")
    return ok


def check_chaos(results) -> bool:
    """The chaos gate: under the injected fault schedule, every request the
    plan did NOT poison must complete ``ok`` — non-faulted completion rate
    exactly 1.0.  Deterministic for a fixed seed; token identity of the
    non-faulted streams vs the fault-free reference, single-victim NaN
    containment and clean auditor ticks were already asserted inside the
    run."""
    ok = True
    for name, v in results.get("chaos", {}).items():
        if name in ("workload", "fault_plan"):
            continue
        rate = v["nonfaulted_completion_rate"]
        if rate < 1.0:
            print(f"REGRESSION chaos/{name}: non-faulted completion rate "
                  f"{rate:.2f} < 1.00 (statuses {v['statuses']})")
            ok = False
        else:
            print(f"ok chaos/{name}: non-faulted completion 1.00 "
                  f"({v['fault_events']} fault events contained; NaN victim "
                  f"rid {v['nan_victim_rid']} failed cleanly; "
                  f"{v['audited_ticks_faulted']} audited ticks)")
    return ok


def check_hetero(results, *, min_hetero_ratio: float = 1.15) -> bool:
    """The heterogeneous-state gate: on the long-encoder EncDec workload,
    per-slot cross-attention K/V caching (project once at admission) must
    beat per-step recomputation on steady tok/s by >= ``min_hetero_ratio``.
    Best-of-N same-process repeats on both sides keeps the ratio
    noise-robust (box-level contention moves both runs together); token
    identity of the cached vs recomputed streams and the constant-size
    recurrent bytes/slot were already asserted inside the run."""
    h = results.get("hetero", {})
    if not h:
        return True
    e = h["encdec"]
    ok = True
    r = e["cross_cache_ratio"]
    if r < min_hetero_ratio:
        print(f"REGRESSION hetero/encdec: cross-attn cache speedup "
              f"{r:.2f}x < {min_hetero_ratio:.2f}x (cached "
              f"{e['cached_tok_s']:.1f} tok/s, recomputed "
              f"{e['recompute_tok_s']:.1f})")
        ok = False
    else:
        print(f"ok hetero/encdec: cross-attn cache {r:.2f}x faster "
              f"({e['recompute_tok_s']:.1f} -> {e['cached_tok_s']:.1f} "
              f"tok/s, S_enc {h['workload']['s_enc']})")
    s = h["ssm"]
    print(f"ok hetero/ssm: recurrent {s['recurrent_bytes_per_slot']} B/slot "
          f"constant in max_len; transformer KV "
          f"{s['kv_bytes_per_slot']} -> {s['kv_bytes_per_slot_2x_len']} "
          f"B/slot ({s['kv_over_recurrent']:.1f}x recurrent at parity)")
    return ok


def check_baseline(results, baseline_path: str, tolerance: float,
                   *, strict: bool = False) -> bool:
    """Per variant x policy: compare steady tok/s and p99 latency (in
    deterministic *steps*) against the checked-in baseline.

    Warn-only unless ``strict``: the absolute floors fire spuriously across
    machine classes (a laptop baseline vs a shared CI runner easily moves
    2x), so a miss prints a WARN and the function still passes.  The
    enforced regression signals are the same-run relative gate and the
    paged/shared capacity gates — see module docstring."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    tag = "REGRESSION" if strict else "WARN (not gated)"
    ok = True
    for name, base in baseline["variants"].items():
        cur = results["variants"].get(name)
        if cur is None:
            print(f"REGRESSION {name}: variant missing from current run")
            ok = False
            continue
        for policy in ("scheduler", "chunked"):
            b, c = base.get(policy), cur.get(policy)
            if b is None:
                continue
            if c is None:
                print(f"REGRESSION {name}/{policy}: policy missing")
                ok = False
                continue
            floor = b["steady_tok_s"] * (1.0 - tolerance)
            if c["steady_tok_s"] < floor:
                print(f"{tag} {name}/{policy}: steady "
                      f"{c['steady_tok_s']:.1f} tok/s < floor {floor:.1f} "
                      f"(baseline {b['steady_tok_s']:.1f}, -{tolerance:.0%})")
                ok = ok and not strict
            else:
                print(f"ok {name}/{policy}: {c['steady_tok_s']:.1f} tok/s "
                      f">= floor {floor:.1f}")
            if b.get("p99_latency_steps"):
                ceil = b["p99_latency_steps"] * (1.0 + tolerance)
                if c.get("p99_latency_steps", 0.0) > ceil:
                    print(f"{tag} {name}/{policy}: p99 "
                          f"{c['p99_latency_steps']:.1f} steps > ceiling "
                          f"{ceil:.1f} (baseline "
                          f"{b['p99_latency_steps']:.1f}, +{tolerance:.0%})")
                    ok = ok and not strict
                else:
                    print(f"ok {name}/{policy}: p99 "
                          f"{c['p99_latency_steps']:.1f} steps <= ceiling "
                          f"{ceil:.1f}")
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload (CI's bench-smoke job)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--baseline", default=None,
                    help="compare steady tok/s and p99 latency against this "
                         "JSON; exit 1 on a regression beyond --tolerance")
    ap.add_argument("--tolerance", type=float, default=0.30)
    ap.add_argument("--min-p99-speedup", type=float, default=1.0,
                    help="relative-gate floor: geomean chunked-vs-one-shot "
                         "p99 speedup across variants")
    ap.add_argument("--min-tok-ratio", type=float, default=1.0,
                    help="relative-gate floor: geomean chunked-vs-one-shot "
                         "steady tok/s ratio across variants")
    ap.add_argument("--min-capacity-ratio", type=float, default=1.5,
                    help="paged gate floor: paged-vs-dense peak concurrent "
                         "requests at equal KV pool tokens")
    ap.add_argument("--min-shared-ratio", type=float, default=1.5,
                    help="prefix-sharing gate floor: shared-vs-unshared "
                         "peak concurrent requests at equal pool bytes")
    ap.add_argument("--min-oversub-ratio", type=float, default=1.3,
                    help="oversubscription gate floor: lazy-vs-upfront peak "
                         "concurrent requests at equal pool bytes")
    ap.add_argument("--min-burst-ttft-ratio", type=float, default=1.2,
                    help="burst gate floor: ragged multi-lane vs single-lane "
                         "mixed p99 TTFT on a one-tick arrival burst")
    ap.add_argument("--min-hetero-ratio", type=float, default=1.15,
                    help="heterogeneous-state gate floor: cached vs "
                         "recomputed cross-attn K/V steady tok/s on the "
                         "long-encoder EncDec workload")
    ap.add_argument("--chaos-only", action="store_true",
                    help="run only the fault-injection chaos sweep + its "
                         "gate (the CI chaos lane; cheap enough for "
                         "REPRO_KERNELS_FORCE=interpret)")
    ap.add_argument("--strict-baseline", action="store_true",
                    help="make the absolute --baseline comparison a hard "
                         "gate again (default: warn-only — cross-machine "
                         "absolute numbers are weather)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.chaos_only:
        cfg = get_config("smollm-135m-smoke")
        model = cfg.build(dtype=jnp.float32, remat="off")
        params = model.init(jax.random.PRNGKey(args.seed))
        results = {"chaos": bench_chaos(model, params, cfg.vocab,
                                        smoke=args.smoke, seed=args.seed)}
        out_path = args.out or os.path.join(OUT_DIR, "serve_chaos.json")
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {out_path}")
        if not check_chaos(results):
            raise SystemExit(1)
        print("serve_bench chaos ok")
        return
    results = run(smoke=args.smoke, seed=args.seed, out_path=args.out)
    ok = check_relative(results, min_p99_speedup=args.min_p99_speedup,
                        min_tok_ratio=args.min_tok_ratio)
    ok = check_paged(results,
                     min_capacity_ratio=args.min_capacity_ratio) and ok
    ok = check_shared(results,
                      min_shared_ratio=args.min_shared_ratio) and ok
    ok = check_oversub(results,
                       min_oversub_ratio=args.min_oversub_ratio) and ok
    ok = check_burst(results,
                     min_burst_ttft_ratio=args.min_burst_ttft_ratio) and ok
    ok = check_chaos(results) and ok
    ok = check_hetero(results,
                      min_hetero_ratio=args.min_hetero_ratio) and ok
    if args.baseline:
        ok = check_baseline(results, args.baseline, args.tolerance,
                            strict=args.strict_baseline) and ok
    if not ok:
        raise SystemExit(1)
    print("serve_bench ok")


if __name__ == "__main__":
    main()
