"""Serve throughput bench: continuous batching vs restart-the-batch, swept
over the paper's deployment quantization variants.

    PYTHONPATH=src python -m benchmarks.serve_bench --smoke \\
        [--baseline benchmarks/baselines/serve_bench.json]

For each variant in {fp32, wq (int8 weights), qkv (int8 KV), wq_qkv} the same
staggered-arrival workload (alternating short/long horizons — the length
spread continuous batching exploits) runs through

  * the continuous-batching Scheduler (serve/scheduler.py), and
  * the restart-the-batch lockstep baseline,

and writes ``benchmarks/out/serve_bench.json`` with steady tok/s, slot
occupancy, p50/p99 latency, peak cache bytes and the scheduler/restart
speedup.  This JSON is the perf trajectory CI tracks: with ``--baseline`` the
run fails if any variant's scheduler steady tok/s regresses more than
--tolerance (default 30%) against the checked-in
``benchmarks/baselines/serve_bench.json``.  To refresh the baseline after an
intentional perf change, copy the new out-file over it (see README "Serving").
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_config
from repro.serve import Request, ServeEngine, run_restart_batching

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

VARIANTS = {
    "fp32": {},
    "wq": {"weight_quant": True},
    "qkv": {"quantized_kv": True},
    "wq_qkv": {"weight_quant": True, "quantized_kv": True},
}


def make_workload(n_requests, prompt_len, short_new, long_new, spacing, vocab,
                  seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(rid=i,
                prompt=rng.integers(0, vocab, size=prompt_len, dtype=np.int32),
                max_new=short_new if i % 2 == 0 else long_new,
                arrival=i * spacing)
        for i in range(n_requests)
    ]


def bench_variant(model, params, kw, workload, *, max_len, slots, seed=0):
    engine = ServeEngine(model=model, params=params, max_len=max_len,
                         batch_slots=slots, **kw)
    sched_res, sched = engine.scheduler().run(workload, seed=seed)
    restart_res, restart = run_restart_batching(engine, workload, seed=seed)
    assert sorted(sched_res) == sorted(r.rid for r in workload)
    assert sorted(restart_res) == sorted(r.rid for r in workload)
    s, r = sched.summary(), restart.summary()
    return {
        **{k: s[k] for k in ("steady_tok_s", "compile_s", "occupancy",
                             "p50_latency_steps", "p99_latency_steps",
                             "peak_cache_bytes")},
        "restart_tok_s": r["steady_tok_s"],
        "restart_occupancy": r["occupancy"],
        "speedup_vs_restart": round(s["steady_tok_s"]
                                    / max(r["steady_tok_s"], 1e-9), 3),
    }


def run(smoke: bool = True, seed: int = 0, out_path: str = None):
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(seed))
    # Alternating short/long horizons: the restart baseline holds every slot
    # for the batch's longest request, so the short ones idle ~half the slot
    # ticks — exactly the waste continuous batching reclaims.
    if smoke:
        wl_cfg = dict(n_requests=16, prompt_len=8, short_new=4, long_new=60,
                      spacing=2, slots=4)
    else:
        wl_cfg = dict(n_requests=48, prompt_len=16, short_new=8, long_new=96,
                      spacing=3, slots=8)
    workload = make_workload(
        wl_cfg["n_requests"], wl_cfg["prompt_len"], wl_cfg["short_new"],
        wl_cfg["long_new"], wl_cfg["spacing"], cfg.vocab, seed=seed)
    max_len = wl_cfg["prompt_len"] + wl_cfg["long_new"]

    results = {"config": {"arch": "smollm-135m-smoke", "backend":
                          jax.default_backend(), **wl_cfg},
               "variants": {}}
    for name, kw in VARIANTS.items():
        results["variants"][name] = bench_variant(
            model, params, kw, workload, max_len=max_len,
            slots=wl_cfg["slots"], seed=seed)
        v = results["variants"][name]
        print(f"{name:8s} sched {v['steady_tok_s']:8.1f} tok/s "
              f"(occ {v['occupancy']:.2f}) | restart "
              f"{v['restart_tok_s']:8.1f} tok/s | "
              f"speedup {v['speedup_vs_restart']:.2f}x | "
              f"cache {v['peak_cache_bytes']/1024:.0f} KiB")

    out_path = out_path or os.path.join(OUT_DIR, "serve_bench.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}")
    return results


def check_baseline(results, baseline_path: str, tolerance: float) -> bool:
    with open(baseline_path) as f:
        baseline = json.load(f)
    ok = True
    for name, base in baseline["variants"].items():
        cur = results["variants"].get(name)
        if cur is None:
            print(f"REGRESSION {name}: variant missing from current run")
            ok = False
            continue
        floor = base["steady_tok_s"] * (1.0 - tolerance)
        if cur["steady_tok_s"] < floor:
            print(f"REGRESSION {name}: steady {cur['steady_tok_s']:.1f} tok/s "
                  f"< floor {floor:.1f} "
                  f"(baseline {base['steady_tok_s']:.1f}, -{tolerance:.0%})")
            ok = False
        else:
            print(f"ok {name}: {cur['steady_tok_s']:.1f} tok/s "
                  f">= floor {floor:.1f}")
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload (CI's bench-smoke job)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--baseline", default=None,
                    help="compare steady tok/s against this JSON; exit 1 on "
                         "a regression beyond --tolerance")
    ap.add_argument("--tolerance", type=float, default=0.30)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    results = run(smoke=args.smoke, seed=args.seed, out_path=args.out)
    if args.baseline:
        if not check_baseline(results, args.baseline, args.tolerance):
            raise SystemExit(1)
    print("serve_bench ok")


if __name__ == "__main__":
    main()
