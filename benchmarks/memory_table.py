"""Paper Table A3: model ROM footprint vs filters per data type.

ROM = parameters at logical width + inference-code overhead (cost_model).
Validates claim C3 (÷2 at int16, ÷4 at int8).
"""
from __future__ import annotations

import jax

from repro.configs.microai_resnet import build_resnet
from repro.core import integerize
from repro.core.cost_model import rom_bytes
from repro.core.policy import QMode, QuantPolicy

from .common import write_csv


def run():
    rows = []
    for f in (16, 24, 32, 40, 48, 64, 80):
        model = build_resnet("uci-har", filters=f)
        params = jax.eval_shape(
            lambda m=model: m.init(jax.random.PRNGKey(0)))
        n = sum(p.size for p in jax.tree_util.tree_leaves(params))
        r32 = rom_bytes(n, 32)
        r16 = rom_bytes(n, 16)
        r8 = rom_bytes(n, 8)
        rows.append((f, n, r32, r16, r8, round(r32 / r16, 2),
                     round(r32 / r8, 2)))
    write_csv("memory_table.csv",
              "filters,params,rom_f32,rom_i16,rom_i8,ratio_16,ratio_8", rows)

    # cross-check against a real integerized tree (not just n*width/8)
    model = build_resnet("uci-har", filters=16)
    params = model.init(jax.random.PRNGKey(0))
    pol8 = QuantPolicy(mode=QMode.EVAL, weight_bits=8, act_bits=8)
    i8 = integerize.integerize(params, pol8)
    print(f"# integerized-tree check (f=16): f32={integerize.model_rom_bytes(params)}"
          f" int8={integerize.model_rom_bytes(i8)}")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
