"""Paper Sec. 6.2 / claim C5 (structural): compiled engine vs interpreter.

The paper's KerasCNN2C statically compiles the graph into straight-line code
(letting the compiler fold layer configs into immediates), while TFLite-Micro
interprets a graph microcode op-by-op.  The TPU/JAX analogues:

  compiled    = one jit over the whole model (XLA sees everything, fuses)
  interpreted = per-layer jit'd calls dispatched from Python (op-by-op
                boundary = no cross-layer fusion + dispatch overhead)

Reported: wall time per inference for both, and the ratio.  The absolute
numbers are CPU-container-specific; the *ordering* is the claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.microai_resnet import build_resnet
from repro.nn.layers import max_pool, qadd, relu
from repro.nn.module import Context, eval_context

from .common import dataset, timeit, write_csv


def make_interpreter(model):
    """Op-by-op executor: per-layer kernels are pre-compiled (as a real
    interpreter's are); what remains is dispatch overhead + no cross-layer
    fusion — the TFLM-vs-codegen difference the paper measures."""
    ls = model._layers()
    ctx = eval_context()
    conv = {nm: jax.jit(lambda p, v, l=ls[nm]: l.apply(p, v, Context()))
            for nm in ("conv1", "conv2", "conv3", "short1", "conv4", "conv5",
                       "fc")}
    j_relu = jax.jit(relu)
    j_pool = jax.jit(lambda v: max_pool(v, model.pool, ndim=model.ndim))
    j_add = jax.jit(lambda a, b: qadd(a, b, ctx))
    j_gmax = jax.jit(lambda v: jnp.max(v, axis=1))

    def run(params, x):
        h = j_relu(conv["conv1"](params["conv1"], x))
        r = j_relu(conv["conv2"](params["conv2"], h))
        r = conv["conv3"](params["conv3"], r)
        sc = conv["short1"](params["short1"], h)
        h = j_relu(j_add(r, sc))
        h = j_pool(h)
        r = j_relu(conv["conv4"](params["conv4"], h))
        r = conv["conv5"](params["conv5"], r)
        h = j_relu(j_add(r, h))
        h = j_gmax(h)
        return conv["fc"](params["fc"], h)

    return run


def run():
    rows = []
    for f in (16, 32, 64):
        model = build_resnet("uci-har", filters=f)
        params = model.init(jax.random.PRNGKey(0))
        x, _, _, _ = dataset("uci-har")
        xb = jnp.asarray(x[:1])

        compiled = jax.jit(lambda p, v: model.apply(p, v, Context()))
        t_comp = timeit(compiled, params, xb)
        interp = make_interpreter(model)
        t_interp = timeit(interp, params, xb)
        rows.append((f, round(t_comp, 1), round(t_interp, 1),
                     round(t_interp / t_comp, 2)))
    write_csv("engine_compare.csv",
              "filters,compiled_us,interpreted_us,interp_over_compiled", rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
