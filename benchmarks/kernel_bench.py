"""Kernel microbenchmarks: integer vs float GEMM paths (paper Sec. 2/7 —
"integer operations require much less computation", SMLAD/MXU argument).

On this CPU container the jnp reference paths are timed (the Pallas kernels
target TPU and run here only under interpret=True, which measures Python,
not hardware).  Reported: name,us_per_call,derived.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qformat
from repro.kernels import ref

from .common import timeit, write_csv


def run():
    m = k = n = 512
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    xf = jax.random.normal(kx, (m, k), jnp.float32)
    wf = jax.random.normal(kw, (k, n), jnp.float32)
    # chunked-prefill attention: C=64 chunk over a 2k int8 cache (serve path)
    c, g, hkv, d, s = 64, 4, 4, 64, 2048
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q_ck = jax.random.normal(ks[0], (c, g * hkv, d), jnp.float32)
    k_ck = jax.random.normal(ks[1], (c, hkv, d), jnp.float32)
    v_ck = jax.random.normal(ks[2], (c, hkv, d), jnp.float32)
    k_cache = jax.random.randint(ks[3], (4, s, hkv, d), -100, 100,
                                 jnp.int32).astype(jnp.int8)
    v_cache = jax.random.randint(ks[4], (4, s, hkv, d), -100, 100,
                                 jnp.int32).astype(jnp.int8)
    x8 = qformat.quantize(xf, jnp.int32(5), 8)
    w8 = qformat.quantize(wf, jnp.int32(5), 8)
    w16 = qformat.quantize(wf, jnp.int32(9), 16)
    x16 = qformat.quantize(xf, jnp.int32(9), 16)
    scale = jnp.exp2(-jnp.float32(5))

    fns = {
        "matmul_f32": jax.jit(lambda a, b: a @ b),
        "qmm_int8_acc32": jax.jit(ref.qmm_ref),
        "qmm_int16_acc32": jax.jit(ref.qmm_ref),
        "qmm_requant_int8": jax.jit(
            lambda a, b: ref.qmm_requant_ref(a, b, jnp.int32(5), width=8)),
        "wq_matmul_int8w": jax.jit(
            lambda a, b: ref.wq_matmul_ref(a, b, scale)),
        "fake_quant_fwd": jax.jit(
            lambda a: ref.fake_quant_ref(a, jnp.int32(5), width=8)),
        "qchunk_attn_c64_s2k": jax.jit(
            lambda *a: ref.qchunk_attn_ref(*a, jnp.int32(5), jnp.int32(5),
                                           jnp.int32(1), jnp.int32(512))),
    }
    args = {
        "matmul_f32": (xf, wf),
        "qmm_int8_acc32": (x8, w8),
        "qmm_int16_acc32": (x16, w16),
        "qmm_requant_int8": (x8, w8),
        "wq_matmul_int8w": (xf, w8),
        "fake_quant_fwd": (xf,),
        "qchunk_attn_c64_s2k": (q_ck, k_ck, v_ck, k_cache, v_cache),
    }
    base = None
    rows = []
    for name, fn in fns.items():
        us = timeit(fn, *args[name])
        if name == "matmul_f32":
            base = us
        rows.append((name, round(us, 1),
                     f"{base/us:.2f}x_vs_f32" if base else ""))
    write_csv("kernel_bench.csv", "name,us_per_call,derived", rows)
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
