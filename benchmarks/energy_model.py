"""Paper Table A5: energy per inference (µWh) = I·V·t on both boards.

Reproduces the paper's headline efficiency ordering: the SparkFun Edge is
~6x more power-efficient at equal work (subthreshold operation), and int8/16
beat float by the inference-time ratio.
"""
from __future__ import annotations

from repro.core.cost_model import (BOARDS, inference_energy_uwh,
                                   inference_seconds, resnet6_ops)

from .common import write_csv

FILTERS = [16, 24, 32, 40, 48, 64, 80]


def run():
    rows = []
    for f in FILTERS:
        ops = resnet6_ops(f, 128, 9)
        for board in BOARDS:
            sec = inference_seconds(ops, board)
            uwh = inference_energy_uwh(sec, board)
            rows.append((f, board, round(sec * 1e3, 2), round(uwh, 4)))
    write_csv("energy_model.csv", "filters,board,model_ms,model_uwh", rows)

    # headline ratio check (paper: SparkFun ≈ 6x more efficient at same time)
    e_n = inference_energy_uwh(1.0, "nucleo-l452re-p")
    e_s = inference_energy_uwh(1.0, "sparkfun-edge")
    print(f"# power ratio nucleo/sparkfun at equal runtime: {e_n/e_s:.2f}x")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
