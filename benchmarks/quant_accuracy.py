"""Paper Figs. 5-10 + Appendix B: accuracy vs filters for float32 / int16-PTQ
/ int8-QAT / int9-PTQ, plus the TFLite-style affine-PTQ baseline the paper
compares against (Sec. 7).

Synthetic datasets stand in for UCI-HAR/SMNIST/GTSRB (offline container);
the claim validated is the *relative* ordering (C1, C2, C4), not absolute
accuracies — see EXPERIMENTS.md §Paper-claims.

``--smoke`` runs :func:`run_frontier` instead: the quality-vs-tok/s frontier
joining this benchmark's accuracy side (weight-only fp32 / int8 / packed
int4-per-block on the smoke task) with ``serve_bench.bench_weight_formats``'s
serving side (tok/s + weight payload bytes per format) into
``benchmarks/out/frontier.json``.  :func:`check_frontier` is the CI gate —
warn-only on the int4-vs-int8 accuracy delta (sub-int8 is the frontier being
*measured*, not a regression bar), hard only on the payload halving.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.policy import QMode, QuantPolicy

from .common import OUT_DIR, accuracy, train_resnet, write_csv

AFFINE_PTQ = QuantPolicy(mode=QMode.EVAL, weight_bits=8, act_bits=8,
                         symmetric=False, power_of_two=False)


def run(quick: bool = True):
    datasets = ["uci-har", "smnist"] if quick else ["uci-har", "smnist",
                                                    "gtsrb"]
    filter_sweep = [8, 16, 24] if quick else [8, 16, 24, 32, 48]
    # "hard" rows push the float model off the accuracy ceiling so the
    # int8/int16 separation (paper C2/C4) is measurable, not saturated
    difficulties = [("easy", 0.0), ("hard", 2.2)]
    iters = 350 if quick else 700
    rows = []
    for ds in datasets:
        for diff_name, noise in difficulties:
            for f in filter_sweep:
                model, params, test = train_resnet(ds, f, iters=iters,
                                                   extra_noise=noise)
                acc_f32 = accuracy(model, params, test)
                acc_i16 = accuracy(model, params, test,
                                   QuantPolicy.int16_ptq())
                acc_i9 = accuracy(model, params, test, QuantPolicy.int9_ptq())
                acc_i8ptq = accuracy(model, params, test, QuantPolicy(
                    mode=QMode.EVAL, weight_bits=8, act_bits=8))
                acc_aff = accuracy(model, params, test, AFFINE_PTQ)
                # QAT fine-tune from the float model (paper Sec. 4.3)
                _, qat_params, _ = train_resnet(
                    ds, f, iters=iters // 2, policy=QuantPolicy.int8_qat(),
                    lr=0.01, init_params=params, extra_noise=noise)
                acc_i8qat = accuracy(model, qat_params, test,
                                     QuantPolicy(mode=QMode.EVAL,
                                                 weight_bits=8, act_bits=8))
                n_params = sum(p.size for p in
                               __import__("jax").tree_util.tree_leaves(params))
                rows.append((ds, diff_name, f, n_params,
                             round(acc_f32, 4), round(acc_i16, 4),
                             round(acc_i8qat, 4), round(acc_i9, 4),
                             round(acc_i8ptq, 4), round(acc_aff, 4)))
    write_csv("quant_accuracy.csv",
              "dataset,difficulty,filters,params,float32,int16_ptq,int8_qat,"
              "int9_ptq,int8_ptq,int8_affine_ptq", rows)
    return rows


def run_frontier(smoke: bool = True, seed: int = 0, out_path: str = None,
                 weight_block: int = 16):
    """Quality-vs-tok/s frontier: fp32 / int8 / packed int4-per-block.

    Accuracy side: the smoke ResNet served through the weight-only paths
    (``integerize_weights_only``; int4 packs kernels per-block).  Serving
    side: ``serve_bench.bench_weight_formats`` on the smoke LM (tok/s,
    kernel payload bytes, determinism).  One artifact so every format lands
    with both numbers, like the paper's accuracy-and-ROM tables.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.integerize import integerize_weights_only
    from repro.models.registry import get_config

    from . import serve_bench

    iters = 250 if smoke else 500
    model, params, test = train_resnet("uci-har", 8, iters=iters,
                                       extra_noise=2.2, seed=seed)
    acc = {
        "fp32": accuracy(model, params, test),
        "int8": accuracy(model, integerize_weights_only(params, bits=8),
                         test),
        "int4": accuracy(model, integerize_weights_only(
            params, bits=4, block_size=weight_block), test),
    }

    cfg = get_config("smollm-135m-smoke")
    lm = cfg.build(dtype=jnp.float32, remat="off")
    lm_params = lm.init(jax.random.PRNGKey(seed))
    serving = serve_bench.bench_weight_formats(
        lm, lm_params, cfg.vocab, smoke=smoke, seed=seed,
        weight_block=weight_block)

    frontier = {"task": {"dataset": "uci-har", "filters": 8, "iters": iters,
                         "weight_block": weight_block,
                         "serve_arch": "smollm-135m-smoke"},
                "formats": {}}
    for name in ("fp32", "int8", "int4"):
        frontier["formats"][name] = {"accuracy": round(acc[name], 4),
                                     **serving[name]}
        f = frontier["formats"][name]
        print(f"frontier/{name:5s} acc {f['accuracy']:.4f} | "
              f"{f['tok_s']:8.1f} tok/s | kernel payload "
              f"{f['kernel_bytes']} B")

    out_path = out_path or os.path.join(OUT_DIR, "frontier.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as fh:
        json.dump(frontier, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {out_path}")
    return frontier


def check_frontier(frontier, *, max_acc_delta: float = 0.05) -> bool:
    """Frontier gate, mirroring the serve_bench ``check_*`` pattern.

    Hard: the packed int4 kernel payload must be <= 0.5x the int8 payload
    (exact for even K — a packing bug shows up here immediately).
    Warn-only: int4 accuracy within ``max_acc_delta`` (5 points) of int8 on
    the smoke task — printed as WARN, never failing the job, because the
    smoke task's sub-int8 headroom is the quantity being charted.
    """
    f = frontier["formats"]
    ok = True
    ratio = f["int4"]["kernel_bytes"] / max(f["int8"]["kernel_bytes"], 1)
    if ratio > 0.5:
        print(f"REGRESSION frontier: int4 kernel payload {ratio:.3f}x int8 "
              f"> 0.5x ({f['int4']['kernel_bytes']} vs "
              f"{f['int8']['kernel_bytes']} B) — packing is broken")
        ok = False
    else:
        print(f"ok frontier payload: int4 kernels {ratio:.3f}x int8 bytes")
    delta = f["int8"]["accuracy"] - f["int4"]["accuracy"]
    if delta > max_acc_delta:
        print(f"WARN (not gated) frontier: int4 accuracy "
              f"{f['int4']['accuracy']:.4f} is {delta:.3f} below int8 "
              f"{f['int8']['accuracy']:.4f} (> {max_acc_delta:.2f})")
    else:
        print(f"ok frontier accuracy: int4 within {delta:.3f} of int8")
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="run the quality-vs-tok/s frontier (fp32/int8/int4) "
                         "and write benchmarks/out/frontier.json")
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        frontier = run_frontier(smoke=True, seed=args.seed,
                                out_path=args.out)
        if not check_frontier(frontier):
            raise SystemExit(1)
        print("quant_accuracy frontier ok")
        return
    run(quick=True)


if __name__ == "__main__":
    main()
