"""Paper Figs. 5-10 + Appendix B: accuracy vs filters for float32 / int16-PTQ
/ int8-QAT / int9-PTQ, plus the TFLite-style affine-PTQ baseline the paper
compares against (Sec. 7).

Synthetic datasets stand in for UCI-HAR/SMNIST/GTSRB (offline container);
the claim validated is the *relative* ordering (C1, C2, C4), not absolute
accuracies — see EXPERIMENTS.md §Paper-claims.
"""
from __future__ import annotations

from repro.core.policy import QMode, QuantPolicy

from .common import accuracy, train_resnet, write_csv

AFFINE_PTQ = QuantPolicy(mode=QMode.EVAL, weight_bits=8, act_bits=8,
                         symmetric=False, power_of_two=False)


def run(quick: bool = True):
    datasets = ["uci-har", "smnist"] if quick else ["uci-har", "smnist",
                                                    "gtsrb"]
    filter_sweep = [8, 16, 24] if quick else [8, 16, 24, 32, 48]
    # "hard" rows push the float model off the accuracy ceiling so the
    # int8/int16 separation (paper C2/C4) is measurable, not saturated
    difficulties = [("easy", 0.0), ("hard", 2.2)]
    iters = 350 if quick else 700
    rows = []
    for ds in datasets:
        for diff_name, noise in difficulties:
            for f in filter_sweep:
                model, params, test = train_resnet(ds, f, iters=iters,
                                                   extra_noise=noise)
                acc_f32 = accuracy(model, params, test)
                acc_i16 = accuracy(model, params, test,
                                   QuantPolicy.int16_ptq())
                acc_i9 = accuracy(model, params, test, QuantPolicy.int9_ptq())
                acc_i8ptq = accuracy(model, params, test, QuantPolicy(
                    mode=QMode.EVAL, weight_bits=8, act_bits=8))
                acc_aff = accuracy(model, params, test, AFFINE_PTQ)
                # QAT fine-tune from the float model (paper Sec. 4.3)
                _, qat_params, _ = train_resnet(
                    ds, f, iters=iters // 2, policy=QuantPolicy.int8_qat(),
                    lr=0.01, init_params=params, extra_noise=noise)
                acc_i8qat = accuracy(model, qat_params, test,
                                     QuantPolicy(mode=QMode.EVAL,
                                                 weight_bits=8, act_bits=8))
                n_params = sum(p.size for p in
                               __import__("jax").tree_util.tree_leaves(params))
                rows.append((ds, diff_name, f, n_params,
                             round(acc_f32, 4), round(acc_i16, 4),
                             round(acc_i8qat, 4), round(acc_i9, 4),
                             round(acc_i8ptq, 4), round(acc_aff, 4)))
    write_csv("quant_accuracy.csv",
              "dataset,difficulty,filters,params,float32,int16_ptq,int8_qat,"
              "int9_ptq,int8_ptq,int8_affine_ptq", rows)
    return rows


def main():
    run(quick=True)


if __name__ == "__main__":
    main()
