"""Roofline reader (deliverable g): dry-run JSONs → three-term table.

Per (arch × shape × mesh × variant) cell:

  compute_s    = HLO_FLOPs/device ÷ peak_FLOP/s     (197 TF bf16 per v5e chip)
  memory_s     = HLO_bytes/device ÷ HBM_bw          (819 GB/s)
  collective_s = wire_bytes/device ÷ link_bw        (50 GB/s/link, 1 link)

HLO numbers use the depth-probe extrapolation (scan bodies are counted once
by XLA's cost model — see dryrun.py).  MODEL_FLOPS = 6·N·D for training
(2·N·D for inference) with N = active params for MoE; the ratio
MODEL_FLOPS/HLO_FLOPs exposes remat/attention/dispatch overhead, and

  roofline_fraction = useful-compute time ÷ dominant-term time
                    = (MODEL_FLOPS/chips/peak) ÷ max(terms)

is the score reported in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import re
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")

PEAK = 197e12
HBM = 819e9
LINK = 50e9


def analytic_flops(r: Dict) -> float:
    """Analytic per-device FLOPs floor for the compute term.

    The depth probes fix the *layer-stack* while-loop undercount, but the
    flash-attention / SSM chunk scans INSIDE a layer are also while loops
    whose bodies cost_analysis counts once.  This supplements HLO flops with
    the closed-form linear + attention counts (remat recompute included for
    train); the compute term uses max(HLO, analytic).
    """
    try:
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "src"))
        from repro.models.registry import get_config

        cfg = get_config(r["arch"])
    except Exception:
        return 0.0
    B, S = r["global_batch"], r["seq_len"]
    n_act = r["active_params"] or r["params"]
    kind = r["kind"]
    tokens = B * (S if kind != "decode" else 1)
    # linear part: fwd 2ND; train adds bwd 4ND + remat-recompute 2ND
    lin = (8 if kind == "train" else 2) * n_act * tokens
    # attention part: scores + out, causal halves the square
    period = len(cfg.layout)
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.layout[i % period] == "a")
    hq, dh = cfg.n_heads, cfg.head_dim
    if kind == "decode":
        attn = n_attn * 4 * B * S * hq * dh
    else:
        fwd = n_attn * 2 * B * S * S * hq * dh   # causal: 4·BS²HD / 2
        attn = fwd * (4 if kind == "train" else 1)
    return (lin + attn) / r["mesh"]["n_chips"]


def analyze_record(r: Dict) -> Dict:
    chips = r["mesh"]["n_chips"]
    ex = r.get("extrapolated") or {}
    flops_dev = ex.get("flops") or r["cost"].get("flops", 0.0)
    bytes_dev = ex.get("bytes accessed") or r["cost"].get("bytes accessed", 0.0)
    wire_dev = ex.get("wire_bytes", r.get("collective_wire_bytes", 0.0))
    # gradient-accumulation variants wrap the step in ANOTHER while loop
    # whose body cost_analysis counts once — scale by the microbatch split
    m = re.search(r"mb(\d+)", r.get("variant", ""))
    if m:
        k = int(m.group(1))
        flops_dev, bytes_dev, wire_dev = (flops_dev * k, bytes_dev * k,
                                          wire_dev * k)
    flops_est = max(flops_dev, analytic_flops(r))
    compute_s = flops_est / PEAK
    memory_s = bytes_dev / HBM
    coll_s = wire_dev / LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)

    n = r["active_params"] if r["active_params"] else r["params"]
    tokens = r["global_batch"] * (r["seq_len"] if r["kind"] != "decode" else 1)
    factor = 6 if r["kind"] == "train" else 2
    model_flops = factor * n * tokens
    model_dev = model_flops / chips
    hlo_ratio = model_dev / flops_est if flops_est else 0.0
    bound = max(terms.values())
    frac = (model_dev / PEAK) / bound if bound else 0.0
    args_gib = r["memory"].get("argument_size_in_bytes", 0) / 2**30
    temp_gib = r["memory"].get("temp_size_in_bytes", 0) / 2**30
    return {
        "arch": r["arch"], "shape": r["shape"], "kind": r["kind"],
        "mesh": "x".join(str(v) for v in r["mesh"]["shape"].values()),
        "variant": r.get("variant", "baseline"), "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant, "model_flops": model_flops,
        "hlo_flops_dev": flops_dev, "useful_ratio": hlo_ratio,
        "roofline_fraction": frac,
        "args_gib": args_gib, "temp_gib": temp_gib,
        "fits_hbm": (args_gib + temp_gib) < 16.0,
    }


def load_all(pattern: str = "*.json") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            r = json.load(f)
        if "error" in r:
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "variant": r.get("variant", "baseline"),
                        "error": r["error"].strip().splitlines()[-1]})
            continue
        out.append(analyze_record(r))
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def run(write: bool = True):
    rows = load_all()
    ok = [r for r in rows if "error" not in r]
    lines = ["| arch | shape | mesh | variant | compute | memory | coll "
             "| dominant | useful | roofline | args GiB | temp GiB | fits |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in ok:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['variant']} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {r['args_gib']:.2f} | {r['temp_gib']:.2f} "
            f"| {'y' if r['fits_hbm'] else 'n'} |")
    err = [r for r in rows if "error" in r]
    for r in err:
        lines.append(f"| {r['arch']} | {r['shape']} | — | {r['variant']} "
                     f"| ERROR: {r['error'][:60]} ||||||||||")
    md = "\n".join(lines)
    if write:
        os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
        with open(OUT_MD, "w") as f:
            f.write(md + "\n")
    print(md)
    print(f"\n# cells: {len(ok)} ok, {len(err)} errors")
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
