"""Shared benchmark utilities: resnet training loop + CSV output."""
from __future__ import annotations

import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.microai_resnet import build_resnet
from repro.core.policy import QuantPolicy
from repro.data.synthetic import make_classification_dataset
from repro.nn.module import Context, eval_context
from repro.optim import multistep_lr, sgd

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def write_csv(name: str, header: str, rows) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    lines = [header] + [",".join(str(x) for x in r) for r in rows]
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"\n# {name}")
    print("\n".join(lines))
    return path


_DATA_CACHE: Dict[Tuple, Tuple] = {}


def dataset(name: str, n_train=1024, n_test=384, seed=0, extra_noise=0.0):
    key = (name, n_train, n_test, seed, extra_noise)
    if key not in _DATA_CACHE:
        _DATA_CACHE[key] = make_classification_dataset(
            name, n_train=n_train, n_test=n_test, seed=seed,
            extra_noise=extra_noise)
    return _DATA_CACHE[key]


def train_resnet(dataset_name: str, filters: int, *, iters: int = 400,
                 policy: Optional[QuantPolicy] = None, lr: float = 0.02,
                 seed: int = 0, init_params=None, batch: int = 64,
                 extra_noise: float = 0.0):
    """Train the paper's ResNetv1-6 (float or QAT) on a synthetic dataset."""
    x_tr, y_tr, x_te, y_te = dataset(dataset_name, extra_noise=extra_noise)
    model = build_resnet(dataset_name, filters=filters)
    params = init_params or model.init(jax.random.PRNGKey(seed))
    opt = sgd(momentum=0.9, weight_decay=5e-4)
    opt_state = opt.init(params)
    sched = multistep_lr(lr, milestones=(iters * 2 // 3, iters * 5 // 6),
                         gamma=0.13)
    policy = policy or QuantPolicy.float32()

    @jax.jit
    def step(params, opt_state, xb, yb, lr):
        def loss_fn(p):
            ctx = Context(policy=policy, train=True)
            logits = model.apply(p, xb, ctx)
            oh = jax.nn.one_hot(yb, logits.shape[-1])
            return -jnp.mean(jnp.sum(oh * jax.nn.log_softmax(logits), -1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, loss

    rng = np.random.default_rng(seed)
    for it in range(iters):
        idx = rng.integers(0, x_tr.shape[0], batch)
        params, opt_state, _ = step(params, opt_state, x_tr[idx], y_tr[idx],
                                    sched(it))
    return model, params, (x_te, y_te)


def accuracy(model, params, test, policy: Optional[QuantPolicy] = None,
             qstate=None) -> float:
    x, y = test
    ctx = eval_context(policy or QuantPolicy.float32(), qstate=qstate)
    logits = model.apply(params, x, ctx)
    if hasattr(logits, "dequantize"):
        logits = logits.dequantize()
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


def timeit(fn, *args, warmup=2, reps=10) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)
