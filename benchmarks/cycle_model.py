"""Paper Tables A4/A6: the Appendix-E integer-ALU cycle model vs the paper's
measured on-device inference times (MicroAI int8/int16, both boards).

The cycle model is exact arithmetic (Table A6 op counts × cycle weights);
the validation (claim C6) is that it reproduces the *shape* of Table A4 —
Pearson r against the measured milliseconds across the filter sweep.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import inference_seconds, resnet6_ops

from .common import write_csv

# Paper Table A4, MicroAI int8 rows (ms per inference), filters 16..80.
PAPER_A4 = {
    ("nucleo-l452re-p", "int8"): [43.003, 107.705, 180.830, 272.986, 383.761,
                                  659.996, 1034.033],
    ("sparkfun-edge", "int8"): [39.417, 101.704, 172.551, 259.830, 375.840,
                                658.441, 1003.365],
    ("nucleo-l452re-p", "int16"): [44.915, 120.308, 205.499, 318.310, 459.880,
                                   796.310, 1223.513],
}
FILTERS = [16, 24, 32, 40, 48, 64, 80]
# UCI-HAR input: 128 samples x 9 channels (paper Sec. 6.1.1)
SAMPLES, CHANNELS = 128, 9


def run():
    rows = []
    model_ms = []
    for f in FILTERS:
        ops = resnet6_ops(f, SAMPLES, CHANNELS)
        sec = inference_seconds(ops, "nucleo-l452re-p")
        model_ms.append(sec * 1e3)
        rows.append((f, ops.macc, ops.add, ops.shift, ops.maxsat, ops.cycles,
                     round(sec * 1e3, 2)))
    write_csv("cycle_model.csv",
              "filters,macc,add,shift,maxsat,cycles,model_ms_nucleo", rows)

    corr_rows = []
    for (board, dtype), meas in PAPER_A4.items():
        r = float(np.corrcoef(model_ms, meas)[0, 1])
        scale = float(np.mean(np.array(meas) / np.array(model_ms)))
        corr_rows.append((board, dtype, round(r, 5), round(scale, 3)))
    write_csv("cycle_model_validation.csv",
              "board,dtype,pearson_r_vs_paper_A4,mean_measured_over_model",
              corr_rows)
    return corr_rows


def main():
    run()


if __name__ == "__main__":
    main()
