"""EncDec (whisper-style) serving through the continuous-batching scheduler:
per-request encoder context threaded through the jitted steps (the PR-4-era
scheduler silently decoded without ``enc``), learned-position decode
offsets, and the guard rails (one-shot admission unsupported, enc required).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config
from repro.nn.module import eval_context
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def whisper():
    cfg = get_config("whisper-tiny-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _encode(model, params, seed, s_enc=6, scale=0.1):
    embeds = scale * jax.random.normal(jax.random.PRNGKey(seed),
                                       (1, s_enc, model.d_model), jnp.float32)
    return model.encode(params, embeds, eval_context())   # (1, S_enc, D)


def test_encdec_chunked_serving_matches_generate(whisper):
    """Two requests with DIFFERENT encoder contexts: the scheduler's chunked
    stream must equal lockstep generate() fed the same per-slot enc rows —
    without enc plumbing each slot decodes against nothing and diverges."""
    cfg, model, params = whisper
    eng = ServeEngine(model=model, params=params, max_len=20, batch_slots=2)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab, size=(2, 5), dtype=np.int32)
    encs = [_encode(model, params, seed) for seed in (10, 20)]
    want = np.asarray(eng.generate(jnp.asarray(prompts), 6,
                                   enc=jnp.concatenate(encs, axis=0)))
    reqs = [Request(rid=i, prompt=prompts[i], max_new=6, enc=encs[i])
            for i in range(2)]
    got, _ = eng.scheduler(chunk_size=3).run(reqs)
    for i in range(2):
        assert got[i].tokens == [int(x) for x in want[i]], i


def test_encdec_enc_actually_matters(whisper):
    """Sanity that the identity test is not vacuous: swapping a request's
    encoder context changes its decoded stream."""
    cfg, model, params = whisper
    eng = ServeEngine(model=model, params=params, max_len=20, batch_slots=1)
    prompt = np.arange(5, dtype=np.int32) + 3
    streams = []
    for seed in (10, 20):
        got, _ = eng.scheduler(chunk_size=3).run(
            [Request(rid=0, prompt=prompt, max_new=8,
                     enc=_encode(model, params, seed, scale=20.0))])
        streams.append(got[0].tokens)
    assert streams[0] != streams[1]


def test_encdec_paged_chunked_matches_dense(whisper):
    """EncDec over the paged decoder cache: same streams as the dense run."""
    cfg, model, params = whisper
    rng = np.random.default_rng(5)
    encs = [_encode(model, params, 30 + i) for i in range(3)]
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=4 + i),
                    max_new=5, arrival=i, enc=encs[i]) for i in range(3)]
    dense = ServeEngine(model=model, params=params, max_len=24,
                        batch_slots=2)
    base, _ = dense.scheduler(chunk_size=4).run(reqs)
    paged = ServeEngine(model=model, params=params, max_len=24,
                        batch_slots=2, paged_kv=True, page_size=8)
    got, _ = paged.scheduler(chunk_size=4).run(reqs)
    for i in range(3):
        assert got[i].tokens == base[i].tokens, i


def test_encdec_decode_positions_advance(whisper):
    """Incremental decode must agree with a one-shot forward: the learned
    position table is offset by the cache's live length (the old code looked
    up position 0 for every generated token)."""
    cfg, model, params = whisper
    toks = (np.arange(7, dtype=np.int32) + 1)[None]
    ctx = eval_context()
    enc = _encode(model, params, 42)
    full_logits, _ = model.apply(params, jnp.asarray(toks), ctx, enc=enc)
    cache = model.init_cache(1, 8, quantized_kv=False, kv_dtype=jnp.float32)
    step_logits = []
    for i in range(7):
        lg, cache = model.apply(params, jnp.asarray(toks[:, i:i + 1]), ctx,
                                cache=cache, decode=True, enc=enc)
        step_logits.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(step_logits, axis=1)),
                               np.asarray(full_logits), rtol=2e-4, atol=2e-4)


def test_encdec_one_shot_admission_raises(whisper):
    """One-shot admission prefills in a scratch cache without the request's
    encoder output or cross-attention K/V — the construction-time error must
    say so and name the chunked remedy."""
    cfg, model, params = whisper
    eng = ServeEngine(model=model, params=params, max_len=16, batch_slots=1)
    with pytest.raises(ValueError, match="chunked admission.*chunk_size"):
        eng.scheduler()                  # no chunk_size = one-shot admission


def test_encdec_requests_require_enc(whisper):
    cfg, model, params = whisper
    eng = ServeEngine(model=model, params=params, max_len=16, batch_slots=1)
    sched = eng.scheduler(chunk_size=3)
    with pytest.raises(ValueError, match="encoder output"):
        sched.run([Request(rid=0, prompt=np.arange(4), max_new=2)])


def test_causal_requests_reject_enc(whisper):
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model=model, params=params, max_len=16, batch_slots=1)
    sched = eng.scheduler(chunk_size=3)
    with pytest.raises(ValueError, match="no encoder"):
        sched.run([Request(rid=0, prompt=np.arange(4), max_new=2,
                           enc=np.zeros((1, 4, 8), np.float32))])
