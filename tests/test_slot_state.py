"""Per-slot decode-state adapters (serve/slot_state.py): SSM/RWKV recurrent
state and EncDec cached cross-attention serve through the same
continuous-batching loop as KV caches, token-identical to their lockstep
baselines; the PagedKVState wrap keeps the paged/shared/oversubscribed
workloads byte-identical to the pre-refactor scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config
from repro.nn.module import eval_context
from repro.serve import (Request, ServeEngine, state_bytes_per_slot,
                         state_kinds)


@pytest.fixture(scope="module")
def mamba_lm():
    cfg = get_config("mamba-130m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def rwkv_lm():
    cfg = get_config("rwkv6-7b-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def whisper():
    cfg = get_config("whisper-tiny-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_len", 32)
    kw.setdefault("batch_slots", 2)
    return ServeEngine(model=model, params=params, **kw)


# --------------------------------------------------------------------------
# state_kinds: the adapter factory sees the right cache taxonomy
# --------------------------------------------------------------------------

def test_state_kinds_by_family(mamba_lm, whisper):
    causal = get_config("smollm-135m-smoke").build(dtype=jnp.float32,
                                                   remat="off")
    assert state_kinds(causal) == ("kv",)
    assert state_kinds(mamba_lm[1]) == ("recurrent",)
    assert state_kinds(whisper[1]) == ("kv", "cross")
    hybrid = get_config("jamba-v0.1-52b-smoke").build(dtype=jnp.float32,
                                                      remat="off")
    assert state_kinds(hybrid) == ("kv", "recurrent")


def test_recurrent_bytes_per_slot_constant_in_length(mamba_lm):
    """The paper-motivating property: SSM decode state is O(1) per slot
    while a transformer's KV cache grows linearly with max_len."""
    cfg, model, params = mamba_lm
    short = model.init_cache(2, 32, per_slot_len=True, kv_dtype=jnp.float32)
    long = model.init_cache(2, 64, per_slot_len=True, kv_dtype=jnp.float32)
    b_short = state_bytes_per_slot(short, 2)
    b_long = state_bytes_per_slot(long, 2)
    assert b_short["kv"] == b_long["kv"] == 0
    assert b_short["recurrent"] == b_long["recurrent"] > 0

    tcfg = get_config("smollm-135m-smoke")
    tmodel = tcfg.build(dtype=jnp.float32, remat="off")
    kv_short = state_bytes_per_slot(
        tmodel.init_cache(2, 32, per_slot_len=True, kv_dtype=jnp.float32), 2)
    kv_long = state_bytes_per_slot(
        tmodel.init_cache(2, 64, per_slot_len=True, kv_dtype=jnp.float32), 2)
    # ~2x (the constant per-slot ``len`` word keeps it just shy of exact)
    assert kv_long["kv"] > 1.9 * kv_short["kv"] > 0
    assert kv_short["recurrent"] == 0


# --------------------------------------------------------------------------
# SSM/RWKV serving: token identity with lockstep generate()
# --------------------------------------------------------------------------

@pytest.mark.parametrize("weight_quant", [False, True], ids=["fp32", "int8w"])
def test_ssm_serving_token_identical_to_lockstep(mamba_lm, weight_quant):
    """A mixed mamba workload (staggered arrivals, more requests than slots)
    through the chunked loop equals per-request lockstep generate()."""
    cfg, model, params = mamba_lm
    eng = _engine(model, params, weight_quant=weight_quant)
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab, size=(4, 8), dtype=np.int32)
    base = np.asarray(
        _engine(model, params, batch_slots=4,
                weight_quant=weight_quant).generate(jnp.asarray(prompts), 6))
    reqs = [Request(rid=i, prompt=prompts[i], max_new=6, arrival=i)
            for i in range(4)]
    results, stats = eng.scheduler(chunk_size=4).run(reqs)
    assert stats.state_kinds == "recurrent"
    for i in range(4):
        assert results[i].status == "ok"
        assert results[i].tokens == [int(x) for x in base[i]], (weight_quant,
                                                                i)


def test_rwkv_serving_token_identical_to_lockstep(rwkv_lm):
    cfg, model, params = rwkv_lm
    eng = _engine(model, params)
    rng = np.random.default_rng(4)
    prompts = rng.integers(0, cfg.vocab, size=(2, 8), dtype=np.int32)
    base = np.asarray(eng.generate(jnp.asarray(prompts), 6))
    reqs = [Request(rid=i, prompt=prompts[i], max_new=6) for i in range(2)]
    results, stats = eng.scheduler(chunk_size=4).run(reqs)
    assert stats.state_kinds == "recurrent"
    for i in range(2):
        assert results[i].tokens == [int(x) for x in base[i]], i


def test_ssm_one_shot_admission_matches_chunked(mamba_lm):
    """One-shot (stop-the-world batch-1 prefill) admission carries the
    recurrence through ``_slot_prefill`` + the scatter-admission walker."""
    cfg, model, params = mamba_lm
    eng = _engine(model, params)
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=6 + i),
                    max_new=5) for i in range(3)]
    chunked, _ = eng.scheduler(chunk_size=3).run(reqs)
    one_shot, _ = eng.scheduler().run(reqs)
    for i in range(3):
        assert one_shot[i].tokens == chunked[i].tokens, i


def test_ssm_eos_evicts_and_readmits(mamba_lm):
    """EOS eviction zeroes the slot's recurrent rows; the readmitted request
    must decode from fresh state, not the dead occupant's."""
    cfg, model, params = mamba_lm
    eng = _engine(model, params, batch_slots=1)
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab
    free_run, _ = eng.scheduler(chunk_size=3).run(
        [Request(rid=0, prompt=prompt, max_new=8)])
    eos = free_run[0].tokens[2]
    solo, _ = eng.scheduler(chunk_size=3).run(
        [Request(rid=1, prompt=prompt + 1, max_new=3)])

    reqs = [Request(rid=0, prompt=prompt, max_new=8),
            Request(rid=1, prompt=prompt + 1, max_new=3)]
    results, _ = eng.scheduler(eos_id=eos, chunk_size=3, audit=True).run(reqs)
    assert results[0].eos is True and results[0].tokens[-1] == eos
    assert len(results[0].tokens) <= 3
    assert results[1].admitted_at >= results[0].finished_at
    # the slot's state was wiped between occupants: request 1's stream is
    # exactly its solo stream
    assert results[1].tokens == solo[1].tokens


def test_ssm_forced_preemption_recompute_identity(mamba_lm):
    """The ``preempts=`` drill mid-decode: the victim's recurrence is
    discarded, its continuation re-prefills prompt+tokens from zeros, and
    under greedy decoding the stream is unchanged."""
    cfg, model, params = mamba_lm
    eng = _engine(model, params)
    rng = np.random.default_rng(9)
    prompts = rng.integers(0, cfg.vocab, size=(2, 8), dtype=np.int32)
    base = np.asarray(eng.generate(jnp.asarray(prompts), 8))
    reqs = [Request(rid=i, prompt=prompts[i], max_new=8) for i in range(2)]
    results, stats = eng.scheduler(chunk_size=4, audit=True).run(
        reqs, preempts={0: 6})
    assert stats.preemptions >= 1
    assert stats.preempted_rids.get(0, 0) >= 1
    for i in range(2):
        assert results[i].status == "ok"
        assert results[i].tokens == [int(x) for x in base[i]], i
    assert stats.audited_ticks > 0


# --------------------------------------------------------------------------
# Unsupported recurrent combinations fail loudly at construction
# --------------------------------------------------------------------------

def test_recurrent_validation_ladder(mamba_lm):
    cfg, model, params = mamba_lm
    eng = _engine(model, params)
    with pytest.raises(ValueError, match="ragged"):
        eng.scheduler(chunk_size=4, ragged=True)
    with pytest.raises(ValueError, match="prompt_bucket"):
        eng.scheduler(prompt_bucket=8)
    paged = _engine(model, params, paged_kv=True, page_size=8)
    with pytest.raises(ValueError, match="paged"):
        paged.scheduler(chunk_size=4)


# --------------------------------------------------------------------------
# EncDec: cached cross-attention K/V == per-step recomputation
# --------------------------------------------------------------------------

def _encode(model, params, seed, s_enc=6):
    embeds = 0.1 * jax.random.normal(jax.random.PRNGKey(seed),
                                     (1, s_enc, model.d_model), jnp.float32)
    return model.encode(params, embeds, eval_context())


def test_encdec_cached_cross_logits_identical(whisper):
    """Decode-step logits with the admission-time xkv cache equal the
    recompute-from-enc path bit-for-bit shape-for-shape (same projections,
    applied once vs every step)."""
    cfg, model, params = whisper
    ctx = eval_context()
    encs = [_encode(model, params, seed) for seed in (11, 22)]
    enc = jnp.concatenate(encs, axis=0)
    kw = dict(quantized_kv=False, kv_dtype=jnp.float32, per_slot_len=True)
    cached = model.init_cache(2, 16, cross_attn_cache=True, **kw)
    plain = model.init_cache(2, 16, cross_attn_cache=False, **kw)
    for slot in range(2):
        cached = model.write_cross_kv(params, cached, encs[slot],
                                      jnp.int32(slot), ctx)
    toks = (np.arange(2 * 5, dtype=np.int32).reshape(2, 5) * 3) % cfg.vocab
    for i in range(5):
        step = jnp.asarray(toks[:, i:i + 1])
        lg_c, cached = model.apply(params, step, ctx, cache=cached,
                                   decode=True, enc=enc)
        lg_p, plain = model.apply(params, step, ctx, cache=plain,
                                  decode=True, enc=enc)
        np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_p),
                                   rtol=1e-5, atol=1e-5)


def test_encdec_serving_identical_with_and_without_cache(whisper):
    """The served token streams agree across ``cross_attn_cache`` on/off —
    the cache is a FLOPs cut, not a semantics change."""
    cfg, model, params = whisper
    rng = np.random.default_rng(5)
    encs = [_encode(model, params, 30 + i) for i in range(3)]
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=4 + i),
                    max_new=5, arrival=i, enc=encs[i]) for i in range(3)]
    on = _engine(model, params, max_len=24)
    off = _engine(model, params, max_len=24, cross_attn_cache=False)
    got_on, st_on = on.scheduler(chunk_size=4).run(reqs)
    got_off, st_off = off.scheduler(chunk_size=4).run(reqs)
    assert st_on.state_kinds == "kv+cross"
    assert st_off.state_kinds == "kv"
    for i in range(3):
        assert got_on[i].tokens == got_off[i].tokens, i


def test_encdec_cached_audit_clean(whisper):
    """audit=True drives check_cross_lens every tick over live + lane slots."""
    cfg, model, params = whisper
    rng = np.random.default_rng(6)
    encs = [_encode(model, params, 40 + i, s_enc=5) for i in range(3)]
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=5),
                    max_new=4, arrival=i, enc=encs[i]) for i in range(3)]
    eng = _engine(model, params, max_len=24)
    got, stats = eng.scheduler(chunk_size=3, audit=True).run(reqs)
    assert stats.audited_ticks > 0
    assert all(got[i].status == "ok" for i in range(3))


# --------------------------------------------------------------------------
# PagedKVState: the mechanical wrap keeps the paged workloads identical
# --------------------------------------------------------------------------

def test_paged_shared_oversubscribed_identity():
    """Shared-prefix + oversubscribed paged serving (the pre-refactor
    oracle workload) still equals the dense chunked run token-for-token."""
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab, size=8, dtype=np.int32)
    reqs = []
    for i in range(4):
        tail = rng.integers(0, cfg.vocab, size=4, dtype=np.int32)
        reqs.append(Request(rid=i, prompt=np.concatenate([shared, tail]),
                            max_new=6, arrival=i))
    dense = ServeEngine(model=model, params=params, max_len=32,
                        batch_slots=2)
    base, _ = dense.scheduler(chunk_size=4).run(reqs)
    paged = ServeEngine(model=model, params=params, max_len=32,
                        batch_slots=2, paged_kv=True, page_size=4,
                        kv_pool_pages=12)
    got, stats = paged.scheduler(chunk_size=4, oversubscribe=True,
                                 audit=True).run(reqs)
    assert stats.state_kinds == "kv"
    for i in range(4):
        assert got[i].status == "ok"
        assert got[i].tokens == base[i].tokens, i
    assert stats.prefix_hits > 0
    assert stats.audited_ticks > 0
