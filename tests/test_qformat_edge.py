"""Deterministic edge-case tests for the Qm.n core (``core/qformat``).

These pin — without hypothesis, which is an optional dev dependency — the
exact corner behaviours the property suite covers statistically: all-zero
tensors, negative fractional-bit exponents, int9 logical width in int16
containers, and the requantize left-shift pre-saturation rule (the bug the
``requantize`` docstring records hypothesis once catching).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import qformat
from repro.core.qformat import QTensor


# ---- all-zero tensors ------------------------------------------------------


def test_all_zero_tensor_clamps_exponent_and_roundtrips():
    x = jnp.zeros((4, 4))
    n = qformat.frac_bits_for(qformat.max_abs(x), 8)
    # max|x| == 0 drives m to a large negative value; the clamp catches it
    assert int(n) == qformat.N_MAX
    qt = qformat.quantize_tensor(x, 8)
    np.testing.assert_array_equal(np.asarray(qt.q), np.zeros((4, 4), np.int8))
    np.testing.assert_array_equal(np.asarray(qt.dequantize()), np.zeros((4, 4)))


def test_all_zero_per_channel_column():
    # one all-zero channel must not poison its neighbours' exponents
    x = jnp.array([[0.0, 4.0], [0.0, -4.0]])
    qt = qformat.quantize_tensor(x, 8, channel_axis=1)
    assert int(qt.n[0]) == qformat.N_MAX
    np.testing.assert_array_equal(np.asarray(qt.dequantize()), np.asarray(x))


# ---- negative n (ranges beyond the integer width) --------------------------


def test_negative_n_for_large_ranges():
    # max|x| = 3e5 needs m = 19 integer bits => n = 8 - 19 - 1 = -12
    n = qformat.frac_bits_for(jnp.float32(3e5), 8)
    assert int(n) == -12
    x = jnp.array([40960.0, -12288.0])
    q = qformat.quantize(x, n, 8)
    np.testing.assert_array_equal(np.asarray(q), [10, -3])
    # multiples of 2^12 round-trip exactly even at negative n
    np.testing.assert_array_equal(np.asarray(qformat.dequantize(q, n)),
                                  np.asarray(x))


def test_negative_n_scale_is_power_of_two():
    assert float(qformat.scale_from_n(jnp.int32(-3))) == 8.0
    assert float(qformat.scale_from_n(jnp.int32(5))) == 1.0 / 32.0


# ---- int9 (paper Appendix B) storage ---------------------------------------


def test_int9_stored_in_int16_container():
    assert qformat.storage_dtype(9) == jnp.int16
    assert qformat.accumulator_dtype(9) == jnp.int32
    assert qformat.qmax(9) == 255 and qformat.qmin(9) == -256
    x = jnp.linspace(-1.0, 1.0, 7)
    qt = qformat.quantize_tensor(x, 9)
    assert qt.q.dtype == jnp.int16
    # int9 quantization really uses the 9-bit range, not the int8 one
    assert int(jnp.max(jnp.abs(qt.q))) > 127


def test_rom_bytes_count_logical_width():
    ones = jnp.ones((4, 8))
    assert qformat.quantize_tensor(ones, 8).nbytes_model == 32
    assert qformat.quantize_tensor(ones, 9).nbytes_model == 32 * 9 // 8
    assert qformat.quantize_tensor(ones, 16).nbytes_model == 64


# ---- requantize: shifts, floor semantics, pre-saturation -------------------


def test_requantize_right_shift_floors():
    # arithmetic right shift floors toward -inf (documented engine semantics)
    got = qformat.requantize(jnp.int32(-5), jnp.int32(1), jnp.int32(0), 8)
    assert int(got) == -3
    got = qformat.requantize(jnp.int32(5), jnp.int32(1), jnp.int32(0), 8)
    assert int(got) == 2


def test_requantize_left_shift_saturates_before_overflow():
    """n_out > n_in left-shifts the accumulator; the result must saturate as
    if computed at infinite precision, even when the shifted value would
    overflow the accumulator container (the hypothesis-found bug)."""
    # small shift, still out of int8 range -> qmax
    assert int(qformat.requantize(jnp.int32(1000), jnp.int32(0),
                                  jnp.int32(4), 8)) == 127
    assert int(qformat.requantize(jnp.int32(-1000), jnp.int32(0),
                                  jnp.int32(4), 8)) == -128
    # huge shift: 2^30 << 30 wraps any fixed-width container; the
    # pre-saturation guard (compare against qmax >> lshift) must win
    assert int(qformat.requantize(jnp.int32(2 ** 30), jnp.int32(0),
                                  jnp.int32(30), 8)) == 127
    assert int(qformat.requantize(jnp.int32(-(2 ** 30)), jnp.int32(0),
                                  jnp.int32(30), 8)) == -128


def test_requantize_left_shift_exact_when_in_range():
    # in-range left shifts are exact bit shifts
    got = qformat.requantize(jnp.int32(3), jnp.int32(0), jnp.int32(4), 8)
    assert int(got) == 48
    got = qformat.requantize(jnp.int32(-7), jnp.int32(2), jnp.int32(4), 16)
    assert int(got) == -28


def test_requantize_identity_when_formats_match():
    acc = jnp.arange(-8, 8, dtype=jnp.int32)
    got = qformat.requantize(acc, jnp.int32(5), jnp.int32(5), 8)
    np.testing.assert_array_equal(np.asarray(got), np.arange(-8, 8))


def test_align_then_requantize_roundtrip():
    # align to a finer common grid, shift back: exact for in-range values
    q = jnp.array([-3, 0, 7], dtype=jnp.int8)
    acc = qformat.align(q, jnp.int32(4), jnp.int32(8))
    np.testing.assert_array_equal(np.asarray(acc), [-48, 0, 112])
    back = qformat.requantize(acc, jnp.int32(8), jnp.int32(4), 8)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_qtensor_pytree_roundtrip():
    import jax

    qt = qformat.quantize_tensor(jnp.ones((2, 3)), 8, channel_axis=1)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert len(leaves) == 2  # q + n
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, QTensor)
    assert back.width == 8 and back.channel_axis == 1
