"""Flash attention (custom VJP) vs dense reference: fwd + grads, GQA,
offsets, cache-length masking, decode path + int8 kernel dispatch,
chunk-append cache API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import decode_attention, flash_attention


def ref_attn(q, k, v, causal, q_offset=0, kv_len=None):
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, sq, hkv, g, d).astype(jnp.float32) / np.sqrt(d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    kpos = jnp.arange(skv)
    qpos = q_offset + jnp.arange(sq)
    mask = kpos[None, :] < (kv_len if kv_len is not None else skv)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)


CASES = [
    # sq, skv, hq, hkv, causal, q_offset, kv_len
    (128, 128, 8, 2, True, 0, None),
    (100, 100, 4, 4, True, 0, None),          # non-block-multiple seq
    (64, 200, 8, 4, True, 100, 164),          # prefill into cache
    (37, 256, 6, 3, False, 0, 200),           # cross-attention style
    (256, 64, 4, 1, True, 0, None),           # long q, short kv (MQA)
]


@pytest.mark.parametrize("sq,skv,hq,hkv,causal,qoff,kvlen", CASES)
def test_flash_forward_matches_ref(sq, skv, hq, hkv, causal, qoff, kvlen):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, sq, hq, 64))
    k = jax.random.normal(ks[1], (2, skv, hkv, 64))
    v = jax.random.normal(ks[2], (2, skv, hkv, 64))
    kvl = jnp.int32(kvlen if kvlen is not None else skv)
    got = flash_attention(q, k, v, jnp.int32(qoff), kvl, causal, 32, 64)
    want = ref_attn(q, k, v, causal, qoff, kvlen)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("sq,skv,hq,hkv,causal,qoff,kvlen", CASES)
def test_flash_gradients_match_ref(sq, skv, hq, hkv, causal, qoff, kvlen):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, sq, hq, 64))
    k = jax.random.normal(ks[1], (2, skv, hkv, 64))
    v = jax.random.normal(ks[2], (2, skv, hkv, 64))
    kvl = jnp.int32(kvlen if kvlen is not None else skv)

    def f1(q, k, v):
        return jnp.sum(jnp.square(
            flash_attention(q, k, v, jnp.int32(qoff), kvl, causal, 32, 64)))

    def f2(q, k, v):
        return jnp.sum(jnp.square(ref_attn(q, k, v, causal, qoff, kvlen)))

    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=3e-3, atol=3e-4)


def test_decode_attention_matches_ref_float_and_int8():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, hq, hkv, d, s, kv_len = 2, 8, 2, 64, 128, 100
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    got = decode_attention(q, k, v, jnp.int32(kv_len))
    want = ref_attn(q, k, v, False, kv_len - 1, kv_len)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    # int8 cache on the paper grid: matches dequantized-float attention
    from repro.core import qformat

    n = jnp.int32(4)
    kq, vq = qformat.quantize(k, n, 8), qformat.quantize(v, n, 8)
    got8 = decode_attention(q, kq, vq, jnp.int32(kv_len), k_n=n, v_n=n)
    want8 = ref_attn(q, qformat.dequantize(kq, n), qformat.dequantize(vq, n),
                     False, kv_len - 1, kv_len)
    np.testing.assert_allclose(got8, want8, rtol=2e-4, atol=2e-5)


def test_flash_bwd_memory_is_flat_in_seq():
    """The custom VJP's residuals are O(S·D), not O(S²) — check by jaxpr:
    no (…, S, S)-shaped residual crosses the custom_vjp boundary."""
    b, hq, hkv, d, s = 1, 4, 2, 32, 512

    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, jnp.int32(0), jnp.int32(s),
                                       True, 128, 128))

    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    jaxpr = jax.make_jaxpr(jax.grad(f, (0, 1, 2)))(q, k, v)
    # scan for any residual-sized (S,S) arrays in the top-level eqn outputs
    big = s * s
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            shape = getattr(var.aval, "shape", ())
            if len(shape) >= 2:
                assert shape[-1] * shape[-2] < big * 0.9, (eqn.primitive, shape)


# --------------------------------------------------------------------------
# int8 decode dispatch + the chunk-append cache API
# --------------------------------------------------------------------------

def test_decode_attention_int8_routes_to_kernel(monkeypatch):
    """int8 caches dispatch to kernels.ops.qdecode_attn (never the
    dequantize-everything einsum) unless the run is sharded; float caches
    keep the einsum path."""
    from repro.kernels import ops as kops

    calls = []
    real = kops.qdecode_attn

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(kops, "qdecode_attn", spy)
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 16))
    k8 = jax.random.randint(ks[1], (2, 32, 2, 16), -100, 100).astype(jnp.int8)
    v8 = jax.random.randint(ks[2], (2, 32, 2, 16), -100, 100).astype(jnp.int8)
    n = jnp.int32(4)

    out = decode_attention(q, k8, v8, jnp.int32(20), k_n=n, v_n=n)
    assert calls == [1]
    # sharded decode keeps the einsum path (partitioner-friendly) and agrees
    out_sharded = decode_attention(q, k8, v8, jnp.int32(20), k_n=n, v_n=n,
                                   sharded=True)
    assert calls == [1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_sharded),
                               rtol=1e-5, atol=1e-5)
    # float caches never touch the int8 kernel
    decode_attention(q, jax.random.normal(ks[1], (2, 32, 2, 16)),
                     jax.random.normal(ks[2], (2, 32, 2, 16)), jnp.int32(20))
    assert calls == [1]


@pytest.mark.parametrize("quantized", [False, True], ids=["float", "int8"])
def test_append_kv_chunk_writes_one_slot_absolute_len(quantized):
    from repro.nn.attention import KVChunk, append_kv_chunk, init_kv_cache

    cache = init_kv_cache(3, 12, 2, 4, quantized=quantized,
                          dtype=jnp.float32, per_slot_len=True)
    # slot 1 mid-prefill at row 4; a masked decode step junk-bumped its len
    cache["len"] = jnp.asarray([2, 5, 0], jnp.int32)
    k_new = jnp.ones((1, 4, 2, 4)) * 3.0
    chunk = KVChunk(slot=jnp.int32(1), start=jnp.int32(4),
                    length=jnp.int32(2))       # partial last chunk
    out = append_kv_chunk(cache, k_new, k_new, chunk)
    # absolute length: start + valid, junk bump overwritten
    np.testing.assert_array_equal(np.asarray(out["len"]), [2, 6, 0])
    kf = np.asarray(out["k"], np.float32)
    assert (kf[1, 4:8] != 0).all()            # chunk rows written
    assert (kf[1, :4] == 0).all()             # prefix untouched
    assert (kf[0] == 0).all() and (kf[2] == 0).all()   # other slots untouched


def test_chunk_attention_matches_flash_prefill():
    """A full-prompt 'chunk' with empty prefix equals plain causal
    attention — chunk_attention's masking (pos <= start + c) is exactly the
    one-shot causal rule."""
    from repro.nn.attention import (KVChunk, append_kv_chunk,
                                    chunk_attention, init_kv_cache)

    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    c, hq, hkv, d = 6, 4, 2, 16
    q = jax.random.normal(ks[0], (1, c, hq, d))
    k = jax.random.normal(ks[1], (1, c, hkv, d))
    v = jax.random.normal(ks[2], (1, c, hkv, d))
    cache = init_kv_cache(2, 8, hkv, d, quantized=False,
                          dtype=jnp.float32, per_slot_len=True)
    chunk = KVChunk(slot=jnp.int32(1), start=jnp.int32(0), length=jnp.int32(c))
    got = chunk_attention(q, append_kv_chunk(cache, k, v, chunk),
                          jnp.int32(1), jnp.int32(0))
    want = ref_attn(q, k, v, True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
