"""Flash attention (custom VJP) vs dense reference: fwd + grads, GQA,
offsets, cache-length masking, decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import decode_attention, flash_attention


def ref_attn(q, k, v, causal, q_offset=0, kv_len=None):
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, sq, hkv, g, d).astype(jnp.float32) / np.sqrt(d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    kpos = jnp.arange(skv)
    qpos = q_offset + jnp.arange(sq)
    mask = kpos[None, :] < (kv_len if kv_len is not None else skv)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)


CASES = [
    # sq, skv, hq, hkv, causal, q_offset, kv_len
    (128, 128, 8, 2, True, 0, None),
    (100, 100, 4, 4, True, 0, None),          # non-block-multiple seq
    (64, 200, 8, 4, True, 100, 164),          # prefill into cache
    (37, 256, 6, 3, False, 0, 200),           # cross-attention style
    (256, 64, 4, 1, True, 0, None),           # long q, short kv (MQA)
]


@pytest.mark.parametrize("sq,skv,hq,hkv,causal,qoff,kvlen", CASES)
def test_flash_forward_matches_ref(sq, skv, hq, hkv, causal, qoff, kvlen):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, sq, hq, 64))
    k = jax.random.normal(ks[1], (2, skv, hkv, 64))
    v = jax.random.normal(ks[2], (2, skv, hkv, 64))
    kvl = jnp.int32(kvlen if kvlen is not None else skv)
    got = flash_attention(q, k, v, jnp.int32(qoff), kvl, causal, 32, 64)
    want = ref_attn(q, k, v, causal, qoff, kvlen)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("sq,skv,hq,hkv,causal,qoff,kvlen", CASES)
def test_flash_gradients_match_ref(sq, skv, hq, hkv, causal, qoff, kvlen):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, sq, hq, 64))
    k = jax.random.normal(ks[1], (2, skv, hkv, 64))
    v = jax.random.normal(ks[2], (2, skv, hkv, 64))
    kvl = jnp.int32(kvlen if kvlen is not None else skv)

    def f1(q, k, v):
        return jnp.sum(jnp.square(
            flash_attention(q, k, v, jnp.int32(qoff), kvl, causal, 32, 64)))

    def f2(q, k, v):
        return jnp.sum(jnp.square(ref_attn(q, k, v, causal, qoff, kvlen)))

    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=3e-3, atol=3e-4)


def test_decode_attention_matches_ref_float_and_int8():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, hq, hkv, d, s, kv_len = 2, 8, 2, 64, 128, 100
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    got = decode_attention(q, k, v, jnp.int32(kv_len))
    want = ref_attn(q, k, v, False, kv_len - 1, kv_len)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    # int8 cache on the paper grid: matches dequantized-float attention
    from repro.core import qformat

    n = jnp.int32(4)
    kq, vq = qformat.quantize(k, n, 8), qformat.quantize(v, n, 8)
    got8 = decode_attention(q, kq, vq, jnp.int32(kv_len), k_n=n, v_n=n)
    want8 = ref_attn(q, qformat.dequantize(kq, n), qformat.dequantize(vq, n),
                     False, kv_len - 1, kv_len)
    np.testing.assert_allclose(got8, want8, rtol=2e-4, atol=2e-5)


def test_flash_bwd_memory_is_flat_in_seq():
    """The custom VJP's residuals are O(S·D), not O(S²) — check by jaxpr:
    no (…, S, S)-shaped residual crosses the custom_vjp boundary."""
    b, hq, hkv, d, s = 1, 4, 2, 32, 512

    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, jnp.int32(0), jnp.int32(s),
                                       True, 128, 128))

    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    jaxpr = jax.make_jaxpr(jax.grad(f, (0, 1, 2)))(q, k, v)
    # scan for any residual-sized (S,S) arrays in the top-level eqn outputs
    big = s * s
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            shape = getattr(var.aval, "shape", ())
            if len(shape) >= 2:
                assert shape[-1] * shape[-2] < big * 0.9, (eqn.primitive, shape)
