"""Property-based tests (hypothesis) for the quantization core's invariants.

These pin down the *mathematical contract* of the paper's scheme (Eqs. 1-4 +
Sec. 5.8 integer arithmetic) over adversarial inputs, not just examples.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'dev' extra (pip install -e .[dev])")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import qformat
from repro.core.quantizers import fake_quant

jax.config.update("jax_enable_x64", False)

finite_floats = st.floats(min_value=-1e4, max_value=1e4,
                          allow_nan=False, allow_infinity=False, width=32)
small_arrays = hnp.arrays(np.float32, hnp.array_shapes(max_dims=2,
                                                       max_side=16),
                          elements=finite_floats)
widths = st.sampled_from([8, 9, 16])


@settings(max_examples=60, deadline=None)
@given(small_arrays, widths)
def test_quantize_dequantize_error_bound(x, width):
    """|x − dq(q(x))| ≤ 2⁻ⁿ for every in-range element (truncation ≤ 1 step)."""
    n = qformat.frac_bits_for(qformat.max_abs(jnp.asarray(x)), width)
    q = qformat.quantize(jnp.asarray(x), n, width)
    back = np.asarray(qformat.dequantize(q, n))
    step = float(2.0 ** -int(n))
    in_range = np.abs(x) * 2.0 ** int(n) <= qformat.qmax(width)
    err = np.abs(x - back)
    assert np.all(err[in_range] <= step * (1 + 1e-5)), err.max()


@settings(max_examples=60, deadline=None)
@given(small_arrays, widths)
def test_no_overflow_at_derived_exponent(x, width):
    """Eq. 1-2's exponent never saturates the max element (paper's whole
    point: represent the full range)."""
    xa = jnp.asarray(x)
    ma = float(qformat.max_abs(xa))
    if ma == 0 or ma < 2.0 ** -(qformat.N_MAX - 1):
        return
    n = qformat.frac_bits_for(qformat.max_abs(xa), width)
    scaled = np.abs(x).max() * 2.0 ** int(n)
    # the max element must fit in the integer range (with trunc, strictly)
    assert scaled <= qformat.qmax(width) + 1


@settings(max_examples=60, deadline=None)
@given(small_arrays, widths)
def test_fake_quant_idempotent(x, width):
    """Fake-quant is a projection: applying it twice = once (same grid)."""
    xa = jnp.asarray(x)
    n = qformat.frac_bits_for(qformat.max_abs(xa), width)
    y1 = np.asarray(qformat.quantize_dequantize(xa, n, width))
    y2 = np.asarray(qformat.quantize_dequantize(jnp.asarray(y1), n, width))
    np.testing.assert_allclose(y1, y2, rtol=0, atol=0)


@settings(max_examples=40, deadline=None)
@given(hnp.arrays(np.int32, (8,), elements=st.integers(-2**20, 2**20)),
       st.integers(-8, 8), st.integers(-8, 8), st.sampled_from([8, 16]))
def test_requantize_matches_float_semantics(acc, n_in, n_out, width):
    """Integer shift requant == float rescale + trunc-toward-neg-inf + sat.

    (Arithmetic right shift floors — the documented engine semantics.)
    """
    got = np.asarray(qformat.requantize(jnp.asarray(acc), jnp.int32(n_in),
                                        jnp.int32(n_out), width))
    shift = n_in - n_out
    if shift >= 0:
        want = np.floor(acc / 2.0 ** shift)
    else:
        want = acc * 2.0 ** (-shift)
    want = np.clip(want, qformat.qmin(width), qformat.qmax(width))
    np.testing.assert_array_equal(got, want.astype(got.dtype))


@settings(max_examples=40, deadline=None)
@given(hnp.arrays(np.int8, (16,), elements=st.integers(-128, 127)),
       st.integers(-4, 10), st.integers(-4, 10))
def test_align_is_exact_left_shift(q, n_x, n_common):
    """Aligning to more fractional bits is exact (information-preserving)."""
    if n_common < n_x:
        return
    out = np.asarray(qformat.align(jnp.asarray(q), jnp.int32(n_x),
                                   jnp.int32(n_common)))
    np.testing.assert_array_equal(out, q.astype(np.int64) * 2 ** (n_common - n_x))


@settings(max_examples=30, deadline=None)
@given(small_arrays)
def test_ste_gradient_is_identity(x):
    """QAT backward: d(fake_quant)/dx == 1 elementwise (paper Sec. 4.3)."""
    xa = jnp.asarray(x)
    n = jnp.int32(5)
    g = jax.grad(lambda t: jnp.sum(fake_quant(t, n, 8)))(xa)
    np.testing.assert_array_equal(np.asarray(g), np.ones_like(x))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16))
def test_qtensor_rom_bytes(rows, cols):
    """Table A3 semantics: logical-width bytes, not container bytes (int9!)."""
    x = jnp.ones((rows, cols))
    t8 = qformat.quantize_tensor(x, 8)
    t9 = qformat.quantize_tensor(x, 9)
    t16 = qformat.quantize_tensor(x, 16)
    assert t8.nbytes_model == rows * cols
    assert t9.nbytes_model == rows * cols * 9 // 8   # int9 logical packing
    assert t16.nbytes_model == rows * cols * 2


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 1024)),
                min_size=2, max_size=12))
def test_pool_allocator_no_conflicts(layers):
    """The paper's RAM-pool allocator never places a layer's output over its
    own input, and total RAM ≥ the largest single buffer."""
    from repro.core.cost_model import PoolAllocator

    graph = []
    prev = None
    for i, (_, nbytes) in enumerate(layers):
        graph.append({"name": f"l{i}", "inputs": [prev] if prev else [],
                      "bytes": nbytes})
        prev = f"l{i}"
    alloc = PoolAllocator()
    total = alloc.allocate(graph)
    assert total >= max(b for _, b in layers)
    assert len(alloc.pools) >= 2 or len(layers) < 2


@settings(max_examples=40, deadline=None)
@given(hnp.arrays(np.float32, (4, 8), elements=finite_floats))
def test_per_channel_beats_or_ties_per_tensor(x):
    """Per-channel exponents (beyond-paper) never increase quantization MSE."""
    xa = jnp.asarray(x)
    pt = qformat.quantize_tensor(xa, 8)
    pc = qformat.quantize_tensor(xa, 8, channel_axis=1)
    mse_t = float(jnp.mean(jnp.square(xa - pt.dequantize())))
    mse_c = float(jnp.mean(jnp.square(xa - pc.dequantize())))
    assert mse_c <= mse_t * (1 + 1e-4) + 1e-12
