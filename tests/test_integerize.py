"""Unit tests for ``core/integerize``: ROM accounting (paper Table A3),
entry-point input quantization (Sec. 5.6) and the skip rules that keep
precision-sensitive leaves (norms, router) in float."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import integerize, qformat
from repro.core.integerize import (_is_skipped, integerize as integerize_tree,
                                   model_rom_bytes, quantize_input)
from repro.core.policy import QuantPolicy
from repro.core.qformat import QTensor


# ---- model_rom_bytes: Table A3 semantics -----------------------------------


def test_rom_bytes_int8_plus_exponent():
    params = {"l": {"kernel": qformat.quantize_tensor(jnp.ones((4, 8)), 8)}}
    # 32 weights at logical 8-bit + 4 bytes of exponent storage
    assert model_rom_bytes(params) == 4 * 8 + 4


def test_rom_bytes_int9_logical_not_container():
    params = {"l": {"kernel": qformat.quantize_tensor(jnp.ones((4, 8)), 9)}}
    # int9 counts 9 bits/weight (packed), NOT the 16-bit storage container
    assert model_rom_bytes(params) == 4 * 8 * 9 // 8 + 4


def test_rom_bytes_mixed_tree_counts_float_leaves_at_itemsize():
    params = {
        "dense": {"kernel": qformat.quantize_tensor(jnp.ones((4, 8)), 8)},
        "norm": {"scale": jnp.ones((8,), jnp.float32)},
    }
    assert model_rom_bytes(params) == (4 * 8 + 4) + 8 * 4


# ---- quantize_input (Sec. 5.6 entry-point conversion) ----------------------


def test_quantize_input_roundtrip_on_grid():
    qstate = {"in": 5}
    x = jnp.array([0.5, -1.25, 3.96875, 0.0])  # multiples of 2^-5
    qt = quantize_input(x, qstate, "in", 8)
    assert isinstance(qt, QTensor)
    assert qt.q.dtype == jnp.int8 and int(qt.n) == 5
    np.testing.assert_array_equal(np.asarray(qt.dequantize()), np.asarray(x))


def test_quantize_input_saturates_out_of_range():
    qt = quantize_input(jnp.array([100.0, -100.0]), {"in": 5}, "in", 8)
    np.testing.assert_array_equal(np.asarray(qt.q), [127, -128])


def test_quantize_input_missing_site_raises():
    with pytest.raises(KeyError):
        quantize_input(jnp.ones(3), {}, "absent", 8)


# ---- _is_skipped: norms and router stay float ------------------------------


@pytest.mark.parametrize("path,skipped", [
    ("block/norm1/scale", True),
    ("stack/ln_f/scale", True),
    ("block/rms_in/scale", True),
    ("moe/router/kernel", True),
    ("mixer/ssm/a_log", True),
    ("attn/wq/kernel", False),
    ("ffn/w_gate/kernel", False),
    ("embed/table", False),
])
def test_is_skipped_paths(path, skipped):
    assert _is_skipped(path, QuantPolicy.int8_qat()) is skipped


def test_integerize_keeps_norms_float_and_bakes_n_out():
    params = {
        "dense": {"kernel": jnp.ones((4, 4)) * 0.5, "bias": jnp.ones((4,))},
        "norm": {"scale": jnp.ones((4,))},
        "router": {"kernel": jnp.ones((4, 2))},
    }
    out = integerize_tree(params, QuantPolicy.int8_qat(),
                          qstate={"dense/out": 4})
    assert isinstance(out["dense"]["kernel"], QTensor)
    assert isinstance(out["dense"]["bias"], QTensor)
    # calibrated activation exponent baked next to the quantized layer
    assert int(out["dense"]["n_out"]) == 4
    # norm scale and router kernel pass through untouched (float)
    assert not isinstance(out["norm"]["scale"], QTensor)
    assert not isinstance(out["router"]["kernel"], QTensor)
    assert "n_out" not in out["router"]


def test_integerize_weights_only_leaves_small_leaves_alone():
    params = {
        "attn": {"wq": {"kernel": jnp.ones((8, 8))}},
        "norm": {"scale": jnp.ones((8,))},
        "head": {"bias": jnp.ones((8,))},
    }
    out = integerize.integerize_weights_only(params, bits=8)
    qt = out["attn"]["wq"]["kernel"]
    assert isinstance(qt, QTensor) and qt.q.dtype == jnp.int8
    # per-channel exponents along the output axis
    assert qt.n.shape == (8,) and qt.channel_axis == 1
    assert not isinstance(out["norm"]["scale"], QTensor)
    assert not isinstance(out["head"]["bias"], QTensor)


def test_integerize_weights_only_stacked_keeps_per_layer_grids():
    # scan-stacked kernel (L, D, F): each layer gets its own exponent row
    w = jnp.stack([jnp.ones((4, 6)), jnp.ones((4, 6)) * 100.0])
    out = integerize.integerize_weights_only({"ffn": {"kernel": w}}, bits=8)
    qt = out["ffn"]["kernel"]
    n = np.asarray(qt.n).reshape(2, 6)
    assert (n[0] != n[1]).all()  # 1.0-scale layer vs 100.0-scale layer
    np.testing.assert_allclose(np.asarray(qt.dequantize()), np.asarray(w),
                               rtol=2 ** -6)
