"""End-to-end system tests: the paper's full pipeline (train → quantize →
deploy) plus the framework's fault-tolerance and serving behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.microai_resnet import build_resnet
from repro.core import integerize
from repro.core.policy import QMode, QuantPolicy
from repro.data.synthetic import make_classification_dataset
from repro.models.registry import get_config
from repro.nn.module import Context, eval_context
from repro.optim import multistep_lr, sgd
from repro.serve.engine import ServeEngine
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import make_train_step


# --------------------------------------------------------------------------
# Paper pipeline on the paper's network
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trained_resnet():
    """A small float ResNetv1-6 trained on synthetic UCI-HAR-like data."""
    x_tr, y_tr, x_te, y_te = make_classification_dataset(
        "uci-har", n_train=768, n_test=256, seed=0)
    model = build_resnet("uci-har", filters=12)
    params = model.init(jax.random.PRNGKey(0))
    opt = sgd(momentum=0.9, weight_decay=5e-4)
    opt_state = opt.init(params)
    sched = multistep_lr(0.05, milestones=(260, 340))

    @jax.jit
    def step(params, opt_state, xb, yb, lr):
        def loss_fn(p):
            logits = model.apply(p, xb, Context(train=True))
            oh = jax.nn.one_hot(yb, logits.shape[-1])
            return -jnp.mean(jnp.sum(oh * jax.nn.log_softmax(logits), -1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, loss

    rng = np.random.default_rng(0)
    bs = 64
    for it in range(400):
        idx = rng.integers(0, x_tr.shape[0], bs)
        params, opt_state, loss = step(params, opt_state, x_tr[idx], y_tr[idx],
                                       sched(it))
    return model, params, (x_te, y_te)


def _accuracy(model, params, data, ctx):
    x, y = data
    logits = model.apply(params, x, ctx)
    if hasattr(logits, "dequantize"):
        logits = logits.dequantize()
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


def test_float_baseline_learns(trained_resnet):
    model, params, test = trained_resnet
    acc = _accuracy(model, params, test, eval_context())
    # Every rng is seeded, but the 400-step trajectory amplifies XLA
    # numeric drift across jax/XLA versions and CPU codegen — observed
    # final accuracy ranges ~0.61-0.9 for the same seeds.  The bar only
    # needs to separate "learned" from chance (1/6 ≈ 0.17); the PTQ tests
    # below are all *relative* to this float accuracy, so they are immune.
    assert acc > 0.5, f"float baseline failed to learn: {acc}"


def test_int16_ptq_matches_float(trained_resnet):
    """Paper claim C1: int16 PTQ ≈ float32, no QAT needed."""
    model, params, test = trained_resnet
    acc_f = _accuracy(model, params, test, eval_context())
    acc_16 = _accuracy(model, params, test,
                       eval_context(QuantPolicy.int16_ptq()))
    assert abs(acc_f - acc_16) < 0.02, (acc_f, acc_16)


def test_int8_ptq_reasonable_int9_better(trained_resnet):
    """Paper Appendix B shape: int9 PTQ ≥ int8 PTQ (more grid precision)."""
    model, params, test = trained_resnet
    pol8 = QuantPolicy(mode=QMode.EVAL, weight_bits=8, act_bits=8)
    pol9 = QuantPolicy.int9_ptq()
    acc8 = _accuracy(model, params, test, eval_context(pol8))
    acc9 = _accuracy(model, params, test, eval_context(pol9))
    acc_f = _accuracy(model, params, test, eval_context())
    assert acc9 >= acc8 - 0.02
    assert acc_f - acc8 < 0.15, f"int8 PTQ collapsed: {acc8} vs {acc_f}"


def test_integer_engine_end_to_end(trained_resnet):
    """Paper Sec. 5.8: calibrate → integerize → full-integer inference.

    The integer engine's predictions must track the fake-quant EVAL path
    (same grid, same scales) almost everywhere.
    """
    model, params, (x_te, y_te) = trained_resnet
    policy = QuantPolicy(mode=QMode.EVAL, weight_bits=8, act_bits=8)

    calib = policy.with_mode(QMode.CALIB)

    @jax.jit
    def calib_step(p, xb):
        ctx = Context(policy=calib, train=False)
        model.apply(p, xb, ctx)
        return ctx.stats

    acc_stats = {}
    for i in range(4):
        st = calib_step(params, x_te[i * 32:(i + 1) * 32])
        for k, v in st.items():
            acc_stats[k] = jnp.maximum(acc_stats[k], v) if k in acc_stats else v
    from repro.core import ptq

    qstate = ptq.ranges_to_qstate(acc_stats, policy)
    iparams = integerize.integerize(params, policy, qstate)

    # input quantization (paper Sec. 5.6: caller converts)
    in_site = "resnet6/conv1/in"
    assert in_site in qstate
    xq = integerize.quantize_input(x_te[:64], qstate, in_site, 8)

    int_ctx = Context(policy=policy.with_mode(QMode.INTEGER), train=False,
                      qstate=qstate)
    out = model.apply(iparams, xq, int_ctx)
    assert out.shape == (64, 6)
    int_pred = jnp.argmax(out, -1)

    eval_ctx = Context(policy=policy, train=False, qstate=qstate)
    fq_logits = model.apply(params, x_te[:64], eval_ctx)
    fq_pred = jnp.argmax(fq_logits, -1)
    agree = float(jnp.mean(int_pred == fq_pred))
    assert agree > 0.9, f"integer engine diverges from fake-quant: {agree}"

    # memory claim C3: int8 storage is ~4x smaller than float32
    rom_int8 = integerize.model_rom_bytes(iparams)
    rom_f32 = integerize.model_rom_bytes(params)
    assert rom_f32 / rom_int8 > 3.5, (rom_f32, rom_int8)


def test_weight_only_serving_path(trained_resnet):
    """int8 weight-only (TPU serving mode): logits stay close to float."""
    model, params, (x_te, _) = trained_resnet
    wq = integerize.integerize_weights_only(params)
    lf = model.apply(params, x_te[:32], eval_context())
    lq = model.apply(wq, x_te[:32], eval_context())
    cos = jnp.sum(lf * lq) / (jnp.linalg.norm(lf) * jnp.linalg.norm(lq))
    assert float(cos) > 0.99, float(cos)


# --------------------------------------------------------------------------
# Fault tolerance
# --------------------------------------------------------------------------

def test_checkpoint_restart_exact_resume(tmp_path):
    """Simulated preemption: resume from the checkpoint reproduces the run."""
    from repro.data.pipeline import markov_batch_fn

    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="none")
    opt = sgd(momentum=0.9)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step_fn = jax.jit(make_train_step(model, opt, 0.01))
    bf = markov_batch_fn(cfg.vocab, 4, 32, seed=3)

    ckpt = CheckpointManager(str(tmp_path / "ck"), keep=2)
    losses = []
    for s in range(6):
        state, m = step_fn(state, bf(s))
        losses.append(float(m["loss"]))
        if s == 2:
            ckpt.save(3, state)

    # "preemption": restart from step 3 and replay
    state2 = ckpt.restore(3, {"params": params, "opt": opt.init(params),
                              "step": jnp.zeros((), jnp.int32)})
    assert int(state2["step"]) == 3
    for s in range(3, 6):
        state2, m2 = step_fn(state2, bf(s))
        assert abs(float(m2["loss"]) - losses[s]) < 1e-5, s
    for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                    jax.tree_util.tree_leaves(state2["params"])):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_checkpoint_atomicity_and_retention(tmp_path):
    ckpt = CheckpointManager(str(tmp_path / "ck"), keep=2)
    tree = {"w": jnp.arange(8.0), "b": {"x": jnp.ones((3,))}}
    for s in (1, 2, 3):
        ckpt.save(s, tree)
    assert ckpt.all_steps() == [2, 3]      # retention
    # a stale .tmp dir (killed writer) must be invisible to restore
    os.makedirs(os.path.join(str(tmp_path), "ck", "step_000000009.tmp"))
    assert ckpt.latest_step() == 3
    restored = ckpt.restore(3, tree)
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_elastic_restore_changes_dtype(tmp_path):
    """Restore casts dtypes onto the target spec (mesh-independent format)."""
    ckpt = CheckpointManager(str(tmp_path / "ck"))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(1, tree)
    target = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
    out = ckpt.restore(1, target)
    assert out["w"].dtype == jnp.bfloat16


def test_async_checkpoint(tmp_path):
    ckpt = CheckpointManager(str(tmp_path / "ck"))
    tree = {"w": jnp.ones((128, 128))}
    fut = ckpt.save_async(7, tree)
    fut.result()
    assert ckpt.latest_step() == 7


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------

def test_serve_engine_quantized_variants_agree():
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.arange(2 * 8, dtype=jnp.int32).reshape(2, 8) % cfg.vocab

    outs = {}
    for name, kw in [("float", {}), ("qkv", {"quantized_kv": True}),
                     ("wq", {"weight_quant": True})]:
        eng = ServeEngine(model=model, params=params, max_len=24,
                          batch_slots=2, **kw)
        outs[name] = np.asarray(eng.generate(prompts, 8))
    assert outs["float"].shape == (2, 8)
    for name in ("qkv", "wq"):
        assert outs[name].max() < cfg.vocab
        assert (outs[name][:, 0] == outs["float"][:, 0]).mean() >= 0.5


def test_kv_cache_int8_quantization_grid():
    """int8 KV cache follows the paper's Qm.n grid exactly."""
    from repro.nn.attention import init_kv_cache, update_kv_cache

    cache = init_kv_cache(1, 8, 2, 4, quantized=True, cache_n=3)
    k = jnp.full((1, 2, 2, 4), 0.77)
    v = jnp.full((1, 2, 2, 4), -1.23)
    cache = update_kv_cache(cache, k, v)
    assert int(cache["k"][0, 0, 0, 0]) == int(0.77 * 8)     # trunc(x * 2^3)
    assert int(cache["v"][0, 0, 0, 0]) == int(np.trunc(-1.23 * 8))
    assert int(cache["len"]) == 2


# --------------------------------------------------------------------------
# Data pipeline determinism
# --------------------------------------------------------------------------

def test_pipeline_step_determinism():
    from repro.data.pipeline import markov_batch_fn

    bf1 = markov_batch_fn(1000, 4, 16, seed=7)
    bf2 = markov_batch_fn(1000, 4, 16, seed=7)
    np.testing.assert_array_equal(bf1(5)["tokens"], bf2(5)["tokens"])
    assert not np.array_equal(bf1(5)["tokens"], bf1(6)["tokens"])


def test_int8_weight_gather_training_learns():
    """Beyond-paper: training with materialized-int8 weights (STE, float
    master) — the optimizer accumulates exactly while every forward uses the
    paper's int8 grid."""
    import jax

    from repro.data.pipeline import markov_batch_fn
    from repro.optim import sgd

    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="none")
    opt = sgd(momentum=0.9)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(make_train_step(model, opt, 0.05,
                                   int8_weight_gather=True))
    bf = markov_batch_fn(cfg.vocab, 16, 32, seed=2)
    losses = []
    for s in range(20):
        state, m = step(state, bf(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.15, losses
    # master params stay float (exact accumulation)
    leaves = jax.tree_util.tree_leaves(state["params"])
    assert all(l.dtype == jnp.float32 for l in leaves)
