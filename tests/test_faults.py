"""Hardened serving: per-request deadlines, host-side cancellation,
bounded-queue backpressure, the deterministic fault-injection harness
(serve/faults.py), deadlock-to-``failed`` conversion, the NaN/Inf logit
sentinel behind ``audit=True``, and the hardware page-size guard."""
import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config
from repro.serve import FaultPlan, Request, ServeEngine, STATUSES
import repro.serve.engine as serve_engine
import repro.serve.scheduler as sched_mod
from repro.kernels import ops as kops


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def ssm_lm():
    cfg = get_config("mamba-130m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_len", 48)
    kw.setdefault("batch_slots", 4)
    return ServeEngine(model=model, params=params, **kw)


def _workload(vocab, *, n_requests=4, plen=16, max_new=8, spacing=1, seed=5,
              deadline=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=plen, dtype=np.int32),
                    max_new=max_new, arrival=i * spacing,
                    deadline_steps=deadline)
            for i in range(n_requests)]


# --------------------------------------------------------------------------
# FaultPlan: the schedule object itself
# --------------------------------------------------------------------------

def test_faultplan_normalizes_and_validates():
    p = FaultPlan(alloc_fail=[3, 3, "5"], swap_fail=(2,), nan={np.int64(7): 1})
    assert p.alloc_fail == frozenset({3, 5})
    assert p.deny_alloc(5) and not p.deny_alloc(4)
    assert p.deny_swap(2) and not p.deny_admission(2)
    assert p.nan == {7: 1} and p.nan_events() == [(7, 1)]
    assert not p.empty and p.max_tick == 7
    assert FaultPlan().empty and FaultPlan().max_tick == -1
    with pytest.raises(ValueError):
        FaultPlan(alloc_fail={-1})
    with pytest.raises(ValueError):
        FaultPlan(nan={3: -2})


def test_faultplan_json_and_spec_roundtrip(tmp_path):
    p = FaultPlan(alloc_fail={4}, swap_fail={6}, admit_stall={1},
                  nan={9: 0, 3: 2})
    assert FaultPlan.from_json(p.to_json()) == p
    inline = json.dumps(p.to_json())
    assert FaultPlan.from_spec(inline) == p
    f = tmp_path / "plan.json"
    f.write_text(inline)
    assert FaultPlan.from_spec(str(f)) == p
    with pytest.raises(ValueError, match="unknown FaultPlan keys"):
        FaultPlan.from_json({"alloc_fail": [1], "typo": []})


def test_faultplan_random_is_seed_deterministic():
    a = FaultPlan.random(11, ticks=64, slots=4, nan_events=2)
    b = FaultPlan.random(11, ticks=64, slots=4, nan_events=2)
    c = FaultPlan.random(12, ticks=64, slots=4, nan_events=2)
    assert a == b and a != c
    assert a.max_tick < 64
    with pytest.raises(ValueError):
        FaultPlan.random(0, ticks=0, slots=4)


# --------------------------------------------------------------------------
# Deadlines
# --------------------------------------------------------------------------

def test_deadline_times_out_live_request(smoke_lm):
    """A live request past its deadline is evicted as ``timeout`` carrying a
    clean prefix of its reference stream; co-resident requests are not
    perturbed (greedy decode: eviction frees a slot, never moves tokens)."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=3, max_new=16, spacing=0)
    eng = _engine(model, params)
    base, _ = eng.scheduler(chunk_size=8).run(reqs)
    tight = [r if r.rid != 1 else
             dataclasses.replace(r, deadline_steps=8)
             for r in reqs]
    got, st = eng.scheduler(chunk_size=8).run(tight)
    assert got[1].status == "timeout"
    assert 0 < len(got[1].tokens) < len(base[1].tokens)
    assert got[1].tokens == base[1].tokens[:len(got[1].tokens)]
    for rid in (0, 2):
        assert got[rid].status == "ok" and got[rid].tokens == base[rid].tokens
    assert st.timeouts == 1 and st.completed == 2
    assert st.summary()["timeouts"] == 1
    assert 0 < st.completion_rate < 1


def test_deadline_times_out_queued_request(smoke_lm):
    """A request whose deadline expires while still waiting in the queue is
    reaped without ever being admitted: no tokens, admitted_at == -1."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=3, max_new=16, spacing=0)
    reqs[2] = dataclasses.replace(reqs[2], deadline_steps=4)
    eng = _engine(model, params, batch_slots=2)   # rid 2 must wait
    got, st = eng.scheduler(chunk_size=8).run(reqs)
    assert got[2].status == "timeout"
    assert got[2].tokens == [] and got[2].admitted_at == -1
    assert got[0].status == "ok" and got[1].status == "ok"
    assert st.timeouts == 1


def test_deadline_validation(smoke_lm):
    cfg, model, params = smoke_lm
    bad = _workload(cfg.vocab, n_requests=1, deadline=0)
    with pytest.raises(ValueError, match="deadline_steps"):
        _engine(model, params).scheduler(chunk_size=8).run(bad)


# --------------------------------------------------------------------------
# Cancellation
# --------------------------------------------------------------------------

def test_cancellation_via_schedule_and_mid_run_hook(smoke_lm):
    """Both cancellation paths — the pre-declared ``cancels={rid: tick}``
    schedule and a mid-run ``Scheduler.cancel`` from the ``on_tick`` hook —
    land status="cancelled" with a clean token prefix."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=3, max_new=16, spacing=0)
    eng = _engine(model, params)
    base, _ = eng.scheduler(chunk_size=8).run(reqs)
    got, st = eng.scheduler(chunk_size=8).run(reqs, cancels={0: 6})
    assert got[0].status == "cancelled"
    assert got[0].tokens == base[0].tokens[:len(got[0].tokens)]
    assert len(got[0].tokens) < len(base[0].tokens)
    assert got[1].tokens == base[1].tokens
    assert st.cancellations == 1 and st.summary()["cancellations"] == 1

    sched = eng.scheduler(chunk_size=8)
    got2, st2 = sched.run(reqs, on_tick=lambda t:
                          sched.cancel(2) if t == 6 else None)
    assert got2[2].status == "cancelled"
    assert got2[2].tokens == base[2].tokens[:len(got2[2].tokens)]
    assert got2[0].tokens == base[0].tokens
    assert st2.cancellations == 1


# --------------------------------------------------------------------------
# Bounded-queue backpressure
# --------------------------------------------------------------------------

def test_backpressure_reject(smoke_lm, capsys):
    """With the waiting queue bounded, a same-tick arrival burst past the
    bound is terminated loudly as ``rejected``; the survivors' streams
    match the unbounded run."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=6, max_new=6, spacing=0)
    eng = _engine(model, params, batch_slots=2)
    base, _ = eng.scheduler(chunk_size=8).run(reqs)
    got, st = eng.scheduler(chunk_size=8, max_queue=2).run(reqs)
    rejected = sorted(r for r in got if got[r].status == "rejected")
    kept = sorted(r for r in got if got[r].status == "ok")
    assert st.rejections == len(rejected) > 0
    assert "queue full" in capsys.readouterr().out
    for r in rejected:
        assert got[r].tokens == [] and got[r].admitted_at == -1
    for r in kept:
        assert got[r].tokens == base[r].tokens
    assert set(got) == {r.rid for r in reqs}   # every rid is terminal
    assert st.completion_rate == pytest.approx(len(kept) / len(reqs))


def test_backpressure_shed_oldest(smoke_lm):
    """``shed_oldest`` sheds the longest-waiting request instead of the
    arrival, so later arrivals displace earlier queued ones."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=6, max_new=6, spacing=0)
    eng = _engine(model, params, batch_slots=2)
    r_rej, _ = eng.scheduler(chunk_size=8, max_queue=1,
                             reject_policy="reject").run(reqs)
    r_shed, st = eng.scheduler(chunk_size=8, max_queue=1,
                               reject_policy="shed_oldest").run(reqs)
    assert st.rejections > 0
    rej_reject = {r for r in r_rej if r_rej[r].status == "rejected"}
    rej_shed = {r for r in r_shed if r_shed[r].status == "rejected"}
    # same pressure, opposite victims: reject drops the newcomers,
    # shed_oldest drops the waiters — the highest rid always survives shed
    assert max(r.rid for r in reqs) not in rej_shed
    assert max(r.rid for r in reqs) in rej_reject
    assert len(rej_shed) == len(rej_reject)

    with pytest.raises(ValueError, match="reject_policy"):
        eng.scheduler(chunk_size=8, max_queue=1, reject_policy="drop")
    with pytest.raises(ValueError, match="max_queue"):
        eng.scheduler(chunk_size=8, max_queue=0)


# --------------------------------------------------------------------------
# Injected faults: the three denial seams
# --------------------------------------------------------------------------

def test_admission_stall_fault_shifts_schedule_not_streams(smoke_lm):
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=3, max_new=8, spacing=0)
    eng = _engine(model, params, paged_kv=True, page_size=8)
    base, _ = eng.scheduler(chunk_size=8).run(reqs)
    plan = FaultPlan(admit_stall={0, 1, 2})
    got, st = eng.scheduler(chunk_size=8).run(reqs, fault_plan=plan)
    assert st.fault_events > 0
    for r in reqs:
        assert got[r.rid].status == "ok"
        assert got[r.rid].tokens == base[r.rid].tokens
    # the stall delayed first tokens, visible in virtual-time TTFT
    assert got[0].admitted_at > base[0].admitted_at


def test_alloc_denial_fault_defers_and_preempts(smoke_lm):
    """``alloc_fail`` ticks behave as a momentarily-empty pool: admission
    defers, decode growth preempts — and the streams still match the
    fault-free run once the window passes."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=4, max_new=16, spacing=0)
    eng = _engine(model, params, paged_kv=True, page_size=8,
                  kv_pool_pages=16)
    sched = lambda: eng.scheduler(chunk_size=8, prefix_sharing=False,  # noqa: E731
                                  oversubscribe=True)
    base, _ = sched().run(reqs)
    plan = FaultPlan(alloc_fail={0, 1, 5})
    got, st = sched().run(reqs, fault_plan=plan)
    assert st.fault_events > 0
    for r in reqs:
        assert got[r.rid].status == "ok"
        assert got[r.rid].tokens == base[r.rid].tokens, r.rid


@pytest.mark.parametrize("via", ["fault", "capacity"])
def test_swap_refusal_falls_back_to_recompute(smoke_lm, via):
    """A refused swap park — injected (``swap_fail``) or a genuinely full
    ``SwapArea`` (``swap_bytes``) — degrades that preemption to the
    recompute path: tokens stay identical, ``swap_refusals`` counts it."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=4, plen=16, max_new=12, spacing=0)
    dense = _engine(model, params, batch_slots=3)
    base, _ = dense.scheduler(chunk_size=8, prefix_sharing=False).run(reqs)
    eng = _engine(model, params, batch_slots=3, paged_kv=True, page_size=8,
                  kv_pool_pages=9)
    kw = dict(chunk_size=8, prefix_sharing=False, oversubscribe=True,
              preempt_policy="swap")
    plan = None
    if via == "fault":
        plan = FaultPlan(swap_fail=frozenset(range(200)))
    else:
        kw["swap_bytes"] = 1          # no park ever fits
    got, st = eng.scheduler(**kw).run(reqs, fault_plan=plan)
    assert st.preemptions > 0 and st.swap_refusals > 0
    assert st.swapped_pages == 0      # every park degraded to recompute
    for r in reqs:
        assert got[r.rid].status == "ok"
        assert got[r.rid].tokens == base[r.rid].tokens, (via, r.rid)


# --------------------------------------------------------------------------
# NaN/Inf sentinel (audit=True)
# --------------------------------------------------------------------------

def test_nan_sentinel_evicts_exactly_the_poisoned_slot(smoke_lm):
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=3, max_new=16, spacing=0)
    eng = _engine(model, params, paged_kv=True, page_size=8)
    sched = lambda: eng.scheduler(chunk_size=8, audit=True)  # noqa: E731
    base, base_st = sched().run(reqs)
    assert base_st.audited_ticks > 0
    plan = FaultPlan(nan={6: 1})
    got, st = sched().run(reqs, fault_plan=plan)
    failed = [r for r in got if got[r].status == "failed"]
    assert len(failed) == 1 and st.nan_evictions == 1
    v = failed[0]
    # the poisoned step's garbage token is never recorded
    assert got[v].tokens == base[v].tokens[:len(got[v].tokens)]
    assert len(got[v].tokens) < len(base[v].tokens)
    for r in reqs:
        if r.rid != v:
            assert got[r.rid].tokens == base[r.rid].tokens
    assert st.audited_ticks > 0 and st.failed == 1


def test_nan_sentinel_on_ssm_state(ssm_lm):
    """NaN injection against a recurrent (mamba) slot: the sentinel evicts
    exactly the poisoned slot, its zeroed recurrent rows pass the per-tick
    ``check_recurrent_rows`` audit, and the survivors stay token-identical."""
    cfg, model, params = ssm_lm
    reqs = _workload(cfg.vocab, n_requests=3, plen=8, max_new=10, spacing=0)
    eng = _engine(model, params, max_len=24, batch_slots=3)
    sched = lambda: eng.scheduler(chunk_size=4, audit=True)  # noqa: E731
    base, base_st = sched().run(reqs)
    assert base_st.state_kinds == "recurrent"
    assert base_st.audited_ticks > 0
    got, st = sched().run(reqs, fault_plan=FaultPlan(nan={5: 1}))
    failed = [r for r in got if got[r].status == "failed"]
    assert len(failed) == 1 and st.nan_evictions == 1
    v = failed[0]
    assert got[v].tokens == base[v].tokens[:len(got[v].tokens)]
    assert len(got[v].tokens) < len(base[v].tokens)
    for r in reqs:
        if r.rid != v:
            assert got[r.rid].tokens == base[r.rid].tokens
    assert st.audited_ticks > 0 and st.failed == 1


def test_nan_plan_requires_audit(smoke_lm):
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=1)
    eng = _engine(model, params)
    with pytest.raises(ValueError, match="audit"):
        eng.scheduler(chunk_size=8).run(reqs, fault_plan=FaultPlan(nan={4: 0}))
    with pytest.raises(ValueError, match="slot"):
        eng.scheduler(chunk_size=8, audit=True).run(
            reqs, fault_plan=FaultPlan(nan={4: 99}))


# --------------------------------------------------------------------------
# Deadlock -> failed conversion
# --------------------------------------------------------------------------

class _DyingAllocator(sched_mod.PageAllocator):
    """A pool that permanently exhausts after a fixed allocation budget —
    the state the old code answered with a mid-run RuntimeError."""

    budget = 0

    def alloc(self, n):
        cls = _DyingAllocator
        if cls.budget < n:
            return None
        out = super().alloc(n)
        if out is not None:
            cls.budget -= n
        return out


def test_deadlock_converts_victims_instead_of_raising(smoke_lm, monkeypatch,
                                                      capsys):
    """When the pool can never serve the remaining requests (nothing live,
    resumes and admissions permanently blocked), the scheduler fails one
    victim at a time instead of raising — both the parked branch and the
    queued branch — and still returns a terminal status for every rid with
    the auditor clean throughout."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=4, plen=16, max_new=24, spacing=1)
    _DyingAllocator.budget = 10
    monkeypatch.setattr(sched_mod, "PageAllocator", _DyingAllocator)
    eng = _engine(model, params, paged_kv=True, page_size=8,
                  kv_pool_pages=12)
    got, st = eng.scheduler(chunk_size=8, oversubscribe=True,
                            preempt_policy="swap", audit=True).run(reqs)
    assert sorted(got) == [r.rid for r in reqs]
    assert all(got[r].status in STATUSES for r in got)
    assert st.deadlock_failures > 0
    assert st.failed == st.deadlock_failures == \
        sum(1 for r in got.values() if r.status == "failed")
    assert st.audited_ticks > 0
    out = capsys.readouterr().out
    assert "unservable deadlock" in out          # parked-victim conversion
    assert "can never be admitted" in out        # queued-victim conversion


# --------------------------------------------------------------------------
# The acceptance scenario: everything at once
# --------------------------------------------------------------------------

def test_full_chaos_scenario_contains_all_faults(smoke_lm):
    """Deadlines + bounded queue + auditor + a combined fault plan (pool
    exhaustion, swap refusal, admission stall, one NaN tick): ``run()``
    completes without raising, every request lands a terminal status, the
    NaN victim alone fails, and the non-faulted streams are token-identical
    to the fault-free run."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=5, plen=16, max_new=16, spacing=1,
                     deadline=300)
    eng = _engine(model, params, batch_slots=4, paged_kv=True, page_size=8,
                  kv_pool_pages=12)
    sched = lambda: eng.scheduler(  # noqa: E731
        chunk_size=8, prefix_sharing=False, oversubscribe=True,
        preempt_policy="swap", audit=True, max_queue=5)
    base, base_st = sched().run(reqs)
    assert all(r.status == "ok" for r in base.values())
    plan = FaultPlan(alloc_fail={4, 5}, swap_fail={4, 5, 6},
                     admit_stall={2}, nan={9: 0})
    got, st = sched().run(reqs, fault_plan=plan)
    assert sorted(got) == [r.rid for r in reqs]
    failed = [r for r in got if got[r].status == "failed"]
    assert len(failed) == 1 and st.nan_evictions == 1
    assert st.timeouts == 0 and st.rejections == 0
    for r in reqs:
        if r.rid in failed:
            assert got[r.rid].tokens == \
                base[r.rid].tokens[:len(got[r.rid].tokens)]
        else:
            assert got[r.rid].status == "ok"
            assert got[r.rid].tokens == base[r.rid].tokens, r.rid
    assert st.fault_events > 0 and st.audited_ticks > 0
    s = st.summary()
    for key in ("rejections", "timeouts", "cancellations", "failed",
                "completion_rate", "steady_tok_s", "p99_latency_steps"):
        assert key in s
    assert s["completion_rate"] == pytest.approx((len(reqs) - 1) / len(reqs))


# --------------------------------------------------------------------------
# Hardware page-size guard
# --------------------------------------------------------------------------

def _no_runtime_warning(fn):
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        fn()
    return not any(issubclass(w.category, RuntimeWarning) for w in record)


def test_small_page_warns_once_on_hardware_dispatch(smoke_lm, monkeypatch):
    """A paged engine with page_size below the sublane tile warns exactly
    once per process when kernels dispatch as compiled Pallas, and never
    under interpret/ref dispatch."""
    cfg, model, params = smoke_lm
    monkeypatch.setattr(kops, "FORCE", "pallas")
    monkeypatch.setattr(serve_engine, "_small_page_warned", False)
    with pytest.warns(RuntimeWarning, match="page_size"):
        _engine(model, params, paged_kv=True, page_size=8)
    # latch: second build in the same process is silent
    assert _no_runtime_warning(
        lambda: _engine(model, params, paged_kv=True, page_size=8))

    monkeypatch.setattr(serve_engine, "_small_page_warned", False)
    monkeypatch.setattr(kops, "FORCE", "interpret")
    assert _no_runtime_warning(   # correctness dispatch: no warning
        lambda: _engine(model, params, paged_kv=True, page_size=8))
    # roomy pages never warn, even on hardware
    monkeypatch.setattr(kops, "FORCE", "pallas")
    assert _no_runtime_warning(
        lambda: _engine(model, params, paged_kv=True,
                        page_size=serve_engine.HW_MIN_PAGE_SIZE,
                        max_len=256))
