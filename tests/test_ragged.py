"""Ragged one-forward-per-tick serving: token identity vs the mixed-step
scheduler across dense/paged/prefix-shared/oversubscribed caches, multi-lane
prefill, the O(1) compile-shape property, the qragged kernel-vs-oracle
contract, and the end-to-end interpret-mode Pallas path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config
from repro.nn.module import eval_context
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def whisper():
    cfg = get_config("whisper-tiny-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_len", 48)
    kw.setdefault("batch_slots", 4)
    return ServeEngine(model=model, params=params, **kw)


def _reqs(cfg, n, *, seed=3, base_len=5, stride=3, max_new=6, spacing=1):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=base_len + stride * i),
                    max_new=max_new, arrival=spacing * i) for i in range(n)]


# --------------------------------------------------------------------------
# Token identity vs the mixed step
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized_kv", [False, True],
                         ids=["fp32", "int8kv"])
@pytest.mark.parametrize("chunk", [4, 7])
def test_ragged_token_identical_to_mixed(smoke_lm, quantized_kv, chunk):
    """Multi-lane ragged admission emits exactly the mixed step's streams —
    per-request prompt lengths, staggered arrivals, readmission, and chunk
    sizes that do NOT divide the prompt lengths."""
    cfg, model, params = smoke_lm
    eng = _engine(model, params, quantized_kv=quantized_kv)
    reqs = _reqs(cfg, 6)
    base, _ = eng.scheduler(chunk_size=chunk).run(reqs)
    got, stats = eng.scheduler(chunk_size=chunk, ragged=True,
                               prefill_lanes=3).run(reqs)
    for i in range(6):
        assert got[i].tokens == base[i].tokens, (quantized_kv, chunk, i)
    want_chunks = sum(-(-len(r.prompt) // chunk) for r in reqs)
    assert stats.prefill_chunks == want_chunks


def test_ragged_paged_prefix_sharing_identity(smoke_lm):
    """Ragged over the paged pool with prefix sharing live: shared-prefix
    requests map resident pages (hits > 0) and streams stay identical to the
    mixed paged run."""
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(7)
    head = rng.integers(0, cfg.vocab, size=16, dtype=np.int32)
    reqs = []
    for i in range(4):
        tail = rng.integers(0, cfg.vocab, size=4, dtype=np.int32)
        # arrivals staggered so request 0's prefill is resident before the
        # shared-prefix followers are admitted
        reqs.append(Request(rid=i, prompt=np.concatenate([head, tail]),
                            max_new=5, arrival=0 if i == 0 else 8))
    kw = dict(paged_kv=True, page_size=8, quantized_kv=True)
    base, _ = _engine(model, params, **kw).scheduler(chunk_size=8).run(reqs)
    got, stats = _engine(model, params, **kw).scheduler(
        chunk_size=8, ragged=True, prefill_lanes=2).run(reqs)
    for i in range(4):
        assert got[i].tokens == base[i].tokens, i
    assert stats.prefix_hits > 0
    assert stats.shared_pages_mapped > 0


@pytest.mark.parametrize("preempt", ["recompute", "swap"])
def test_ragged_oversubscribed_preemption_identity(smoke_lm, preempt):
    """Oversubscribed pool running dry mid-decode: the ragged scheduler
    preempts and resumes exactly like the mixed one, bit-identical streams
    under both recompute and swap."""
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(9)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=14, arrival=i) for i in range(4)]
    kw = dict(max_len=32, batch_slots=4, paged_kv=True, page_size=8,
              kv_pool_pages=8, quantized_kv=True)
    sk = dict(chunk_size=8, oversubscribe=True, preempt_policy=preempt)
    base, bstats = _engine(model, params, **kw).scheduler(**sk).run(reqs)
    got, rstats = _engine(model, params, **kw).scheduler(
        ragged=True, prefill_lanes=2, **sk).run(reqs)
    for i in range(4):
        assert got[i].tokens == base[i].tokens, (preempt, i)
    # the pool really ran dry in both runs — the identity is not vacuous
    assert bstats.preemptions > 0 and rstats.preemptions > 0
    if preempt == "swap":
        assert rstats.resumes > 0    # recompute re-queues instead


def test_ragged_eos_evicts_and_readmits(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, batch_slots=1, max_len=32)
    prompt = np.arange(8, dtype=np.int32)
    free_run, _ = eng.scheduler(chunk_size=3, ragged=True).run(
        [Request(rid=0, prompt=prompt, max_new=8)])
    eos = free_run[0].tokens[2]

    reqs = [Request(rid=0, prompt=prompt, max_new=8),
            Request(rid=1, prompt=prompt + 1, max_new=3)]
    results, _ = eng.scheduler(eos_id=eos, chunk_size=3, ragged=True).run(reqs)
    assert results[0].eos is True
    assert results[0].tokens[-1] == eos
    assert len(results[0].tokens) <= 3
    assert results[1].admitted_at >= results[0].finished_at
    assert len(results[1].tokens) == 3


def test_ragged_encdec_matches_mixed(whisper):
    """EncDec ragged ticks gather per-token encoder rows (cross-attention
    sees each lane's own enc): streams equal the mixed chunked run."""
    cfg, model, params = whisper

    def encode(seed, s_enc=6):
        embeds = 0.1 * jax.random.normal(
            jax.random.PRNGKey(seed), (1, s_enc, model.d_model), jnp.float32)
        return model.encode(params, embeds, eval_context())

    rng = np.random.default_rng(11)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=4 + i),
                    max_new=5, arrival=i, enc=encode(10 * (i + 1)))
            for i in range(3)]
    eng = ServeEngine(model=model, params=params, max_len=24, batch_slots=2)
    base, _ = eng.scheduler(chunk_size=4).run(reqs)
    got, _ = eng.scheduler(chunk_size=4, ragged=True,
                           prefill_lanes=2).run(reqs)
    for i in range(3):
        assert got[i].tokens == base[i].tokens, i


# --------------------------------------------------------------------------
# O(1) compile shapes
# --------------------------------------------------------------------------

def test_ragged_compiles_o1_shapes(smoke_lm):
    """One compile shape for the whole run: the jit count is flat across
    distinct prompt-length sets AND across lane counts (pure-decode ticks
    reuse the same ragged shape with inert lane rows)."""
    if not hasattr(jax.jit(lambda: 0), "_cache_size"):
        pytest.skip("jax version does not expose jit cache sizes")
    cfg, model, params = smoke_lm

    def compiles(lanes, lens):
        rng = np.random.default_rng(13)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=p),
                        max_new=3) for i, p in enumerate(lens)]
        _, st = _engine(model, params, max_len=64).scheduler(
            chunk_size=8, ragged=True, prefill_lanes=lanes).run(reqs)
        return st.num_jit_compiles

    n_short = compiles(2, [11])
    n_many = compiles(2, [3, 5, 8, 11, 14, 17, 21])
    assert n_many == n_short, (n_short, n_many)      # O(1) in prompt lengths
    assert n_many <= 8, n_many                       # and a small constant
    assert compiles(1, [11]) == compiles(4, [11]) == n_short


def test_ragged_requires_chunk_size_and_lanes_require_ragged(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params)
    with pytest.raises(ValueError, match="chunk_size"):
        eng.scheduler(ragged=True)
    with pytest.raises(ValueError, match="prefill_lanes"):
        eng.scheduler(chunk_size=4, prefill_lanes=2)
    with pytest.raises(ValueError, match="prefill_lanes"):
        eng.scheduler(chunk_size=4, ragged=True, prefill_lanes=0)


# --------------------------------------------------------------------------
# Kernel vs oracle
# --------------------------------------------------------------------------

def _ragged_case(seed, *, t=10, hq=4, hkv=2, d=8, n_pages=6, ps=4,
                 nslots=3, max_pages=4):
    rng = jax.random.PRNGKey(seed)
    q = jax.random.normal(rng, (t, hq, d), jnp.float32)
    k_new = jax.random.normal(jax.random.fold_in(rng, 1), (t, hkv, d))
    v_new = jax.random.normal(jax.random.fold_in(rng, 2), (t, hkv, d))
    k_pool = jax.random.randint(jax.random.fold_in(rng, 3),
                                (n_pages, ps, hkv, d), -100, 100, jnp.int8)
    v_pool = jax.random.randint(jax.random.fold_in(rng, 4),
                                (n_pages, ps, hkv, d), -100, 100, jnp.int8)
    # slot 0 owns pages 0,1; slot 1 pages 2,3; slot 2 pages 4,5 (+ unmapped)
    table = jnp.asarray([[0, 1, -1, -1], [2, 3, -1, -1], [4, 5, -1, -1]],
                        jnp.int32)
    # decode rows for slots 0..2, then a 4-token chunk for slot 1 (exercises
    # intra-tick visibility: later chunk rows attend to earlier ones), then
    # inert pad rows (position -1)
    slots = jnp.asarray([0, 1, 2, 1, 1, 1, 1, 0, 0, 0], jnp.int32)
    pos = jnp.asarray([5, 3, 6, 4, 5, 6, 7, -1, -1, -1], jnp.int32)
    return q, k_new, v_new, k_pool, v_pool, table, slots, pos


def test_qragged_kernel_matches_oracle():
    from repro.kernels.qragged_attn import qragged_attn_pallas
    from repro.kernels.ref import qragged_attn_ref

    for seed in (0, 1):
        q, k_new, v_new, k_pool, v_pool, table, slots, pos = _ragged_case(seed)
        k_n = jnp.int32(3)
        v_n = jnp.int32(3)
        ref_o, ref_k, ref_v = qragged_attn_ref(
            q, k_new, v_new, k_pool, v_pool, k_n, v_n, table, slots, pos)
        out, ko, vo = qragged_attn_pallas(
            q, k_new, v_new, k_pool, v_pool, k_n, v_n, table, slots, pos,
            interpret=True)
        valid = np.asarray(pos) >= 0
        np.testing.assert_allclose(np.asarray(out)[valid],
                                   np.asarray(ref_o)[valid],
                                   rtol=1e-5, atol=1e-5)
        # pool writes are bit-exact (same paper-grid quantizer) and inert
        # rows wrote nothing — the whole pools must agree
        np.testing.assert_array_equal(np.asarray(ko), np.asarray(ref_k))
        np.testing.assert_array_equal(np.asarray(vo), np.asarray(ref_v))


def test_qragged_inert_rows_write_nothing():
    from repro.kernels.ref import qragged_attn_ref

    q, k_new, v_new, k_pool, v_pool, table, slots, pos = _ragged_case(2)
    all_pad = jnp.full_like(pos, -1)
    _, ko, vo = qragged_attn_ref(q, k_new, v_new, k_pool, v_pool,
                                 jnp.int32(3), jnp.int32(3), table,
                                 slots, all_pad)
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(k_pool))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(v_pool))


# --------------------------------------------------------------------------
# End-to-end interpret-mode Pallas path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_ragged_interpret_kernel_path_identical(smoke_lm, paged):
    """REPRO_KERNELS_FORCE=interpret drives the real qragged Pallas kernel
    (dense caches viewed as an identity-table pool): same streams as the
    blocked-jnp ragged path."""
    from repro.kernels import ops as kops

    if kops.FORCE is not None:
        pytest.skip("dispatch already forced globally (e.g. the CI "
                    "kernels-interpret lane) — the jnp-vs-interpret "
                    "comparison would be vacuous")
    cfg, model, params = smoke_lm
    kw = dict(max_len=32, batch_slots=2, quantized_kv=True)
    if paged:
        kw.update(paged_kv=True, page_size=8)
    eng = _engine(model, params, **kw)
    rng = np.random.default_rng(17)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=6 + i),
                    max_new=4, arrival=i) for i in range(3)]
    base, _ = eng.scheduler(chunk_size=4, ragged=True,
                            prefill_lanes=2).run(reqs)
    assert kops.FORCE is None
    kops.FORCE = "interpret"
    try:
        got, _ = eng.scheduler(chunk_size=4, ragged=True,
                               prefill_lanes=2).run(reqs)
    finally:
        kops.FORCE = None
    for i in range(3):
        assert got[i].tokens == base[i].tokens, (paged, i)
