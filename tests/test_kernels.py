"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fake_quant import fake_quant_pallas
from repro.kernels.qconv1d import qconv1d_pallas
from repro.kernels.qdecode_attn import qdecode_attn_pallas
from repro.kernels.qmm import qmm_pallas, qmm_requant_pallas
from repro.kernels.wq_matmul import wq_matmul_pallas


def _rand_int(key, shape, dtype):
    info = jnp.iinfo(dtype)
    return jax.random.randint(key, shape, info.min, info.max + 1, dtype=jnp.int32).astype(dtype)


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (128, 256, 128), (100, 300, 50), (1, 512, 64)])
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int16])
def test_qmm_matches_ref(m, k, n, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = _rand_int(kx, (m, k), dtype)
    w = _rand_int(kw, (k, n), dtype)
    got = qmm_pallas(x, w, bm=32, bk=64, bn=32, interpret=True)
    want = ref.qmm_ref(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shift", [-3, 0, 5, 11])
@pytest.mark.parametrize("width", [8, 16])
def test_qmm_requant_matches_ref(shift, width):
    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    x = _rand_int(kx, (64, 96), jnp.int8)
    w = _rand_int(kw, (96, 48), jnp.int8)
    got = qmm_requant_pallas(x, w, jnp.int32(shift), width=width, bm=32, bk=32, bn=32,
                             interpret=True)
    want = ref.qmm_requant_ref(x, w, shift, width=width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,n", [(4, 32, 16), (64, 128, 256), (33, 100, 77)])
@pytest.mark.parametrize("per_channel", [False, True])
def test_wq_matmul_matches_ref(m, k, n, per_channel):
    kx, kw, kn = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    wq = _rand_int(kw, (k, n), jnp.int8)
    if per_channel:
        nexp = jax.random.randint(kn, (n,), 3, 9)
    else:
        nexp = jnp.int32(6)
    scale = jnp.exp2(-nexp.astype(jnp.float32))
    got = wq_matmul_pallas(x, wq, scale, bm=32, bk=64, bn=32, interpret=True)
    want = ref.wq_matmul_ref(x, wq, scale)
    # Kernel applies the pow2 scale after K-accumulation (exact in real
    # arithmetic; differs from the ref only by f32 reassociation rounding).
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("shape", [(7,), (128, 9), (4, 33, 5)])
@pytest.mark.parametrize("width,n", [(8, 5), (16, 9), (8, -2)])
def test_fake_quant_matches_ref(shape, width, n):
    x = jax.random.normal(jax.random.PRNGKey(3), shape, jnp.float32) * 4.0
    got = fake_quant_pallas(x, jnp.int32(n), width=width, block_rows=8, interpret=True)
    want = ref.fake_quant_ref(x, n, width=width)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@pytest.mark.parametrize("b,w,c,f,ksize,stride,padding", [
    (2, 128, 9, 16, 3, 1, "SAME"),
    (1, 64, 8, 32, 5, 1, "SAME"),
    (3, 128, 16, 24, 3, 2, "SAME"),
    (2, 50, 4, 8, 3, 1, "VALID"),
    (1, 33, 3, 130, 7, 2, "VALID"),
])
def test_qconv1d_matches_ref(b, w, c, f, ksize, stride, padding):
    kx, kw = jax.random.split(jax.random.PRNGKey(4))
    x = _rand_int(kx, (b, w, c), jnp.int8)
    wgt = _rand_int(kw, (ksize, c, f), jnp.int8)
    got = qconv1d_pallas(x, wgt, stride=stride, padding=padding, bf=64, interpret=True)
    want = ref.qconv1d_ref(x, wgt, stride=stride, padding=padding)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("c,g,hkv,d,s,bs,slot,start", [
    (8, 2, 2, 32, 128, 64, 1, 32),     # chunk mid-cache
    (16, 1, 4, 32, 256, 64, 0, 0),     # empty prefix (first chunk)
    (5, 3, 2, 16, 96, 32, 2, 50),      # chunk straddles block boundaries
    (1, 2, 2, 64, 128, 128, 1, 64),    # single-query chunk == decode shape
    (6, 2, 2, 16, 70, 64, 1, 30),      # bs doesn't divide max_len (serve
    #                                    geometry: prompt + odd horizon)
])
def test_qchunk_attn_matches_ref(c, g, hkv, d, s, bs, slot, start):
    from repro.kernels.qchunk_attn import qchunk_attn_pallas

    hq = g * hkv
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    q = jax.random.normal(ks[0], (c, hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (c, hkv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (c, hkv, d), jnp.float32)
    kcache = _rand_int(ks[3], (3, s, hkv, d), jnp.int8)
    vcache = _rand_int(ks[4], (3, s, hkv, d), jnp.int8)
    k_n, v_n = jnp.int32(5), jnp.int32(6)
    ro, rk, rv = ref.qchunk_attn_ref(q, kc, vc, kcache, vcache, k_n, v_n,
                                     slot, start)
    go, gk, gv = qchunk_attn_pallas(q, kc, vc, kcache, vcache, k_n, v_n,
                                    jnp.int32(slot), jnp.int32(start),
                                    bs=bs, interpret=True)
    # quantize-on-write is exact; only the target rows may change
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(rv))
    untouched = np.delete(np.arange(3), slot)
    np.testing.assert_array_equal(np.asarray(gk)[untouched],
                                  np.asarray(kcache)[untouched])
    np.testing.assert_allclose(np.asarray(go), np.asarray(ro),
                               rtol=2e-4, atol=2e-4)


def test_qchunk_attn_single_query_agrees_with_qdecode():
    """A C=1 chunk over a prefix of length L is exactly a decode step at
    kv_len = L+1 (after its own K/V row is appended)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    hkv, g, d, s, start = 2, 2, 32, 128, 40
    q = jax.random.normal(ks[0], (1, g * hkv, d), jnp.float32)
    kc = jax.random.normal(ks[1], (1, hkv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (1, hkv, d), jnp.float32)
    kcache = _rand_int(ks[3], (2, s, hkv, d), jnp.int8)
    vcache = _rand_int(ks[4], (2, s, hkv, d), jnp.int8)
    k_n = v_n = jnp.int32(5)
    out, k2, v2 = ref.qchunk_attn_ref(q, kc, vc, kcache, vcache, k_n, v_n,
                                      1, start)
    q_dec = jnp.broadcast_to(q, (2, g * hkv, d))   # (B, Hq, D) decode layout
    dec = ref.qdecode_attn_ref(q_dec, k2, v2, k_n, v_n,
                               jnp.asarray([0, start + 1], jnp.int32))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(dec[1]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,hq,hkv,d,s,kv_len", [
    (2, 8, 2, 64, 256, 256),
    (1, 4, 4, 32, 128, 100),
    (2, 16, 2, 64, 512, 17),
])
def test_qdecode_attn_matches_ref(b, hq, hkv, d, s, kv_len):
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(keys[0], (b, hq, d), jnp.float32)
    kc = _rand_int(keys[1], (b, s, hkv, d), jnp.int8)
    vc = _rand_int(keys[2], (b, s, hkv, d), jnp.int8)
    k_n, v_n = jnp.int32(5), jnp.int32(6)
    got = qdecode_attn_pallas(q, kc, vc, k_n, v_n, jnp.int32(kv_len), bs=64, interpret=True)
    want = ref.qdecode_attn_ref(q, kc, vc, k_n, v_n, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# Packed int4 weight-only GEMM: unpack-in-kernel vs the ref oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,block_size", [
    (4, 16, 8, 0),          # single tile, per-channel
    (33, 100, 77, 0),       # tiles don't divide any axis
    (8, 31, 16, 0),         # odd K: last byte holds one live nibble
    (64, 128, 256, 32),     # per-block, block divides K and tiles
    (33, 100, 77, 4),       # per-block, nothing divides anything
    (1, 700, 257, 16),      # GEMV row, K crosses several bk tiles
    (7, 24, 5, 10),         # block > remaining K in last tile
])
def test_wq4_matmul_matches_ref(m, k, n, block_size):
    from repro.core import qformat
    from repro.kernels.wq_matmul import wq4_matmul_pallas

    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    t = qformat.quantize_tensor_packed(w, 4, block_size=block_size or None)
    scale = jnp.exp2(-t.n.astype(jnp.float32))
    if block_size:
        scale = scale.reshape(-1, n)
    got = wq4_matmul_pallas(x, t.q, scale, k=k, block_size=block_size,
                            bm=32, bk=64, bn=32, interpret=True)
    want = ref.wq4_matmul_ref(x, t.q, scale, k=k, block_size=block_size)
    # The integer unpack is bit-exact (asserted below); the f32 accumulation
    # differs from the one-shot ref matmul only by K-tiling reassociation.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-4)


def test_wq4_matmul_single_k_tile_bit_exact():
    """With one K step the kernel's accumulation order matches the ref's
    single dot — the unpack+scale path must then agree bit for bit."""
    from repro.core import qformat
    from repro.kernels.wq_matmul import wq4_matmul_pallas

    kx, kw = jax.random.split(jax.random.PRNGKey(8))
    x = jax.random.normal(kx, (16, 32), jnp.float32)
    w = jax.random.normal(kw, (32, 24), jnp.float32)
    for bs in (0, 8):
        t = qformat.quantize_tensor_packed(w, 4, block_size=bs or None)
        scale = jnp.exp2(-t.n.astype(jnp.float32))
        if bs:
            scale = scale.reshape(-1, 24)
        got = wq4_matmul_pallas(x, t.q, scale, k=32, block_size=bs,
                                bm=16, bk=32, bn=24, interpret=True)
        want = ref.wq4_matmul_ref(x, t.q, scale, k=32, block_size=bs)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_wq4_ref_oracle_matches_dense_dequant():
    """The oracle itself is anchored to the PackedQTensor dequantization."""
    from repro.core import qformat

    kx, kw = jax.random.split(jax.random.PRNGKey(9))
    x = jax.random.normal(kx, (5, 19), jnp.float32)
    w = jax.random.normal(kw, (19, 7), jnp.float32)
    for bs in (None, 4):
        t = qformat.quantize_tensor_packed(w, 4, block_size=bs)
        scale = jnp.exp2(-t.n.astype(jnp.float32))
        if bs:
            scale = scale.reshape(-1, 7)
        got = ref.wq4_matmul_ref(x, t.q, scale, k=19, block_size=bs or 0)
        want = jnp.matmul(x, t.dequantize())
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_wq4_ops_dispatch_int2_and_stacked_fall_back():
    """ops.wq4_matmul: width-2 and stacked (scan) layouts take the pure-JAX
    dequant fallback and still match the dense dequant matmul."""
    from repro.core import qformat
    from repro.kernels import ops as kops

    kx, kw = jax.random.split(jax.random.PRNGKey(10))
    x = jax.random.normal(kx, (3, 6, 20), jnp.float32)
    w = jax.random.normal(kw, (20, 9), jnp.float32)
    t2 = qformat.quantize_tensor_packed(w, 2, block_size=8)
    got = kops.wq4_matmul(x, t2)
    want = jnp.einsum("btk,kn->btn", x, t2.dequantize())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    ws = jax.random.normal(kw, (2, 20, 9), jnp.float32)   # stacked layers
    ts = qformat.quantize_tensor_packed(ws, 4, block_size=4)
    got = kops.wq4_matmul(jnp.ones((4, 20), jnp.float32), ts)
    want = jnp.matmul(jnp.ones((4, 20), jnp.float32), ts.dequantize())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
