"""Paged KV cache tests: the block allocator (exhaustion deferral, churn
reuse), the paged nn primitives and Pallas kernels vs their oracles, paged
vs dense scheduler token identity (fp32 and int8 KV, non-page-aligned
prompts), and the donated jitted steps' in-place buffer reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config
from repro.serve import PageAllocator, Request, ServeEngine


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_len", 48)
    kw.setdefault("batch_slots", 2)
    return ServeEngine(model=model, params=params, **kw)


# --------------------------------------------------------------------------
# PageAllocator
# --------------------------------------------------------------------------

def test_allocator_alloc_free_exhaustion():
    a = PageAllocator(4)
    p1 = a.alloc(3)
    assert p1 is not None and len(p1) == 3 and a.free_pages == 1
    assert a.alloc(2) is None          # all-or-nothing: free list untouched
    assert a.free_pages == 1
    p2 = a.alloc(1)
    assert p2 is not None and a.free_pages == 0 and a.pages_in_use == 4
    a.free(p1)
    assert a.free_pages == 3
    with pytest.raises(ValueError, match="not currently held"):
        a.free(p1)                     # double-free is loud, not silent
    assert a.peak_in_use == 4


def test_allocator_free_returns_released_pages():
    """free() reports exactly the pages whose refcount hit zero — what the
    scheduler must retire from the prefix index."""
    a = PageAllocator(4)
    pages = a.alloc(2)
    a.share(pages)                      # refcount 2
    assert a.free(pages) == []          # still held by the sharer
    assert a.pages_in_use == 2
    assert sorted(a.free(pages)) == sorted(pages)
    assert a.pages_in_use == 0
    with pytest.raises(ValueError, match="not currently held"):
        a.free(pages)
    with pytest.raises(ValueError, match="not currently held"):
        a.share(pages)                  # sharing a free page would alias


def test_allocator_no_leak_over_200_request_churn():
    a = PageAllocator(16)
    rng = np.random.default_rng(0)
    held = []
    for _ in range(200):
        n = int(rng.integers(1, 6))
        got = a.alloc(n)
        if got is None:                # exhausted: free the oldest and retry
            a.free(held.pop(0))
            got = a.alloc(n)
            assert got is not None
        assert len(set(got)) == n      # never hands out a page twice
        for h in held:
            assert not set(got) & set(h)
        held.append(got)
        if len(held) > 3:
            a.free(held.pop(0))
    for h in held:
        a.free(h)
    assert a.free_pages == 16 and a.pages_in_use == 0   # everything returned


# --------------------------------------------------------------------------
# Paged kernels vs oracles (interpret mode)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("ps,n_pool,mp", [(4, 10, 5), (8, 8, 3)])
def test_qpaged_decode_matches_ref(ps, n_pool, mp):
    from repro.kernels import ref
    from repro.kernels.qpaged_attn import qpaged_decode_attn_pallas

    rng = jax.random.PRNGKey(0)
    b, hq, hkv, d = 3, 4, 2, 8
    q = jax.random.normal(rng, (b, hq, d), jnp.float32)
    kp = jax.random.randint(jax.random.fold_in(rng, 1),
                            (n_pool, ps, hkv, d), -100, 100, jnp.int8)
    vp = jax.random.randint(jax.random.fold_in(rng, 2),
                            (n_pool, ps, hkv, d), -100, 100, jnp.int8)
    perm = np.random.default_rng(1).permutation(n_pool)
    table = np.full((b, mp), -1, np.int32)
    table[0, :3] = perm[:3]            # fragmented, out-of-order pages
    table[1, :1] = perm[3:4]
    table[2, :mp] = perm[4:4 + mp]
    table = jnp.asarray(table)
    lens = jnp.asarray([2 * ps + 3, 2, mp * ps], jnp.int32)
    want = ref.qpaged_decode_attn_ref(q, kp, vp, 3, 3, table, lens)
    got = qpaged_decode_attn_pallas(q, kp, vp, jnp.int32(3), jnp.int32(3),
                                    table, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("c,start", [(4, 0), (4, 5), (6, 7), (3, 17)])
def test_qpaged_chunk_matches_ref(c, start):
    from repro.kernels import ref
    from repro.kernels.qpaged_attn import qpaged_chunk_attn_pallas

    rng = jax.random.PRNGKey(2)
    hq, hkv, d, ps, n_pool, mp = 4, 2, 8, 4, 12, 6
    q = jax.random.normal(rng, (c, hq, d), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(rng, 1), (c, hkv, d))
    vc = jax.random.normal(jax.random.fold_in(rng, 2), (c, hkv, d))
    kp = jax.random.randint(jax.random.fold_in(rng, 3),
                            (n_pool, ps, hkv, d), -100, 100, jnp.int8)
    vp = jax.random.randint(jax.random.fold_in(rng, 4),
                            (n_pool, ps, hkv, d), -100, 100, jnp.int8)
    row = jnp.asarray([7, 2, 9, 0, 5, 11], jnp.int32)   # scattered pool pages
    ro, rk, rv = ref.qpaged_chunk_attn_ref(q, kc, vc, kp, vp, 3, 3, row, start)
    go, gk, gv = qpaged_chunk_attn_pallas(q, kc, vc, kp, vp, jnp.int32(3),
                                          jnp.int32(3), row,
                                          jnp.int32(start), interpret=True)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(rv))
    np.testing.assert_allclose(np.asarray(go), np.asarray(ro),
                               rtol=1e-5, atol=1e-5)


def test_qpaged_chunk_out_of_table_rows_dropped():
    """Chunk rows past the page-table extent are dropped, never clamped
    into another logical position's page (ref oracle and Pallas agree)."""
    from repro.kernels import ref
    from repro.kernels.qpaged_attn import qpaged_chunk_attn_pallas

    rng = jax.random.PRNGKey(6)
    c, hq, hkv, d, ps, n_pool = 4, 4, 2, 8, 4, 8
    q = jax.random.normal(rng, (c, hq, d), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(rng, 1), (c, hkv, d))
    kp = jax.random.randint(jax.random.fold_in(rng, 2),
                            (n_pool, ps, hkv, d), -100, 100, jnp.int8)
    row = jnp.asarray([5, 6], jnp.int32)       # table covers 8 logical rows
    start = 6                                  # rows 8..9 fall off the table
    _, rk, _ = ref.qpaged_chunk_attn_ref(q, kc, kc, kp, kp, 3, 3, row, start)
    _, gk, _ = qpaged_chunk_attn_pallas(q, kc, kc, kp, kp, jnp.int32(3),
                                        jnp.int32(3), row, jnp.int32(start),
                                        interpret=True)
    # page 6 rows 0..1 (logical rows 8..9's clamp target) must be untouched
    np.testing.assert_array_equal(np.asarray(rk[6, :2]),
                                  np.asarray(kp[6, :2]))
    np.testing.assert_array_equal(np.asarray(gk[6, :2]),
                                  np.asarray(kp[6, :2]))


def test_qpaged_chunk_untouched_pages_pass_through():
    """Pool pages not owned by the slot survive the fused write bit-exactly
    (the in-place aliasing contract other live slots depend on)."""
    from repro.kernels import ref

    rng = jax.random.PRNGKey(5)
    c, hq, hkv, d, ps, n_pool = 4, 4, 2, 8, 4, 8
    q = jax.random.normal(rng, (c, hq, d), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(rng, 1), (c, hkv, d))
    kp = jax.random.randint(jax.random.fold_in(rng, 2),
                            (n_pool, ps, hkv, d), -100, 100, jnp.int8)
    row = jnp.asarray([3, 6, -1, -1], jnp.int32)
    _, k2, _ = ref.qpaged_chunk_attn_ref(q, kc, kc, kp, kp, 3, 3, row, 2)
    owned = {3, 6}
    for p in range(n_pool):
        if p not in owned:
            np.testing.assert_array_equal(np.asarray(k2[p]),
                                          np.asarray(kp[p]), err_msg=str(p))


# --------------------------------------------------------------------------
# Paged nn primitives
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized", [False, True], ids=["float", "int8"])
def test_paged_update_matches_dense(quantized):
    from repro.nn import attention as A

    b, ml, h, d, ps = 2, 16, 2, 4, 4
    dense = A.init_kv_cache(b, ml, h, d, quantized=quantized,
                            dtype=jnp.float32, per_slot_len=True)
    paged = A.init_paged_kv_cache(b, ml // ps, ps, b * ml // ps, h, d,
                                  quantized=quantized, dtype=jnp.float32)
    paged = A.set_page_row(paged, 0, jnp.asarray([4, 5, 6, 7], jnp.int32))
    paged = A.set_page_row(paged, 1, jnp.asarray([0, 1, 2, 3], jnp.int32))
    # slot 1 sits exactly at a page boundary (len 4, ps 4)
    dense["len"] = jnp.asarray([2, 4], jnp.int32)
    paged["len"] = jnp.asarray([2, 4], jnp.int32)
    k = jax.random.normal(jax.random.PRNGKey(0), (b, 1, h, d))
    d2 = A.update_kv_cache(dense, k, k)
    p2 = A.update_kv_cache(paged, k, k)
    np.testing.assert_array_equal(np.asarray(d2["len"]), np.asarray(p2["len"]))
    for slot in range(b):
        kd, _ = np.asarray(d2["k"][slot]), None
        kp, _ = A.gather_kv_pages(p2, slot)
        np.testing.assert_array_equal(kd[:5], np.asarray(kp)[:5])


def test_paged_evicted_slot_writes_are_dropped():
    """A slot whose pages were unmapped keeps ticking under the decode mask;
    its writes must never land in another slot's pages."""
    from repro.nn import attention as A

    b, h, d, ps = 2, 2, 4, 4
    paged = A.init_paged_kv_cache(b, 2, ps, 4, h, d, quantized=False,
                                  dtype=jnp.float32)
    paged = A.set_page_row(paged, 1, jnp.asarray([0, 1], jnp.int32))
    paged["len"] = jnp.asarray([3, 1], jnp.int32)   # slot 0 evicted (row -1)
    k = jnp.ones((b, 1, h, d))
    p2 = A.update_kv_cache(paged, k, k)
    pool = np.asarray(p2["k"])
    assert pool[0, 1].max() == 1.0                  # slot 1 wrote its row
    assert pool[0, 3].max() == 0.0                  # slot 0's write vanished
    assert pool[1:, :].max() <= 1.0


# --------------------------------------------------------------------------
# Scheduler: paged vs dense token identity + allocator behavior under load
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized_kv", [False, True],
                         ids=["fp32", "int8kv"])
def test_paged_scheduler_token_identical_to_dense(smoke_lm, quantized_kv):
    """Paged chunked admission emits exactly the dense chunked stream —
    staggered arrivals, readmission, prompt lengths that divide neither the
    chunk size nor the page size."""
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=5 + 3 * i),
                    max_new=6, arrival=i) for i in range(4)]
    dense = _engine(model, params, quantized_kv=quantized_kv)
    base, _ = dense.scheduler(chunk_size=7).run(reqs)
    paged = _engine(model, params, quantized_kv=quantized_kv,
                    paged_kv=True, page_size=8)
    got, stats = paged.scheduler(chunk_size=7).run(reqs)
    for i in range(4):
        assert got[i].tokens == base[i].tokens, (quantized_kv, i)
    assert stats.page_stalls == 0          # dense-parity pool never defers
    assert stats.peak_pages_in_use > 0
    assert 0.0 < stats.page_occupancy <= 1.0


def test_paged_int8_fused_kernel_path_identical(smoke_lm):
    """End-to-end through the fused qpaged_chunk_attn + qpaged_decode_attn
    Pallas kernels (interpret): same tokens as the gather-dense jnp path."""
    from repro.kernels import ops as kops

    cfg, model, params = smoke_lm
    eng = _engine(model, params, max_len=24, batch_slots=1, quantized_kv=True,
                  paged_kv=True, page_size=8)
    reqs = [Request(rid=0, prompt=np.arange(6, dtype=np.int32) + 2,
                    max_new=3)]
    base, _ = eng.scheduler(chunk_size=4).run(reqs)
    # tolerate an env-forced mode (the CI interpret lane sets
    # REPRO_KERNELS_FORCE=interpret for the whole process)
    prev = kops.FORCE
    kops.FORCE = "interpret"
    try:
        got, _ = eng.scheduler(chunk_size=4).run(reqs)
    finally:
        kops.FORCE = prev
    assert got[0].tokens == base[0].tokens


def test_page_exhaustion_defers_admission(smoke_lm):
    """A pool smaller than the workload's concurrent demand defers
    admissions (page_stalls > 0) instead of crashing, and every request
    still completes correctly once pages free up."""
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=8) for i in range(5)]
    dense = _engine(model, params, batch_slots=4)
    base, _ = dense.scheduler(chunk_size=4).run(reqs)
    # each request needs ceil(16/8) = 2 pages; pool of 3 fits ONE live
    # request plus nothing — admissions must wait for evictions
    eng = _engine(model, params, batch_slots=4, paged_kv=True, page_size=8,
                  kv_pool_pages=3)
    got, stats = eng.scheduler(chunk_size=4).run(reqs)
    assert stats.page_stalls > 0
    assert stats.peak_pages_in_use <= 3
    assert sorted(got) == list(range(5))
    for i in range(5):
        assert len(got[i].tokens) == 8
        # pages (not slots) were the bottleneck, so scheduling differs from
        # dense — but each request's *content* is identical (same slot-0
        # rng column semantics don't apply; tokens are deterministic given
        # the prompt prefix for temperature=0)
        assert got[i].tokens == base[i].tokens


def test_paged_scheduler_churn_reuses_pages(smoke_lm):
    """A long request churn through a pool that only holds ~2 live requests:
    completion of all requests proves freed pages are recycled; the
    allocator must end empty (no leak)."""
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=6),
                    max_new=2, arrival=i) for i in range(24)]
    eng = _engine(model, params, batch_slots=2, paged_kv=True, page_size=4,
                  kv_pool_pages=4)                 # 16 tokens resident max
    sched = eng.scheduler(chunk_size=6)
    got, stats = sched.run(reqs)
    assert sorted(got) == list(range(24))
    assert all(len(got[i].tokens) == 2 for i in range(24))
    assert stats.peak_pages_in_use <= 4


def test_evict_unmap_enqueued_before_pages_freed(smoke_lm, monkeypatch):
    """Eviction ordering: the device-side page-table unmap must be enqueued
    BEFORE the slot's pages return to the host allocator — a re-admission
    handed a freed page while the evicted row still mapped it would alias
    two slots onto one page.  Every free event must be preceded by at least
    as many unmap dispatches."""
    from repro.serve import paging

    cfg, model, params = smoke_lm
    eng = _engine(model, params, batch_slots=2, paged_kv=True, page_size=8,
                  kv_pool_pages=4)
    sched = eng.scheduler(chunk_size=4)
    events = []
    orig_evict = sched._evict
    sched._evict = lambda cache, slot: (events.append("evict"),
                                        orig_evict(cache, slot))[1]
    orig_free = paging.PageAllocator.free
    monkeypatch.setattr(
        paging.PageAllocator, "free",
        lambda self, pages: (events.append("free"),
                             orig_free(self, pages))[1])
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=6),
                    max_new=3, arrival=i) for i in range(6)]
    got, _ = sched.run(reqs)
    assert sorted(got) == list(range(6))
    assert events.count("free") == 6          # one per evicted request
    n_evict = n_free = 0
    for e in events:
        if e == "evict":
            n_evict += 1
        else:
            n_free += 1
            assert n_free <= n_evict, (
                "pages freed before the slot's unmap was enqueued")


def test_same_tick_page_reuse_is_alias_free(smoke_lm):
    """A pool so tight every admission reuses the just-evicted request's
    pages (LIFO free list): token streams must still match the dense run —
    any unmap/free misordering or stale mapping would corrupt them."""
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(13)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=6),
                    max_new=4) for i in range(6)]
    dense = _engine(model, params, batch_slots=2)
    base, _ = dense.scheduler(chunk_size=6).run(reqs)
    eng = _engine(model, params, batch_slots=2, paged_kv=True, page_size=8,
                  kv_pool_pages=2)           # exactly one live request
    got, stats = eng.scheduler(chunk_size=6).run(reqs)
    assert stats.peak_pages_in_use == 2
    assert stats.page_stalls > 0
    for i in range(6):
        assert got[i].tokens == base[i].tokens, i


def test_paged_requires_chunked_admission(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, paged_kv=True)
    with pytest.raises(ValueError, match="chunked admission"):
        eng.scheduler()


def test_paged_rejects_request_larger_than_pool(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, paged_kv=True, page_size=8, kv_pool_pages=2)
    sched = eng.scheduler(chunk_size=4)
    with pytest.raises(ValueError, match="pool"):
        sched.run([Request(rid=0, prompt=np.arange(20), max_new=8)])


def test_paged_token_budget_composes_with_page_stalls(smoke_lm):
    """token_budget deferral and page deferral are independent gates on the
    same chunk stream; with both tight the run still completes."""
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(9)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=6) for i in range(4)]
    eng = _engine(model, params, batch_slots=4, paged_kv=True, page_size=8,
                  kv_pool_pages=4)
    got, stats = eng.scheduler(chunk_size=4, token_budget=4).run(reqs)
    assert sorted(got) == list(range(4))
    assert all(len(got[i].tokens) == 6 for i in range(4))
    assert stats.stalled_chunks > 0


# --------------------------------------------------------------------------
# Buffer donation: per-tick cache updates are in place at the XLA level
# --------------------------------------------------------------------------

def test_scheduler_steps_donate_cache_buffers(smoke_lm):
    """The jitted decode step consumes (donates) its cache argument; where
    the backend supports donation the output KV buffers are the *same*
    device memory (pointer identity), not a copy."""
    cfg, model, params = smoke_lm
    eng = _engine(model, params)
    sched = eng.scheduler(chunk_size=4)
    cache = eng.new_cache(per_slot=True)
    tok = jnp.full((eng.batch_slots, 1), 0, jnp.int32)
    active = jnp.ones((eng.batch_slots,), bool)
    rng = jax.random.PRNGKey(0)
    leaves_in = [l for l in jax.tree_util.tree_leaves(cache)
                 if l.size > 1024]                 # the big K/V buffers
    ptrs_in = {l.unsafe_buffer_pointer() for l in leaves_in}
    tok2, cache2 = sched._masked_decode(eng.params, tok, cache, rng, active)
    # donation invalidates the inputs regardless of backend buffer reuse
    assert all(l.is_deleted() for l in leaves_in)
    leaves_out = [l for l in jax.tree_util.tree_leaves(cache2)
                  if l.size > 1024]
    ptrs_out = {l.unsafe_buffer_pointer() for l in leaves_out}
    reused = ptrs_in & ptrs_out
    if jax.default_backend() in ("cpu", "tpu", "gpu"):
        assert reused, "no cache buffer was reused in place"


def test_async_harvest_mode_does_not_donate_tok(smoke_lm):
    """Async mode (no eos_id) retains each step's token column until the
    end-of-run harvest — the tok argument must NOT be donated there (and the
    run must still produce correct full-length outputs)."""
    cfg, model, params = smoke_lm
    eng = _engine(model, params)
    reqs = [Request(rid=i, prompt=np.arange(6, dtype=np.int32) + i,
                    max_new=5) for i in range(2)]
    results, _ = eng.scheduler(chunk_size=3).run(reqs)   # async: no eos_id
    assert all(len(results[i].tokens) == 5 for i in range(2))


# --------------------------------------------------------------------------
# page_size default: hardware dispatch resolves to the sublane tile
# --------------------------------------------------------------------------

def test_page_size_default_resolves_by_dispatch(smoke_lm, monkeypatch):
    """With no explicit page_size, a paged engine defaults to the 128-row
    sublane tile under compiled-Pallas dispatch (one DMA per tile) and to a
    small 16-row page everywhere else; the defaults never warn, while an
    explicit sub-tile value on hardware still does."""
    import warnings

    from repro.kernels import ops as kops
    from repro.serve import engine as serve_engine

    cfg, model, params = smoke_lm

    monkeypatch.setattr(kops, "FORCE", "pallas")
    monkeypatch.setattr(serve_engine, "_small_page_warned", False)
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        eng = _engine(model, params, paged_kv=True, max_len=256)
    assert eng.page_size == serve_engine.HW_MIN_PAGE_SIZE
    assert not any(issubclass(w.category, RuntimeWarning) for w in record)

    monkeypatch.setattr(kops, "FORCE", "interpret")
    eng = _engine(model, params, paged_kv=True)
    assert eng.page_size == 16
    monkeypatch.setattr(kops, "FORCE", "ref")
    eng = _engine(model, params, paged_kv=True)
    assert eng.page_size == 16
    # dense engines keep the small default too (page_size is inert there)
    eng = _engine(model, params)
    assert eng.page_size == 16

    # the guard is about *explicit* small values, not the defaults
    monkeypatch.setattr(kops, "FORCE", "pallas")
    monkeypatch.setattr(serve_engine, "_small_page_warned", False)
    with pytest.warns(RuntimeWarning, match="page_size"):
        _engine(model, params, paged_kv=True, page_size=8)


def test_page_size_zero_or_negative_rejected(smoke_lm):
    cfg, model, params = smoke_lm
    for bad in (0, -4):
        with pytest.raises(ValueError, match="page_size"):
            _engine(model, params, paged_kv=True, page_size=bad)
