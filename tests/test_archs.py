"""Per-architecture smoke tests: reduced same-family configs on CPU.

Each assigned arch instantiates its smoke config, runs one forward/train step
and one prefill+decode step, asserting output shapes and the absence of NaNs
(deliverable (f)).  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.policy import QuantPolicy
from repro.models.registry import get_config, list_archs
from repro.nn.module import eval_context, train_context
from repro.optim import sgd
from repro.train.trainer import make_train_step

ARCHS = list_archs()


def _batch(cfg, b=2, s=16):
    toks = jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) % cfg.vocab
    batch = {"tokens": toks, "labels": toks}
    if cfg.is_encdec or cfg.vis_seq:
        n = cfg.enc_seq if cfg.is_encdec else cfg.vis_seq
        batch["embeds"] = jnp.ones((b, n, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch + "-smoke")
    model = cfg.build(dtype=jnp.float32, remat="none")
    optimizer = sgd(momentum=0.9)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": optimizer.init(params),
             "step": jnp.zeros((), jnp.int32)}
    step = jax.jit(make_train_step(model, optimizer, 0.01))
    state, metrics = step(state, _batch(cfg))
    assert jnp.isfinite(metrics["loss"]), arch
    assert int(state["step"]) == 1
    # params actually changed
    leaves0 = jax.tree_util.tree_leaves(params)
    leaves1 = jax.tree_util.tree_leaves(state["params"])
    assert any(not jnp.allclose(a, b) for a, b in zip(leaves0, leaves1))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = get_config(arch + "-smoke")
    model = cfg.build(dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    ctx = eval_context()
    logits, _ = model.apply(params, batch["tokens"], ctx,
                            embeds=batch.get("embeds"))
    exp_s = s + (cfg.vis_seq if cfg.vis_seq else 0)
    assert logits.shape == (b, exp_s, cfg.vocab_padded), arch
    assert not jnp.any(jnp.isnan(logits)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch + "-smoke")
    model = cfg.build(dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    b, s, max_len = 2, 8, 24
    toks = jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) % cfg.vocab
    cache = model.init_cache(b, max_len, quantized_kv=False,
                             kv_dtype=jnp.float32)
    ctx = eval_context()
    kw = {}
    if cfg.is_encdec:
        kw["enc"] = model.encode(params, jnp.ones((b, 16, cfg.d_model),
                                                  jnp.float32), ctx)
    logits, cache = model.apply(params, toks, ctx, cache=cache, decode=True,
                                **kw)
    assert logits.shape == (b, s, cfg.vocab_padded)
    for _ in range(3):
        nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        logits, cache = model.apply(params, nxt, ctx, cache=cache,
                                    decode=True, **kw)
        assert logits.shape == (b, 1, cfg.vocab_padded)
        assert not jnp.any(jnp.isnan(logits)), arch


@pytest.mark.parametrize("arch", ["smollm-135m", "phi3.5-moe-42b-a6.6b",
                                  "jamba-v0.1-52b", "rwkv6-7b"])
def test_smoke_qat_grads(arch):
    """QAT fake-quant forward + STE backward produce finite grads."""
    cfg = get_config(arch + "-smoke")
    model = cfg.build(dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        ctx = train_context(QuantPolicy.int8_qat(), rng=jax.random.PRNGKey(1))
        return model.loss(p, batch, ctx)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in gleaves)
    # at least the embedding gradient is nonzero
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in gleaves)


def test_decode_matches_prefill():
    """Incremental decode must agree with a full forward (cache correctness)."""
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="none")
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = (jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) * 7) % cfg.vocab
    ctx = eval_context()
    full_logits, _ = model.apply(params, toks, ctx)

    cache = model.init_cache(b, s, quantized_kv=False, kv_dtype=jnp.float32)
    logits, cache = model.apply(params, toks[:, :5], ctx, cache=cache,
                                decode=True)
    assert jnp.allclose(logits, full_logits[:, :5], atol=2e-4), "prefill"
    for t in range(5, s):
        step_logits, cache = model.apply(params, toks[:, t:t + 1], ctx,
                                         cache=cache, decode=True)
        assert jnp.allclose(step_logits[:, 0], full_logits[:, t],
                            atol=5e-4), f"decode t={t}"


def test_param_counts_match_analytic():
    """ArchConfig.param_count tracks the real tree within 2%."""
    for arch in ["smollm-135m", "rwkv6-7b", "kimi-k2-1t-a32b"]:
        cfg = get_config(arch + "-smoke")
        model = cfg.build(dtype=jnp.float32)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        real = sum(int(jnp.prod(jnp.array(l.shape)))
                   for l in jax.tree_util.tree_leaves(params))
        approx = cfg.param_count()
        # padded vocab + norms are not in the analytic count; loose bound
        assert abs(real - approx) / real < 0.15, (arch, real, approx)
