"""Prefix sharing over the paged KV pool: the PrefixIndex, refcounted
share/free, copy-on-write at the divergence page, token identity of shared
vs unshared vs dense streams (fp32 + int8 KV, incl. the fused Pallas kernels
in interpret mode), and the capacity win at equal pool bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config
from repro.serve import Request, ServeEngine
from repro.serve.paging import PageAllocator, PrefixIndex


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_len", 48)
    kw.setdefault("batch_slots", 4)
    return ServeEngine(model=model, params=params, **kw)


def _shared_workload(vocab, *, n_prompts=1, n_requests=4, sys_len=24,
                     suffix=8, max_new=8, spacing=1, seed=3):
    """Requests over ``n_prompts`` system prompts with divergent suffixes."""
    rng = np.random.default_rng(seed)
    sys_prompts = [rng.integers(0, vocab, size=sys_len, dtype=np.int32)
                   for _ in range(n_prompts)]
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_prompts[i % n_prompts],
                         rng.integers(0, vocab, size=suffix,
                                      dtype=np.int32)]),
                    max_new=max_new, arrival=i * spacing)
            for i in range(n_requests)]


# --------------------------------------------------------------------------
# PrefixIndex
# --------------------------------------------------------------------------

def test_prefix_index_longest_chain_and_cumulative_hashing():
    ix = PrefixIndex(4)
    a = np.arange(16, dtype=np.int32)              # 4 full pages
    ix.insert(a, [7, 2, 9, 5])
    # full match, partial match, divergence mid-chain
    assert ix.match(a) == [7, 2, 9, 5]
    assert ix.match(a[:10]) == [7, 2]              # only full pages match
    b = a.copy()
    b[5] = 99                                      # diverge in page 1
    assert ix.match(b) == [7]
    # cumulative hashing: identical page content under a different opening
    # can never alias
    c = a.copy()
    c[0] = 99                                      # page 0 differs...
    assert ix.match(c) == []                       # ...pages 1..3 never match


def test_prefix_index_first_writer_wins_and_drop():
    ix = PrefixIndex(4)
    a = np.arange(8, dtype=np.int32)
    ix.insert(a, [1, 2])
    ix.insert(a, [5, 6])                           # duplicate prefill copy
    assert ix.match(a) == [1, 2]                   # canonical pages kept
    ix.drop_pages([1])                             # owner's page released
    assert ix.match(a) == []                       # chain broken at page 0
    ix.drop_pages([2, 3])                          # idempotent / unknown ok


def test_allocator_share_keeps_pages_live():
    a = PageAllocator(6)
    donor = a.alloc(4)
    a.share(donor[:3])                             # a sharer maps the prefix
    assert a.free(donor) == [donor[3]]             # private page released
    assert a.pages_in_use == 3                     # shared prefix survives
    assert sorted(a.free(donor[:3])) == sorted(donor[:3])
    assert a.free_pages == 6


# --------------------------------------------------------------------------
# Scheduler: shared admissions — identity, stats, capacity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized_kv", [False, True],
                         ids=["fp32", "int8kv"])
def test_shared_prefix_token_identity(smoke_lm, quantized_kv):
    """Same system prompt, divergent suffixes and continuations: the shared
    run must emit exactly the dense and unshared-paged streams, while
    actually mapping shared pages."""
    cfg, model, params = smoke_lm
    reqs = _shared_workload(cfg.vocab)
    dense = _engine(model, params, quantized_kv=quantized_kv)
    base, _ = dense.scheduler(chunk_size=8).run(reqs)
    paged = _engine(model, params, quantized_kv=quantized_kv,
                    paged_kv=True, page_size=8)
    shared, s_st = paged.scheduler(chunk_size=8).run(reqs)
    unshared, u_st = paged.scheduler(chunk_size=8,
                                     prefix_sharing=False).run(reqs)
    for i in range(len(reqs)):
        assert shared[i].tokens == base[i].tokens, (quantized_kv, i)
        assert unshared[i].tokens == base[i].tokens, (quantized_kv, i)
    assert s_st.prefix_hits > 0
    assert s_st.shared_pages_mapped > 0
    assert u_st.prefix_hits == 0
    assert s_st.peak_pages_in_use < u_st.peak_pages_in_use


def test_full_prompt_duplicate_triggers_cow(smoke_lm):
    """An identical prompt whose full extent is resident must COW the final
    page (it re-runs the last token for its first-token logits) — and both
    the donor's and the sharer's streams must match the dense run."""
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(7)
    p = rng.integers(0, cfg.vocab, size=16, dtype=np.int32)  # 2 full pages
    reqs = [Request(rid=0, prompt=p, max_new=6, arrival=0),
            Request(rid=1, prompt=p, max_new=6, arrival=1)]
    dense = _engine(model, params)
    base, _ = dense.scheduler(chunk_size=8).run(reqs)
    paged = _engine(model, params, paged_kv=True, page_size=8)
    got, stats = paged.scheduler(chunk_size=8).run(reqs)
    assert stats.cow_copies == 1
    assert stats.prefix_hits == 1
    assert stats.shared_pages_mapped == 1      # page 0 shared, page 1 COW'd
    assert got[0].tokens == base[0].tokens     # donor unharmed by the share
    assert got[1].tokens == base[1].tokens     # sharer bit-identical too


def test_sharing_survives_donor_eviction(smoke_lm):
    """The donor finishes while sharers are live: its shared pages must stay
    resident (refcount) and indexed, so later same-prefix requests keep
    matching; streams stay identical to dense."""
    cfg, model, params = smoke_lm
    reqs = _shared_workload(cfg.vocab, n_requests=6, max_new=4, spacing=3)
    dense = _engine(model, params, batch_slots=6)
    base, _ = dense.scheduler(chunk_size=8).run(reqs)
    paged = _engine(model, params, batch_slots=6, paged_kv=True, page_size=8)
    got, stats = paged.scheduler(chunk_size=8).run(reqs)
    for i in range(6):
        assert got[i].tokens == base[i].tokens, i
    assert stats.prefix_hits >= 2


def test_sharing_raises_concurrency_at_equal_pool(smoke_lm):
    """The tentpole's point: at the same pool bytes, sharing admits more
    concurrent requests than the unshared paged baseline."""
    cfg, model, params = smoke_lm
    reqs = _shared_workload(cfg.vocab, n_requests=6, sys_len=24, suffix=8,
                            max_new=8)
    # each request: extent max(32 chunk-padded, 40) -> 5 pages of 8;
    # shared admissions allocate only 2 fresh pages (3 shared)
    eng = _engine(model, params, batch_slots=6, paged_kv=True, page_size=8,
                  kv_pool_pages=11)
    shared, s_st = eng.scheduler(chunk_size=8).run(reqs)
    unshared, u_st = eng.scheduler(chunk_size=8,
                                   prefix_sharing=False).run(reqs)
    assert sorted(shared) == sorted(unshared) == list(range(6))
    for i in range(6):
        assert shared[i].tokens == unshared[i].tokens, i
    assert u_st.peak_live_slots == 2           # 11 pages / 5 per request
    assert s_st.peak_live_slots >= 3           # donor 5 + sharers 2 each
    assert s_st.page_stalls < u_st.page_stalls


def test_shared_prefix_int8_interpret_e2e(smoke_lm):
    """Sharing + COW end-to-end through the fused qpaged Pallas kernels in
    interpret mode: identical streams to the ref-oracle dispatch."""
    from repro.kernels import ops as kops

    cfg, model, params = smoke_lm
    rng = np.random.default_rng(9)
    sysp = rng.integers(0, cfg.vocab, size=16, dtype=np.int32)
    reqs = [Request(rid=0, prompt=sysp, max_new=3, arrival=0),
            Request(rid=1,
                    prompt=np.concatenate(
                        [sysp, rng.integers(0, cfg.vocab, size=4,
                                            dtype=np.int32)]),
                    max_new=3, arrival=1)]
    eng = _engine(model, params, max_len=32, batch_slots=2,
                  quantized_kv=True, paged_kv=True, page_size=8)
    base, b_st = eng.scheduler(chunk_size=4).run(reqs)
    prev = kops.FORCE
    kops.FORCE = "interpret"
    try:
        got, stats = eng.scheduler(chunk_size=4).run(reqs)
    finally:
        kops.FORCE = prev
    assert stats.prefix_hits == b_st.prefix_hits == 1
    assert got[0].tokens == base[0].tokens
    assert got[1].tokens == base[1].tokens


def test_unshared_flag_disables_sharing(smoke_lm):
    cfg, model, params = smoke_lm
    reqs = _shared_workload(cfg.vocab)
    eng = _engine(model, params, paged_kv=True, page_size=8)
    _, stats = eng.scheduler(chunk_size=8, prefix_sharing=False).run(reqs)
    assert stats.prefix_hits == 0
    assert stats.shared_pages_mapped == 0
    assert stats.cow_copies == 0
