"""Unit tests for the sharding-rule inference (divisibility, dedupe, prefix
fallback, serve orientation) — pure spec logic, no device mesh required
beyond the default 1-CPU (specs are constructed, not applied)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd

pytestmark = pytest.mark.skipif(
    len(jax.devices()) != 1, reason="spec-only tests assume default device")


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    # AbstractMesh carries shapes/names without real devices
    from repro.dist.compat import abstract_mesh

    return abstract_mesh(shape, axes)


def test_divisibility_drops_axis():
    mesh = fake_mesh()
    rules = shd.make_axis_rules(mesh)
    # 9 heads can't shard 16 ways -> replicated; 1536 ff can
    spec = shd._spec_for_path("attn/wq/kernel", (576, 576), rules, mesh)
    assert spec == P("data", "model")
    spec = shd._spec_for_path("attn/wq/kernel", (576, 9), rules, mesh)
    assert spec == P("data", None)


def test_scan_stacked_leading_dims_replicate():
    mesh = fake_mesh()
    rules = shd.make_axis_rules(mesh)
    spec = shd._spec_for_path("stack/body/0/ffn/w_gate/kernel",
                              (30, 576, 1536), rules, mesh)
    assert spec == P(None, "data", "model")


def test_expert_orientation_train_vs_serve():
    mesh = fake_mesh()
    rules = shd.make_axis_rules(mesh)
    shape = (60, 384, 7168, 2048)
    train = shd._spec_for_path("ffn/experts/w_gate/kernel", shape, rules,
                               mesh, serve=False)
    serve = shd._spec_for_path("ffn/experts/w_gate/kernel", shape, rules,
                               mesh, serve=True)
    assert train == P(None, "model", None, "data")   # FSDP on F (train)
    assert serve == P(None, "model", "data", None)   # FSDP on D (decode)


def test_router_replicated():
    mesh = fake_mesh()
    rules = shd.make_axis_rules(mesh)
    spec = shd._spec_for_path("moe/router/kernel", (7168, 384), rules, mesh)
    assert spec == P()


def test_batch_prefix_fallback():
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    rules = shd.make_axis_rules(mesh, dp_only=True)
    # 256 % 512 != 0 -> longest divisible prefix ("data","model") = 256-way
    fit = shd._fit(mesh, rules["batch"], 256)
    assert fit == ("data", "model")
    # fully divisible batch uses all three axes
    assert shd._fit(mesh, rules["batch"], 512) == ("data", "model", "pod")
    # prime batch replicates
    assert shd._fit(mesh, rules["batch"], 7) is None


def test_dedupe_drops_second_use():
    assert shd._dedupe(("model", "model", None)) == ("model", None, None)
    assert shd._dedupe((("data", "model"), "model")) == (("data", "model"),
                                                         None)
    assert shd._dedupe((None, "data", "model")) == (None, "data", "model")


def test_cache_specs_kv_seq_sharded():
    mesh = fake_mesh()
    rules = shd.make_axis_rules(mesh)
    cache = {"kv": {"k": jax.ShapeDtypeStruct((64, 128, 32768, 8, 128),
                                              jnp.bfloat16),
                    "len": jax.ShapeDtypeStruct((), jnp.int32)}}
    specs = shd.cache_pspecs(cache, mesh, rules)
    assert specs["kv"]["k"].spec == P(None, "data", "model", None, None)
    assert specs["kv"]["len"].spec == P()


def test_qtensor_param_specs():
    from repro.core.qformat import QTensor

    mesh = fake_mesh()
    rules = shd.make_axis_rules(mesh)
    qt = QTensor(q=jax.ShapeDtypeStruct((7168, 2048), jnp.int8),
                 n=jax.ShapeDtypeStruct((2048,), jnp.int32),
                 width=8, channel_axis=1)
    specs = shd.param_pspecs({"ffn": {"w_gate": {"kernel": qt}}}, mesh, rules)
    out = specs["ffn"]["w_gate"]["kernel"]
    assert out.q.spec == P("data", "model")
    assert out.n.spec == P("model")   # per-channel exponents ride the N axis
