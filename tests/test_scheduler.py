"""Continuous-batching scheduler tests: token identity vs the lockstep
baseline, queued-request admission into freed slots, EOS eviction mid-stream,
and the per-slot KV cache primitives underneath (fp32 and int8 KV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config
from repro.serve import Request, ServeEngine, run_restart_batching


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_len", 32)
    kw.setdefault("batch_slots", 2)
    return ServeEngine(model=model, params=params, **kw)


# --------------------------------------------------------------------------
# Token identity: simultaneous equal-length arrivals == lockstep generate()
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized_kv", [False, True],
                         ids=["fp32", "int8kv"])
def test_scheduler_token_identical_to_lockstep(smoke_lm, quantized_kv):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, quantized_kv=quantized_kv)
    prompts = (jnp.arange(2 * 8, dtype=jnp.int32).reshape(2, 8) * 7) % cfg.vocab
    base = np.asarray(eng.generate(prompts, 10))

    reqs = [Request(rid=i, prompt=np.asarray(prompts[i]), max_new=10)
            for i in range(2)]
    results, stats = eng.scheduler().run(reqs)
    for i in range(2):
        assert results[i].tokens == list(base[i]), (quantized_kv, i)
    assert stats.occupancy == 1.0
    assert stats.tokens_out == 20


def test_scheduler_weight_quant_variant_runs(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, weight_quant=True, quantized_kv=True)
    results, _ = eng.scheduler().run(
        [Request(rid=0, prompt=np.arange(6), max_new=5)])
    assert len(results[0].tokens) == 5
    assert max(results[0].tokens) < cfg.vocab


# --------------------------------------------------------------------------
# Admission into freed slots
# --------------------------------------------------------------------------

def test_queued_requests_admitted_into_freed_slots(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params)
    rng = np.random.default_rng(0)
    # 5 requests, 2 slots, all at t=0: three must wait for a freed slot.
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=4) for i in range(5)]
    results, stats = eng.scheduler().run(reqs)
    assert sorted(results) == list(range(5))
    assert all(len(results[i].tokens) == 4 for i in range(5))
    # first two admitted immediately; the rest only after an eviction
    assert results[0].admitted_at == 0 and results[1].admitted_at == 0
    for i in (2, 3, 4):
        assert results[i].admitted_at >= min(results[0].finished_at,
                                             results[1].finished_at)
    # never more than batch_slots in flight
    live = [(r.admitted_at, r.finished_at) for r in results.values()]
    for t in range(max(f for _, f in live) + 1):
        assert sum(a <= t < f for a, f in live) <= eng.batch_slots


def test_staggered_arrivals_and_prompt_bucketing(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params)
    rng = np.random.default_rng(1)
    # ragged prompt lengths share compiles via bucket=8; arrivals staggered
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=3 + i),
                    max_new=3, arrival=2 * i) for i in range(4)]
    results, _ = eng.scheduler(prompt_bucket=8).run(reqs)
    assert sorted(results) == list(range(4))
    for i in range(4):
        assert len(results[i].tokens) == 3
        assert results[i].admitted_at >= results[i].arrival


# --------------------------------------------------------------------------
# Chunked-prefill admission (the mixed step)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized_kv", [False, True],
                         ids=["fp32", "int8kv"])
@pytest.mark.parametrize("chunk", [4, 7])
def test_chunked_prefill_token_identity(smoke_lm, quantized_kv, chunk):
    """Chunked admission is token-identical to one-shot prefill admission —
    per-slot prompt lengths, staggered arrivals, readmission into freed
    slots, and chunk sizes that do NOT divide the prompt lengths."""
    cfg, model, params = smoke_lm
    eng = _engine(model, params, max_len=48, quantized_kv=quantized_kv)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=5 + 3 * i),
                    max_new=6, arrival=i) for i in range(4)]
    base, _ = eng.scheduler().run(reqs)
    got, stats = eng.scheduler(chunk_size=chunk).run(reqs)
    for i in range(4):
        assert got[i].tokens == base[i].tokens, (quantized_kv, chunk, i)
    # every prompt was really chunked: sum of per-request ceil(P/C) chunks
    want_chunks = sum(-(-(5 + 3 * i) // chunk) for i in range(4))
    assert stats.prefill_chunks == want_chunks
    assert stats.admission_stalls == 0


def test_chunked_matches_lockstep_generate(smoke_lm):
    """Simultaneous equal-length arrivals through chunked admission still
    reproduce lockstep generate() exactly (the PR 2 identity, now one more
    admission policy deep)."""
    cfg, model, params = smoke_lm
    eng = _engine(model, params)
    prompts = (jnp.arange(2 * 8, dtype=jnp.int32).reshape(2, 8) * 7) % cfg.vocab
    base = np.asarray(eng.generate(prompts, 10))
    reqs = [Request(rid=i, prompt=np.asarray(prompts[i]), max_new=10)
            for i in range(2)]
    results, _ = eng.scheduler(chunk_size=3).run(reqs)
    for i in range(2):
        assert results[i].tokens == list(base[i])


def test_chunked_admission_compiles_o1_shapes(smoke_lm):
    """The bucket-explosion regression PR 2 left open: one-shot admission
    compiles one slot-prefill per distinct prompt length; chunked admission
    compiles O(1) step shapes — the count over 7 distinct lengths equals the
    count over 1 and stays a small constant."""
    if not hasattr(jax.jit(lambda: 0), "_cache_size"):
        pytest.skip("jax version does not expose jit cache sizes")
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(4)

    def reqs_for(lens):
        return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=p),
                        max_new=3) for i, p in enumerate(lens)]

    lens7 = [3, 5, 8, 11, 14, 17, 21]         # 7 distinct lengths

    def chunked_compiles(lens):
        _, st = _engine(model, params, max_len=64).scheduler(
            chunk_size=8).run(reqs_for(lens))
        return st.num_jit_compiles

    n1, n7 = chunked_compiles([11]), chunked_compiles(lens7)
    assert n7 == n1, (n1, n7)                 # O(1) in distinct lengths
    assert n7 <= 8, n7                        # and a small constant

    _, oneshot = _engine(model, params, max_len=64).scheduler().run(
        reqs_for(lens7))
    assert oneshot.num_jit_compiles >= len(lens7)   # one compile per length
    assert n7 < oneshot.num_jit_compiles
    assert oneshot.admission_stalls > 0       # the stop-the-world telltale


def test_chunked_token_budget_defers_chunks(smoke_lm):
    """token_budget below live-decode+chunk defers admission chunks (decode
    tokens are never dropped) and the run still completes correctly."""
    cfg, model, params = smoke_lm
    eng = _engine(model, params, max_len=48, batch_slots=4)
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=8) for i in range(6)]
    base, _ = eng.scheduler().run(reqs)
    # budget 4 == chunk_size: a chunk only rides when no slot decodes beside
    # it, so every admission past the first defers at least once
    got, stats = eng.scheduler(chunk_size=4, token_budget=4).run(reqs)
    for i in range(6):
        assert got[i].tokens == base[i].tokens
    assert stats.stalled_chunks > 0

    with pytest.raises(ValueError, match="token_budget"):
        eng.scheduler(chunk_size=8, token_budget=4)
    with pytest.raises(ValueError, match="chunk_size"):
        eng.scheduler(token_budget=4)


def test_chunked_int8_fused_kernel_path_identical(smoke_lm):
    """End-to-end through the fused qchunk_attn Pallas kernel (interpret):
    in-place quantize-on-write admission emits the same tokens as the
    blocked-jnp chunk path."""
    from repro.kernels import ops as kops

    cfg, model, params = smoke_lm
    eng = _engine(model, params, max_len=24, batch_slots=1,
                  quantized_kv=True)
    reqs = [Request(rid=0, prompt=np.arange(6, dtype=np.int32) + 2,
                    max_new=3)]
    base, _ = eng.scheduler(chunk_size=4).run(reqs)
    assert kops.FORCE is None
    kops.FORCE = "interpret"
    try:
        got, _ = eng.scheduler(chunk_size=4).run(reqs)
    finally:
        kops.FORCE = None
    assert got[0].tokens == base[0].tokens


def test_chunked_rejects_overlong_prompt(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, max_len=16)
    sched = eng.scheduler(chunk_size=6)
    # plen 13 pads to 18 chunk rows > max_len 16 even though 13 + 2 fits
    with pytest.raises(ValueError, match="chunk-padded"):
        sched.run([Request(rid=0, prompt=np.arange(13), max_new=2)])


def test_chunked_eos_evicts_and_readmits(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, batch_slots=1)
    prompt = np.arange(8, dtype=np.int32)
    free_run, _ = eng.scheduler(chunk_size=3).run(
        [Request(rid=0, prompt=prompt, max_new=8)])
    eos = free_run[0].tokens[2]

    reqs = [Request(rid=0, prompt=prompt, max_new=8),
            Request(rid=1, prompt=prompt + 1, max_new=3)]
    results, _ = eng.scheduler(eos_id=eos, chunk_size=3).run(reqs)
    assert results[0].eos is True
    assert results[0].tokens[-1] == eos
    assert len(results[0].tokens) <= 3
    assert results[1].admitted_at >= results[0].finished_at
    assert len(results[1].tokens) == 3


# --------------------------------------------------------------------------
# EOS eviction mid-stream
# --------------------------------------------------------------------------

def test_eos_evicts_slot_and_readmits(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, batch_slots=1)
    prompt = np.arange(8, dtype=np.int32)
    # discover what the model will emit, then declare token #2 to be EOS
    free_run, _ = eng.scheduler().run(
        [Request(rid=0, prompt=prompt, max_new=8)])
    eos = free_run[0].tokens[2]
    assert free_run[0].tokens.count(eos) >= 1

    reqs = [Request(rid=0, prompt=prompt, max_new=8),
            Request(rid=1, prompt=prompt + 1, max_new=3)]
    results, _ = eng.scheduler(eos_id=eos).run(reqs)
    # request 0 stops at the first eos (position 2), not at max_new
    assert results[0].eos is True
    assert results[0].tokens[-1] == eos
    assert len(results[0].tokens) <= 3
    # the freed slot served request 1 afterwards
    assert results[1].admitted_at >= results[0].finished_at
    assert len(results[1].tokens) == 3


# --------------------------------------------------------------------------
# Restart-the-batch baseline semantics (bench comparison point)
# --------------------------------------------------------------------------

def test_restart_batching_matches_lockstep_tokens(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params)
    prompts = (jnp.arange(2 * 8, dtype=jnp.int32).reshape(2, 8) * 3) % cfg.vocab
    base = np.asarray(eng.generate(prompts, 6))
    results, stats = run_restart_batching(
        eng, [Request(rid=i, prompt=np.asarray(prompts[i]), max_new=6)
              for i in range(2)])
    for i in range(2):
        assert results[i].tokens == list(base[i])
    # everyone waits for the longest request: one shared finish tick
    assert results[0].finished_at == results[1].finished_at


# --------------------------------------------------------------------------
# Per-slot cache primitives
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized", [False, True], ids=["float", "int8"])
def test_per_slot_cache_independent_offsets(quantized):
    from repro.nn.attention import init_kv_cache, update_kv_cache

    cache = init_kv_cache(2, 8, 2, 4, quantized=quantized,
                          dtype=jnp.float32, per_slot_len=True)
    cache["len"] = jnp.asarray([0, 3], jnp.int32)
    k = jnp.ones((2, 1, 2, 4)) * jnp.asarray([1.0, 2.0])[:, None, None, None]
    cache = update_kv_cache(cache, k, k)
    np.testing.assert_array_equal(np.asarray(cache["len"]), [1, 4])
    kf = np.asarray(cache["k"], np.float32)
    assert kf[0, 0, 0, 0] != 0          # slot 0 wrote at its own offset 0
    assert kf[1, 3, 0, 0] != 0          # slot 1 wrote at its own offset 3
    assert kf[1, 0, 0, 0] == 0          # and not at slot 0's offset


def test_per_slot_decode_attention_masks_each_slot():
    from repro.nn.attention import decode_attention

    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 1, 4, 8))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 6, 2, 8))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 6, 2, 8))
    lens = jnp.asarray([2, 5], jnp.int32)
    out = decode_attention(q, k, v, lens)
    # per-row scalar-length computation must agree exactly
    for i, ln in enumerate([2, 5]):
        ref = decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                               jnp.int32(ln))
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref[0]),
                                   rtol=1e-6)


def test_qdecode_kernel_per_slot_lengths():
    """Pallas (interpret) and ref agree on per-slot kv_len masking."""
    from repro.kernels.qdecode_attn import qdecode_attn_pallas
    from repro.kernels.ref import qdecode_attn_ref

    rng = jax.random.PRNGKey(3)
    q = jax.random.normal(rng, (2, 4, 8), jnp.float32)
    kc = jax.random.randint(jax.random.fold_in(rng, 1), (2, 8, 2, 8),
                            -100, 100, jnp.int8)
    vc = jax.random.randint(jax.random.fold_in(rng, 2), (2, 8, 2, 8),
                            -100, 100, jnp.int8)
    lens = jnp.asarray([3, 7], jnp.int32)
    ref = qdecode_attn_ref(q, kc, vc, 3, 3, lens)
    out = qdecode_attn_pallas(q, kc, vc, jnp.int32(3), jnp.int32(3), lens,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # scalar kv_len still broadcasts (lockstep path unchanged)
    ref_s = qdecode_attn_ref(q, kc, vc, 3, 3, jnp.int32(5))
    out_s = qdecode_attn_pallas(q, kc, vc, jnp.int32(3), jnp.int32(3),
                                jnp.int32(5), interpret=True)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(ref_s),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Observers: calibration range accumulation (core/observers.py)
# --------------------------------------------------------------------------

def test_minmax_observer_permutation_invariant():
    """Shuffling calibration batches cannot change a min-max range."""
    from repro.core.observers import make_observer

    rng = np.random.default_rng(0)
    stream = [{"a": jnp.float32(v), "b": jnp.float32(w)}
              for v, w in rng.uniform(0.1, 9.0, size=(8, 2))]
    fwd, rev = make_observer("minmax"), make_observer("minmax")
    for s in stream:
        fwd.observe(s)
    for s in reversed(stream):
        rev.observe(s)
    for k in ("a", "b"):
        want = max(float(s[k]) for s in stream)
        assert float(fwd.ranges[k]) == pytest.approx(want)
        assert float(fwd.ranges[k]) == float(rev.ranges[k])


def test_ema_observer_converges_to_stream_range():
    """First batch seeds directly; a constant tail pulls the EMA to the
    stream's running range geometrically (decay^t), and one outlier moves
    it by only (1 - decay) of its excess."""
    from repro.core.observers import EMAObserver

    obs = EMAObserver(decay=0.9)
    obs.observe({"x": jnp.float32(100.0)})       # outlier seed
    for _ in range(60):
        obs.observe({"x": jnp.float32(2.0)})
    assert float(obs.ranges["x"]) == pytest.approx(
        2.0 + 0.9 ** 60 * 98.0, rel=1e-5)

    single = EMAObserver(decay=0.9)
    single.observe({"x": jnp.float32(2.0)})
    assert float(single.ranges["x"]) == pytest.approx(2.0)   # direct seed
    single.observe({"x": jnp.float32(100.0)})
    assert float(single.ranges["x"]) == pytest.approx(0.9 * 2.0 + 0.1 * 100.0)


def test_make_observer_rejects_unknown_kind():
    from repro.core import observers

    with pytest.raises(ValueError, match="unknown observer"):
        observers.make_observer("percentile")
    inst = observers.EMAObserver(decay=0.5)
    assert observers.make_observer(inst) is inst   # pass-through


def test_calibrate_qstate_reproduces_observed_ranges():
    """calibrate() through an observer lands on the same frozen exponents as
    hand-folding the stream's max-|x| into frac_bits_for — and the ema
    strategy shrugs off a spike that minmax must honor."""
    from repro.core import qformat
    from repro.core.policy import QMode, QuantPolicy
    from repro.core.ptq import calibrate

    def apply_fn(params, batch, ctx):
        ctx.record("act", batch)

    policy = QuantPolicy(mode=QMode.EVAL, weight_bits=8, act_bits=8)
    batches = [jnp.full((4,), v, jnp.float32)
               for v in (0.5, 0.9, 0.7, 0.6, 0.8)]
    qstate = calibrate(apply_fn, {}, batches, policy)
    (site, n), = qstate.items()
    want = qformat.frac_bits_for(jnp.float32(0.9), policy.act_bits)
    assert int(n) == int(want)

    spiked = batches + [jnp.full((4,), 200.0, jnp.float32)] + batches * 4
    n_minmax = next(iter(calibrate(apply_fn, {}, spiked, policy).values()))
    n_ema = next(iter(calibrate(apply_fn, {}, spiked, policy,
                                observer="ema").values()))
    assert int(n_minmax) == int(
        qformat.frac_bits_for(jnp.float32(200.0), policy.act_bits))
    assert int(n_ema) > int(n_minmax)   # ema keeps a finer grid past a spike


def test_scheduler_int4_weights_token_identical_repeat(smoke_lm):
    """Packed int4-per-block weights serve deterministically: a rebuilt
    engine over the same params replays the exact token stream."""
    cfg, model, params = smoke_lm

    def reqs():
        return [Request(rid=i,
                        prompt=np.asarray((np.arange(8) * 3 + i) % cfg.vocab,
                                          np.int32),
                        max_new=8) for i in range(2)]

    runs = []
    for _ in range(2):
        eng = _engine(model, params, weight_quant="int4-block",
                      weight_block=32)
        results, _ = eng.scheduler().run(reqs())
        runs.append({i: results[i].tokens for i in range(2)})
    assert runs[0] == runs[1]
    assert all(0 <= t < cfg.vocab for toks in runs[0].values() for t in toks)
