"""Continuous-batching scheduler tests: token identity vs the lockstep
baseline, queued-request admission into freed slots, EOS eviction mid-stream,
and the per-slot KV cache primitives underneath (fp32 and int8 KV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config
from repro.serve import Request, ServeEngine, run_restart_batching


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_len", 32)
    kw.setdefault("batch_slots", 2)
    return ServeEngine(model=model, params=params, **kw)


# --------------------------------------------------------------------------
# Token identity: simultaneous equal-length arrivals == lockstep generate()
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized_kv", [False, True],
                         ids=["fp32", "int8kv"])
def test_scheduler_token_identical_to_lockstep(smoke_lm, quantized_kv):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, quantized_kv=quantized_kv)
    prompts = (jnp.arange(2 * 8, dtype=jnp.int32).reshape(2, 8) * 7) % cfg.vocab
    base = np.asarray(eng.generate(prompts, 10))

    reqs = [Request(rid=i, prompt=np.asarray(prompts[i]), max_new=10)
            for i in range(2)]
    results, stats = eng.scheduler().run(reqs)
    for i in range(2):
        assert results[i].tokens == list(base[i]), (quantized_kv, i)
    assert stats.occupancy == 1.0
    assert stats.tokens_out == 20


def test_scheduler_weight_quant_variant_runs(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, weight_quant=True, quantized_kv=True)
    results, _ = eng.scheduler().run(
        [Request(rid=0, prompt=np.arange(6), max_new=5)])
    assert len(results[0].tokens) == 5
    assert max(results[0].tokens) < cfg.vocab


# --------------------------------------------------------------------------
# Admission into freed slots
# --------------------------------------------------------------------------

def test_queued_requests_admitted_into_freed_slots(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params)
    rng = np.random.default_rng(0)
    # 5 requests, 2 slots, all at t=0: three must wait for a freed slot.
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=4) for i in range(5)]
    results, stats = eng.scheduler().run(reqs)
    assert sorted(results) == list(range(5))
    assert all(len(results[i].tokens) == 4 for i in range(5))
    # first two admitted immediately; the rest only after an eviction
    assert results[0].admitted_at == 0 and results[1].admitted_at == 0
    for i in (2, 3, 4):
        assert results[i].admitted_at >= min(results[0].finished_at,
                                             results[1].finished_at)
    # never more than batch_slots in flight
    live = [(r.admitted_at, r.finished_at) for r in results.values()]
    for t in range(max(f for _, f in live) + 1):
        assert sum(a <= t < f for a, f in live) <= eng.batch_slots


def test_staggered_arrivals_and_prompt_bucketing(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params)
    rng = np.random.default_rng(1)
    # ragged prompt lengths share compiles via bucket=8; arrivals staggered
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=3 + i),
                    max_new=3, arrival=2 * i) for i in range(4)]
    results, _ = eng.scheduler(prompt_bucket=8).run(reqs)
    assert sorted(results) == list(range(4))
    for i in range(4):
        assert len(results[i].tokens) == 3
        assert results[i].admitted_at >= results[i].arrival


# --------------------------------------------------------------------------
# EOS eviction mid-stream
# --------------------------------------------------------------------------

def test_eos_evicts_slot_and_readmits(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params, batch_slots=1)
    prompt = np.arange(8, dtype=np.int32)
    # discover what the model will emit, then declare token #2 to be EOS
    free_run, _ = eng.scheduler().run(
        [Request(rid=0, prompt=prompt, max_new=8)])
    eos = free_run[0].tokens[2]
    assert free_run[0].tokens.count(eos) >= 1

    reqs = [Request(rid=0, prompt=prompt, max_new=8),
            Request(rid=1, prompt=prompt + 1, max_new=3)]
    results, _ = eng.scheduler(eos_id=eos).run(reqs)
    # request 0 stops at the first eos (position 2), not at max_new
    assert results[0].eos is True
    assert results[0].tokens[-1] == eos
    assert len(results[0].tokens) <= 3
    # the freed slot served request 1 afterwards
    assert results[1].admitted_at >= results[0].finished_at
    assert len(results[1].tokens) == 3


# --------------------------------------------------------------------------
# Restart-the-batch baseline semantics (bench comparison point)
# --------------------------------------------------------------------------

def test_restart_batching_matches_lockstep_tokens(smoke_lm):
    cfg, model, params = smoke_lm
    eng = _engine(model, params)
    prompts = (jnp.arange(2 * 8, dtype=jnp.int32).reshape(2, 8) * 3) % cfg.vocab
    base = np.asarray(eng.generate(prompts, 6))
    results, stats = run_restart_batching(
        eng, [Request(rid=i, prompt=np.asarray(prompts[i]), max_new=6)
              for i in range(2)])
    for i in range(2):
        assert results[i].tokens == list(base[i])
    # everyone waits for the longest request: one shared finish tick
    assert results[0].finished_at == results[1].finished_at


# --------------------------------------------------------------------------
# Per-slot cache primitives
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized", [False, True], ids=["float", "int8"])
def test_per_slot_cache_independent_offsets(quantized):
    from repro.nn.attention import init_kv_cache, update_kv_cache

    cache = init_kv_cache(2, 8, 2, 4, quantized=quantized,
                          dtype=jnp.float32, per_slot_len=True)
    cache["len"] = jnp.asarray([0, 3], jnp.int32)
    k = jnp.ones((2, 1, 2, 4)) * jnp.asarray([1.0, 2.0])[:, None, None, None]
    cache = update_kv_cache(cache, k, k)
    np.testing.assert_array_equal(np.asarray(cache["len"]), [1, 4])
    kf = np.asarray(cache["k"], np.float32)
    assert kf[0, 0, 0, 0] != 0          # slot 0 wrote at its own offset 0
    assert kf[1, 3, 0, 0] != 0          # slot 1 wrote at its own offset 3
    assert kf[1, 0, 0, 0] == 0          # and not at slot 0's offset


def test_per_slot_decode_attention_masks_each_slot():
    from repro.nn.attention import decode_attention

    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 1, 4, 8))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (2, 6, 2, 8))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (2, 6, 2, 8))
    lens = jnp.asarray([2, 5], jnp.int32)
    out = decode_attention(q, k, v, lens)
    # per-row scalar-length computation must agree exactly
    for i, ln in enumerate([2, 5]):
        ref = decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                               jnp.int32(ln))
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref[0]),
                                   rtol=1e-6)


def test_qdecode_kernel_per_slot_lengths():
    """Pallas (interpret) and ref agree on per-slot kv_len masking."""
    from repro.kernels.qdecode_attn import qdecode_attn_pallas
    from repro.kernels.ref import qdecode_attn_ref

    rng = jax.random.PRNGKey(3)
    q = jax.random.normal(rng, (2, 4, 8), jnp.float32)
    kc = jax.random.randint(jax.random.fold_in(rng, 1), (2, 8, 2, 8),
                            -100, 100, jnp.int8)
    vc = jax.random.randint(jax.random.fold_in(rng, 2), (2, 8, 2, 8),
                            -100, 100, jnp.int8)
    lens = jnp.asarray([3, 7], jnp.int32)
    ref = qdecode_attn_ref(q, kc, vc, 3, 3, lens)
    out = qdecode_attn_pallas(q, kc, vc, jnp.int32(3), jnp.int32(3), lens,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # scalar kv_len still broadcasts (lockstep path unchanged)
    ref_s = qdecode_attn_ref(q, kc, vc, 3, 3, jnp.int32(5))
    out_s = qdecode_attn_pallas(q, kc, vc, jnp.int32(3), jnp.int32(3),
                                jnp.int32(5), interpret=True)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(ref_s),
                               rtol=1e-5, atol=1e-5)
