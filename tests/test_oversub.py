"""Oversubscribed paged serving: lazy decode-page growth, mid-decode
preemption (recompute + swap policies), starvation-free victim selection,
the loud page-table-edge admission fix (reject/truncate), sharing-aware
occupancy, and per-request prompt-digest caching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config
from repro.serve import Request, ServeEngine
from repro.serve.paging import PageAllocator, PrefixIndex, SwapArea
from repro.serve.scheduler import pick_preemption_victim


@pytest.fixture(scope="module")
def smoke_lm():
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_len", 48)
    kw.setdefault("batch_slots", 4)
    return ServeEngine(model=model, params=params, **kw)


def _workload(vocab, *, n_requests=4, plen=16, max_new=8, spacing=1, seed=5):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=plen, dtype=np.int32),
                    max_new=max_new, arrival=i * spacing)
            for i in range(n_requests)]


# --------------------------------------------------------------------------
# Lazy growth
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized_kv", [False, True],
                         ids=["fp32", "int8kv"])
def test_lazy_growth_token_identity(smoke_lm, quantized_kv):
    """With a roomy pool (no preemption), lazy growth must emit exactly the
    dense and up-front paged streams while reserving fewer pages up front."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab)
    dense = _engine(model, params, quantized_kv=quantized_kv)
    base, _ = dense.scheduler(chunk_size=8, prefix_sharing=False).run(reqs)
    paged = _engine(model, params, quantized_kv=quantized_kv,
                    paged_kv=True, page_size=8)
    upfront, up_st = paged.scheduler(chunk_size=8,
                                     prefix_sharing=False).run(reqs)
    lazy, lz_st = paged.scheduler(chunk_size=8, prefix_sharing=False,
                                  oversubscribe=True).run(reqs)
    for i in range(len(reqs)):
        assert lazy[i].tokens == base[i].tokens, (quantized_kv, i)
        assert upfront[i].tokens == base[i].tokens, (quantized_kv, i)
    assert lz_st.grown_pages > 0               # decode crossed page edges
    assert lz_st.preemptions == 0              # pool was roomy
    assert lz_st.page_occupancy > up_st.page_occupancy


def test_lazy_growth_never_maps_a_live_page(smoke_lm):
    """Every page a slot's table row holds must be uniquely mapped unless
    the allocator says it is shared — growth must never hand out a page
    another live row already maps privately (aliasing)."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=5, spacing=0)
    eng = _engine(model, params, paged_kv=True, page_size=8,
                  kv_pool_pages=9, batch_slots=3)
    got, stats = eng.scheduler(chunk_size=8, prefix_sharing=False,
                               oversubscribe=True).run(reqs)
    # with no sharing, aliasing would corrupt streams; cross-check vs dense
    dense = _engine(model, params, batch_slots=3)
    base, _ = dense.scheduler(chunk_size=8, prefix_sharing=False).run(reqs)
    assert sorted(got) == list(range(5))
    for i in range(5):
        assert got[i].tokens == base[i].tokens, i
    assert stats.grown_pages > 0


# --------------------------------------------------------------------------
# Preemption: recompute + swap
# --------------------------------------------------------------------------

@pytest.mark.parametrize("quantized_kv", [False, True],
                         ids=["fp32", "int8kv"])
@pytest.mark.parametrize("policy", ["recompute", "swap"])
def test_preempt_resume_token_identity(smoke_lm, policy, quantized_kv):
    """A pool too small for every admitted request's decode horizon forces
    mid-decode preemption; the preempted request's final stream must still
    be token-identical to the dense run (recompute: greedy continuation;
    swap: bit-exact page restore)."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=4, plen=16, max_new=12, spacing=0)
    dense = _engine(model, params, batch_slots=3,
                    quantized_kv=quantized_kv)
    base, _ = dense.scheduler(chunk_size=8, prefix_sharing=False).run(reqs)
    # 16-token prompts admit with 2 pages of 8; +12 decode rows grow toward
    # 4 pages each.  3 slots x 4 pages = 12 > pool 7 -> growth runs dry.
    eng = _engine(model, params, batch_slots=3, quantized_kv=quantized_kv,
                  paged_kv=True, page_size=8, kv_pool_pages=7)
    got, stats = eng.scheduler(chunk_size=8, prefix_sharing=False,
                               oversubscribe=True,
                               preempt_policy=policy).run(reqs)
    assert stats.preemptions > 0, "pool was not tight enough to preempt"
    assert sorted(got) == list(range(4))
    for i in range(4):
        assert got[i].tokens == base[i].tokens, (policy, quantized_kv, i)
    if policy == "swap":
        assert stats.swapped_pages > 0
        assert stats.resumes > 0
        assert stats.swap_peak_bytes > 0
    else:
        assert stats.resumes == 0          # recompute re-queues instead


def test_swap_never_moves_shared_pages(smoke_lm):
    """Under prefix sharing, a preempted sharer's shared prefix pages stay
    resident (only private pages swap); the donor and every sharer still
    emit exactly the dense streams."""
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(11)
    sysp = rng.integers(0, cfg.vocab, size=16, dtype=np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [sysp, rng.integers(0, cfg.vocab, size=8,
                                            dtype=np.int32)]),
                    max_new=12, arrival=0)
            for i in range(4)]
    dense = _engine(model, params, batch_slots=3)
    base, _ = dense.scheduler(chunk_size=8).run(reqs)
    eng = _engine(model, params, batch_slots=3, paged_kv=True, page_size=8,
                  kv_pool_pages=9)
    got, stats = eng.scheduler(chunk_size=8, oversubscribe=True,
                               preempt_policy="swap").run(reqs)
    assert stats.preemptions > 0
    assert stats.prefix_hits > 0
    for i in range(4):
        assert got[i].tokens == base[i].tokens, i
    # the 2 shared prompt pages are mapped by several rows; had they been
    # swapped+freed the other sharers would have read reused garbage above.
    # swap traffic must stay below the victims' full footprint:
    assert stats.swapped_pages < stats.preemptions * 4


def test_aging_bound_prevents_starvation(smoke_lm):
    """Heavy oversubscription with many same-size victims: the aging bound
    must still let every request finish (a request preempted `bound` times
    becomes untouchable until everyone else is), token-identical."""
    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=6, plen=16, max_new=12, spacing=0)
    dense = _engine(model, params, batch_slots=3)
    base, _ = dense.scheduler(chunk_size=8, prefix_sharing=False).run(reqs)
    eng = _engine(model, params, batch_slots=3, paged_kv=True, page_size=8,
                  kv_pool_pages=7)
    got, stats = eng.scheduler(chunk_size=8, prefix_sharing=False,
                               oversubscribe=True, preempt_aging=1,
                               preempt_policy="recompute").run(reqs)
    assert sorted(got) == list(range(6))       # nobody starved
    for i in range(6):
        assert got[i].tokens == base[i].tokens, i
    assert stats.preemptions > 0
    assert max(stats.preempted_rids.values()) <= stats.preemptions


def test_oversub_int8_interpret_e2e(smoke_lm):
    """Preempt+resume end-to-end through the fused qpaged Pallas kernels in
    interpret mode: identical streams to the ref-oracle dispatch."""
    from repro.kernels import ops as kops

    cfg, model, params = smoke_lm
    reqs = _workload(cfg.vocab, n_requests=3, plen=16, max_new=10, spacing=0)
    eng = _engine(model, params, max_len=32, batch_slots=2,
                  quantized_kv=True, paged_kv=True, page_size=8,
                  kv_pool_pages=5)
    base, b_st = eng.scheduler(chunk_size=8, prefix_sharing=False,
                               oversubscribe=True).run(reqs)
    prev = kops.FORCE
    kops.FORCE = "interpret"
    try:
        got, stats = eng.scheduler(chunk_size=8, prefix_sharing=False,
                                   oversubscribe=True).run(reqs)
    finally:
        kops.FORCE = prev
    assert b_st.preemptions > 0 and stats.preemptions > 0
    for i in range(3):
        assert got[i].tokens == base[i].tokens, i


# --------------------------------------------------------------------------
# Victim selection
# --------------------------------------------------------------------------

def test_victim_selection_least_progress_and_aging():
    # (slot, rid, emitted, admitted_at)
    cands = [(0, 10, 5, 0), (1, 11, 2, 3), (2, 12, 2, 1)]
    # least emitted wins; tie broken toward the most recent admission
    assert pick_preemption_victim(cands, {}, 2) == 1
    # an aged rid is only chosen when every candidate is aged
    assert pick_preemption_victim(cands, {11: 2}, 2) == 2
    assert pick_preemption_victim(cands, {10: 2, 11: 2, 12: 2}, 2) == 1
    assert pick_preemption_victim([], {}, 2) is None


# --------------------------------------------------------------------------
# Page-table-edge admission: loud reject / explicit truncate
# --------------------------------------------------------------------------

def test_oversize_request_rejected_loudly(smoke_lm):
    """The headline bugfix: a request whose prompt+max_new exceeds the page
    table must be rejected at admission, not silently clamped into
    OOB-sentinel row drops and garbage decode."""
    cfg, model, params = smoke_lm
    eng = _engine(model, params, paged_kv=True, page_size=8)   # cap = 48
    r = Request(rid=0, prompt=np.arange(16, dtype=np.int32) % cfg.vocab,
                max_new=40, arrival=0)                         # 56 > 48
    with pytest.raises(ValueError, match="decode garbage"):
        eng.scheduler(chunk_size=8).run([r], warmup=False)


def test_oversize_plan_raises_not_clamps(smoke_lm):
    """_plan_admission itself refuses a plan that cannot cover the
    request's real rows (the old code clamped and dropped live KV)."""
    cfg, model, params = smoke_lm
    eng = _engine(model, params, paged_kv=True, page_size=8)
    sched = eng.scheduler(chunk_size=8, prefix_sharing=False)
    alloc = PageAllocator(eng.kv_num_pages)
    r = Request(rid=0, prompt=np.zeros(16, np.int32), max_new=40, arrival=0)
    with pytest.raises(ValueError, match="out-of-bounds sentinel"):
        sched._plan_admission(r, 16, alloc, None)
    assert alloc.pages_in_use == 0             # nothing leaked


def test_oversize_truncate_mode_grants_what_fits(smoke_lm):
    """oversize='truncate' clamps max_new to the table capacity, records
    it per request, and serves the grant exactly."""
    cfg, model, params = smoke_lm
    eng = _engine(model, params, paged_kv=True, page_size=8)   # cap = 48
    reqs = [Request(rid=0, prompt=np.arange(16, dtype=np.int32) % cfg.vocab,
                    max_new=40, arrival=0),                    # -> grant 32
            Request(rid=1, prompt=np.arange(8, dtype=np.int32) % cfg.vocab,
                    max_new=4, arrival=0)]                     # untouched
    got, stats = eng.scheduler(chunk_size=8,
                               oversize="truncate").run(reqs)
    assert stats.truncations == 1
    assert stats.truncated_rids == {0: 32}
    assert len(got[0].tokens) == 32
    assert len(got[1].tokens) == 4


# --------------------------------------------------------------------------
# Occupancy + digest caching (satellites #2, #3)
# --------------------------------------------------------------------------

def test_occupancy_bounded_under_prefix_sharing(smoke_lm):
    """page_occupancy counts a shared pool page once (at its deepest live
    row), so heavy sharing can no longer report > 1.0."""
    cfg, model, params = smoke_lm
    rng = np.random.default_rng(13)
    sysp = rng.integers(0, cfg.vocab, size=24, dtype=np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [sysp, rng.integers(0, cfg.vocab, size=8,
                                            dtype=np.int32)]),
                    max_new=8, arrival=i)
            for i in range(4)]
    eng = _engine(model, params, paged_kv=True, page_size=8)
    _, stats = eng.scheduler(chunk_size=8).run(reqs)
    assert stats.prefix_hits > 0
    assert 0.0 < stats.page_occupancy <= 1.0


def test_prompt_digests_hashed_once_per_request(smoke_lm, monkeypatch):
    """Admission retries under page stalls must reuse the cached digests —
    one PrefixIndex.digests call per request, however long it queues."""
    cfg, model, params = smoke_lm
    calls = []
    orig = PrefixIndex.digests

    def counting(self, prompt):
        calls.append(len(np.asarray(prompt).reshape(-1)))
        return orig(self, prompt)

    monkeypatch.setattr(PrefixIndex, "digests", counting)
    reqs = _workload(cfg.vocab, n_requests=4, plen=16, max_new=8, spacing=0)
    # 3 pages per request up front, pool of 5: admissions stall repeatedly
    eng = _engine(model, params, paged_kv=True, page_size=8,
                  kv_pool_pages=5, batch_slots=2)
    got, stats = eng.scheduler(chunk_size=8).run(reqs)
    assert sorted(got) == list(range(4))
    assert stats.page_stalls > 0
    assert len(calls) == 4                     # once per request, ever


# --------------------------------------------------------------------------
# SwapArea bookkeeping
# --------------------------------------------------------------------------

def test_swap_area_accounting():
    sa = SwapArea()
    a = {"k": np.zeros((2, 8, 2, 4), np.int8), "v": np.zeros(16, np.float32)}
    sa.put(3, a)
    assert 3 in sa and len(sa) == 1
    assert sa.bytes_held == a["k"].nbytes + a["v"].nbytes
    assert sa.peak_bytes == sa.bytes_held
    with pytest.raises(ValueError):
        sa.put(3, a)                           # double-park is a bug
    peak = sa.peak_bytes
    assert sa.pop(3) is a
    assert sa.bytes_held == 0 and sa.peak_bytes == peak
    with pytest.raises(KeyError):
        sa.pop(3)
    sa.put(4, None)                            # fully-shared victim: no data
    assert sa.pop(4) is None
