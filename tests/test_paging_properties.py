"""Property-based tests (hypothesis) for the PageAllocator's invariants
under adversarial alloc/share/free churn: a live (refcount > 0) page never
re-enters the free list, alloc stays all-or-nothing under interleaving,
``peak_in_use`` is monotone within a run — plus the oversubscription layer:
lazy one-page growth never aliases a live mapping, swap park/restore cycles
conserve pages, and victim selection is deterministic and starvation-free."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'dev' extra (pip install -e .[dev])")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.paging import PageAllocator, SwapArea
from repro.serve.scheduler import pick_preemption_victim

POOL = 12

# an op stream: ("alloc", n) takes n pages, ("share", i) adds a reference to
# the i-th outstanding allocation, ("free", i) drops one
ops_strategy = st.lists(
    st.tuples(st.sampled_from(["alloc", "share", "free"]),
              st.integers(0, 10)),
    max_size=250)


@settings(max_examples=200, deadline=None)
@given(ops=ops_strategy)
def test_allocator_invariants_under_churn(ops):
    a = PageAllocator(POOL)
    held = []                      # one entry per outstanding reference set
    peak = 0
    for op, arg in ops:
        if op == "alloc":
            n = arg % 7
            free_before = a.free_pages
            got = a.alloc(n)
            if got is None:
                # all-or-nothing: a failed alloc leaves the free list intact
                assert n > free_before
                assert a.free_pages == free_before
            else:
                assert len(got) == len(set(got)) == n
                held.append(list(got))
        elif op == "share" and held:
            pages = held[arg % len(held)]
            a.share(pages)
            held.append(list(pages))
        elif op == "free" and held:
            released = a.free(held.pop(arg % len(held)))
            # a page is released exactly when no outstanding set holds it
            live = {p for h in held for p in h}
            assert not (set(released) & live)
        # INVARIANT: live pages never re-enter the free list
        live = {p for h in held for p in h}
        assert live.isdisjoint(a._free)
        assert a.pages_in_use == len(live)
        # INVARIANT: the high-water mark is monotone within a run
        assert a.peak_in_use >= peak
        peak = a.peak_in_use
    for h in held:
        a.free(h)
    assert a.pages_in_use == 0 and a.free_pages == POOL


@settings(max_examples=100, deadline=None)
@given(sizes=st.lists(st.integers(0, POOL + 2), max_size=40))
def test_alloc_failure_order_independent(sizes):
    """A None answer depends only on the current free count, never on the
    history of prior failures (failed allocs are true no-ops)."""
    a = PageAllocator(POOL)
    held = []
    for n in sizes:
        expect_ok = n <= a.free_pages
        got = a.alloc(n)
        assert (got is not None) == expect_ok
        if got is not None:
            held.append(got)
        elif held:
            a.free(held.pop(0))
    for h in held:
        a.free(h)
    assert a.free_pages == POOL


# --------------------------------------------------------------------------
# Oversubscription: lazy growth, swap park/restore, victim selection
# --------------------------------------------------------------------------

# ("admit", n_pages) / ("grow", i) one page onto row i / ("park", i) free
# row i's tail keeping a shared head / ("finish", i)
growth_ops = st.lists(
    st.tuples(st.sampled_from(["admit", "grow", "park", "finish"]),
              st.integers(0, 10)),
    max_size=200)


@settings(max_examples=200, deadline=None)
@given(ops=growth_ops)
def test_lazy_growth_never_aliases_a_live_page(ops):
    """The scheduler's growth loop is alloc(1)+append per boundary: however
    admissions, growths, parks and finishes interleave, a page may appear in
    at most one row per reference the allocator tracks for it — growth can
    never hand a row a page some other live row still maps privately."""
    a = PageAllocator(POOL)
    rows = {}                       # row id -> list of pages (in table order)
    parked = {}                     # row id -> kept shared head
    nxt = 0
    for op, arg in ops:
        if op == "admit":
            got = a.alloc(arg % 3)
            if got is not None:
                rows[nxt] = list(got)
                nxt += 1
        elif op == "grow" and rows:
            rid = sorted(rows)[arg % len(rows)]
            got = a.alloc(1)
            if got is not None:
                rows[rid].extend(got)
        elif op == "park" and rows:
            rid = sorted(rows)[arg % len(rows)]
            pages = rows.pop(rid)
            keep = arg % (len(pages) + 1)
            a.share(pages[:keep])   # parked head keeps its reference...
            a.free(pages)           # ...while the row itself lets go
            parked[rid] = pages[:keep]
        elif op == "finish":
            pool = rows if (arg % 2 == 0 and rows) or not parked else parked
            if pool:
                rid = sorted(pool)[arg % len(pool)]
                a.free(pool.pop(rid))
        # INVARIANT: per page, live mappings never exceed its refcount, and
        # no live mapping sits in the free list
        holders = {}
        for pages in list(rows.values()) + list(parked.values()):
            for p in pages:
                holders[p] = holders.get(p, 0) + 1
        for p, n in holders.items():
            assert a.refcount(p) == n, (p, n, a.refcount(p))
            assert p not in a._free
    for pages in list(rows.values()) + list(parked.values()):
        a.free(pages)
    assert a.pages_in_use == 0


@settings(max_examples=100, deadline=None)
@given(cycle=st.lists(st.integers(1, POOL), max_size=30))
def test_swap_park_restore_conserves_pages(cycle):
    """Park (free private pages into a SwapArea) then restore (alloc fresh,
    pop the area): every cycle conserves pool pages and swap bytes, and the
    restored page count always equals what was parked."""
    import numpy as np
    a = PageAllocator(POOL)
    sa = SwapArea()
    held = a.alloc(POOL)
    parked = []                     # (rid, n_pages)
    rid = 0
    for n in cycle:
        if parked and (n % 2 == 0 or n > len(held)):
            prid, pn = parked.pop(0)
            got = a.alloc(pn)
            if got is None:
                parked.insert(0, (prid, pn))
                continue
            data = sa.pop(prid)
            assert (data is None and pn == 0) or data.shape[0] == pn
            held.extend(got)
        else:
            take = min(n, len(held))
            priv, held = held[:take], held[take:]
            sa.put(rid, np.zeros((take, 4), np.int8) if take else None)
            a.free(priv)
            parked.append((rid, take))
            rid += 1
        assert a.pages_in_use == len(held)
        assert sa.bytes_held == sum(4 * pn for _, pn in parked)
        assert sa.peak_bytes >= sa.bytes_held
    assert len(sa) == len(parked)


victim_cands = st.lists(
    st.tuples(st.integers(0, 7),        # slot
              st.integers(0, 20),       # rid
              st.integers(1, 50),       # emitted
              st.integers(0, 100)),     # admitted_at
    min_size=1, max_size=8,
    unique_by=lambda c: c[0])


@settings(max_examples=200, deadline=None)
@given(cands=victim_cands,
       counts=st.dictionaries(st.integers(0, 20), st.integers(0, 5)),
       bound=st.integers(1, 4))
def test_victim_selection_deterministic_and_starvation_free(
        cands, counts, bound):
    v = pick_preemption_victim(cands, counts, bound)
    assert v == pick_preemption_victim(list(reversed(cands)), counts, bound)
    chosen = next(c for c in cands if c[0] == v)
    aged = [c for c in cands if counts.get(c[1], 0) >= bound]
    if len(aged) < len(cands):
        # an under-bound candidate exists: the aged are untouchable...
        assert counts.get(chosen[1], 0) < bound
        # ...and among the eligible, least decode progress is sacrificed
        eligible = [c for c in cands if counts.get(c[1], 0) < bound]
        assert chosen[2] == min(c[2] for c in eligible)
    else:
        assert chosen[2] == min(c[2] for c in cands)
