"""Property-based tests (hypothesis) for the PageAllocator's invariants
under adversarial alloc/share/free churn: a live (refcount > 0) page never
re-enters the free list, alloc stays all-or-nothing under interleaving, and
``peak_in_use`` is monotone within a run."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'dev' extra (pip install -e .[dev])")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.paging import PageAllocator

POOL = 12

# an op stream: ("alloc", n) takes n pages, ("share", i) adds a reference to
# the i-th outstanding allocation, ("free", i) drops one
ops_strategy = st.lists(
    st.tuples(st.sampled_from(["alloc", "share", "free"]),
              st.integers(0, 10)),
    max_size=250)


@settings(max_examples=200, deadline=None)
@given(ops=ops_strategy)
def test_allocator_invariants_under_churn(ops):
    a = PageAllocator(POOL)
    held = []                      # one entry per outstanding reference set
    peak = 0
    for op, arg in ops:
        if op == "alloc":
            n = arg % 7
            free_before = a.free_pages
            got = a.alloc(n)
            if got is None:
                # all-or-nothing: a failed alloc leaves the free list intact
                assert n > free_before
                assert a.free_pages == free_before
            else:
                assert len(got) == len(set(got)) == n
                held.append(list(got))
        elif op == "share" and held:
            pages = held[arg % len(held)]
            a.share(pages)
            held.append(list(pages))
        elif op == "free" and held:
            released = a.free(held.pop(arg % len(held)))
            # a page is released exactly when no outstanding set holds it
            live = {p for h in held for p in h}
            assert not (set(released) & live)
        # INVARIANT: live pages never re-enter the free list
        live = {p for h in held for p in h}
        assert live.isdisjoint(a._free)
        assert a.pages_in_use == len(live)
        # INVARIANT: the high-water mark is monotone within a run
        assert a.peak_in_use >= peak
        peak = a.peak_in_use
    for h in held:
        a.free(h)
    assert a.pages_in_use == 0 and a.free_pages == POOL


@settings(max_examples=100, deadline=None)
@given(sizes=st.lists(st.integers(0, POOL + 2), max_size=40))
def test_alloc_failure_order_independent(sizes):
    """A None answer depends only on the current free count, never on the
    history of prior failures (failed allocs are true no-ops)."""
    a = PageAllocator(POOL)
    held = []
    for n in sizes:
        expect_ok = n <= a.free_pages
        got = a.alloc(n)
        assert (got is not None) == expect_ok
        if got is not None:
            held.append(got)
        elif held:
            a.free(held.pop(0))
    for h in held:
        a.free(h)
    assert a.free_pages == POOL
