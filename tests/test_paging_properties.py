"""Property-based tests (hypothesis) for the PageAllocator's invariants
under adversarial alloc/share/free churn: a live (refcount > 0) page never
re-enters the free list, alloc stays all-or-nothing under interleaving,
``peak_in_use`` is monotone within a run — plus the oversubscription layer:
lazy one-page growth never aliases a live mapping, swap park/restore cycles
conserve pages, and victim selection is deterministic and starvation-free.

The serve/audit.py auditor gets the same treatment: any honestly churned
state passes ``check_allocator``/``check_swap``, and a single injected
corruption (double-map, leaked page, stale refcount, table/byte drift) is
always caught as an :class:`AuditError`."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'dev' extra (pip install -e .[dev])")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.audit import (AuditError, check_allocator,
                               check_page_tables, check_swap)
from repro.serve.paging import PageAllocator, SwapArea
from repro.serve.scheduler import pick_preemption_victim

POOL = 12

# an op stream: ("alloc", n) takes n pages, ("share", i) adds a reference to
# the i-th outstanding allocation, ("free", i) drops one
ops_strategy = st.lists(
    st.tuples(st.sampled_from(["alloc", "share", "free"]),
              st.integers(0, 10)),
    max_size=250)


@settings(max_examples=200, deadline=None)
@given(ops=ops_strategy)
def test_allocator_invariants_under_churn(ops):
    a = PageAllocator(POOL)
    held = []                      # one entry per outstanding reference set
    peak = 0
    for op, arg in ops:
        if op == "alloc":
            n = arg % 7
            free_before = a.free_pages
            got = a.alloc(n)
            if got is None:
                # all-or-nothing: a failed alloc leaves the free list intact
                assert n > free_before
                assert a.free_pages == free_before
            else:
                assert len(got) == len(set(got)) == n
                held.append(list(got))
        elif op == "share" and held:
            pages = held[arg % len(held)]
            a.share(pages)
            held.append(list(pages))
        elif op == "free" and held:
            released = a.free(held.pop(arg % len(held)))
            # a page is released exactly when no outstanding set holds it
            live = {p for h in held for p in h}
            assert not (set(released) & live)
        # INVARIANT: live pages never re-enter the free list
        live = {p for h in held for p in h}
        assert live.isdisjoint(a._free)
        assert a.pages_in_use == len(live)
        # INVARIANT: the high-water mark is monotone within a run
        assert a.peak_in_use >= peak
        peak = a.peak_in_use
    for h in held:
        a.free(h)
    assert a.pages_in_use == 0 and a.free_pages == POOL


@settings(max_examples=100, deadline=None)
@given(sizes=st.lists(st.integers(0, POOL + 2), max_size=40))
def test_alloc_failure_order_independent(sizes):
    """A None answer depends only on the current free count, never on the
    history of prior failures (failed allocs are true no-ops)."""
    a = PageAllocator(POOL)
    held = []
    for n in sizes:
        expect_ok = n <= a.free_pages
        got = a.alloc(n)
        assert (got is not None) == expect_ok
        if got is not None:
            held.append(got)
        elif held:
            a.free(held.pop(0))
    for h in held:
        a.free(h)
    assert a.free_pages == POOL


# --------------------------------------------------------------------------
# Oversubscription: lazy growth, swap park/restore, victim selection
# --------------------------------------------------------------------------

# ("admit", n_pages) / ("grow", i) one page onto row i / ("park", i) free
# row i's tail keeping a shared head / ("finish", i)
growth_ops = st.lists(
    st.tuples(st.sampled_from(["admit", "grow", "park", "finish"]),
              st.integers(0, 10)),
    max_size=200)


@settings(max_examples=200, deadline=None)
@given(ops=growth_ops)
def test_lazy_growth_never_aliases_a_live_page(ops):
    """The scheduler's growth loop is alloc(1)+append per boundary: however
    admissions, growths, parks and finishes interleave, a page may appear in
    at most one row per reference the allocator tracks for it — growth can
    never hand a row a page some other live row still maps privately."""
    a = PageAllocator(POOL)
    rows = {}                       # row id -> list of pages (in table order)
    parked = {}                     # row id -> kept shared head
    nxt = 0
    for op, arg in ops:
        if op == "admit":
            got = a.alloc(arg % 3)
            if got is not None:
                rows[nxt] = list(got)
                nxt += 1
        elif op == "grow" and rows:
            rid = sorted(rows)[arg % len(rows)]
            got = a.alloc(1)
            if got is not None:
                rows[rid].extend(got)
        elif op == "park" and rows:
            rid = sorted(rows)[arg % len(rows)]
            pages = rows.pop(rid)
            keep = arg % (len(pages) + 1)
            a.share(pages[:keep])   # parked head keeps its reference...
            a.free(pages)           # ...while the row itself lets go
            parked[rid] = pages[:keep]
        elif op == "finish":
            pool = rows if (arg % 2 == 0 and rows) or not parked else parked
            if pool:
                rid = sorted(pool)[arg % len(pool)]
                a.free(pool.pop(rid))
        # INVARIANT: per page, live mappings never exceed its refcount, and
        # no live mapping sits in the free list
        holders = {}
        for pages in list(rows.values()) + list(parked.values()):
            for p in pages:
                holders[p] = holders.get(p, 0) + 1
        for p, n in holders.items():
            assert a.refcount(p) == n, (p, n, a.refcount(p))
            assert p not in a._free
    for pages in list(rows.values()) + list(parked.values()):
        a.free(pages)
    assert a.pages_in_use == 0


@settings(max_examples=100, deadline=None)
@given(cycle=st.lists(st.integers(1, POOL), max_size=30))
def test_swap_park_restore_conserves_pages(cycle):
    """Park (free private pages into a SwapArea) then restore (alloc fresh,
    pop the area): every cycle conserves pool pages and swap bytes, and the
    restored page count always equals what was parked."""
    import numpy as np
    a = PageAllocator(POOL)
    sa = SwapArea()
    held = a.alloc(POOL)
    parked = []                     # (rid, n_pages)
    rid = 0
    for n in cycle:
        if parked and (n % 2 == 0 or n > len(held)):
            prid, pn = parked.pop(0)
            got = a.alloc(pn)
            if got is None:
                parked.insert(0, (prid, pn))
                continue
            data = sa.pop(prid)
            assert (data is None and pn == 0) or data.shape[0] == pn
            held.extend(got)
        else:
            take = min(n, len(held))
            priv, held = held[:take], held[take:]
            sa.put(rid, np.zeros((take, 4), np.int8) if take else None)
            a.free(priv)
            parked.append((rid, take))
            rid += 1
        assert a.pages_in_use == len(held)
        assert sa.bytes_held == sum(4 * pn for _, pn in parked)
        assert sa.peak_bytes >= sa.bytes_held
    assert len(sa) == len(parked)


# --------------------------------------------------------------------------
# The auditor: honest churn passes, injected corruption is always caught
# --------------------------------------------------------------------------

def _churn(a, ops):
    """Drive alloc/share/free churn; returns the live holder map the
    scheduler would hand ``check_allocator`` ({key: page list})."""
    held = {}
    nxt = 0
    for op, arg in ops:
        if op == "alloc":
            got = a.alloc(arg % 5)
            if got is not None:
                held[("slot", nxt)] = list(got)
                nxt += 1
        elif op == "share" and held:
            key = sorted(held)[arg % len(held)]
            a.share(held[key])
            held[("parked", nxt)] = list(held[key])
            nxt += 1
        elif op == "free" and held:
            key = sorted(held)[arg % len(held)]
            a.free(held.pop(key))
    return held


@settings(max_examples=200, deadline=None)
@given(ops=ops_strategy)
def test_auditor_blesses_honest_churn(ops):
    """Whatever alloc/share/free interleaving produced the state, the
    auditor must pass it: the auditor's job is catching *bugs*, and the
    allocator API, used correctly, cannot produce one."""
    a = PageAllocator(POOL)
    held = _churn(a, ops)
    check_allocator(a, held)
    for key in list(held):
        a.free(held.pop(key))
        check_allocator(a, held)


@settings(max_examples=200, deadline=None)
@given(ops=ops_strategy,
       kind=st.sampled_from(["double_map", "leak", "stale_refcount",
                             "out_of_pool"]),
       pick=st.integers(0, 1000))
def test_auditor_catches_injected_corruption(ops, kind, pick):
    """One corruption of any flavor — a page mapped by a holder the
    allocator never counted (double-map), a holder entry dropped while its
    reference survives (leak), a refcount bumped with no holder (stale),
    or a mapping outside the pool — must always raise AuditError."""
    a = PageAllocator(POOL)
    held = _churn(a, ops)
    if not held:   # guarantee a live page to corrupt
        held[("slot", 0)] = list(a.alloc(2))
    key = sorted(held)[pick % len(held)]
    if not held[key]:
        held[key] = list(a.alloc(1) or [])
        if not held[key]:
            held.pop(key)
            key = max(held, key=lambda k: len(held[k]))
    page = held[key][pick % len(held[key])]
    if kind == "double_map":
        held[("evil", -1)] = [page]
    elif kind == "leak":
        held[key] = [p for p in held[key] if p != page]
    elif kind == "stale_refcount":
        a.share([page])
    else:
        held[("evil", -1)] = [POOL + 3]
    with pytest.raises(AuditError):
        check_allocator(a, held)


@settings(max_examples=100, deadline=None)
@given(cycle=st.lists(st.integers(1, POOL), max_size=30),
       corrupt=st.sampled_from(["none", "missing_rid", "ghost_rid",
                                "byte_drift"]))
def test_auditor_swap_byte_conservation(cycle, corrupt):
    """Honest park/restore churn always satisfies ``check_swap``; dropping
    a parked rid, leaving a ghost entry behind, or drifting the byte
    accounting is always caught."""
    import numpy as np
    sa = SwapArea()
    parked = []
    rid = 0
    for n in cycle:
        if parked and n % 2 == 0:
            prid, _ = parked.pop(0)
            sa.pop(prid)
        else:
            data = np.zeros((n, 4), np.int8)
            sa.put(rid, data)
            parked.append((rid, data))
            rid += 1
        check_swap(sa, parked)
    check_swap(None, [])
    if corrupt == "none" or not parked:
        return
    if corrupt == "missing_rid":
        sa.pop(parked[0][0])
    elif corrupt == "ghost_rid":
        sa.put(10 ** 6, np.zeros((1, 4), np.int8))
    else:
        parked[0] = (parked[0][0], np.zeros((parked[0][1].shape[0] + 1, 4),
                                            np.int8))
    with pytest.raises(AuditError):
        check_swap(sa, parked)


def test_auditor_page_table_corruptions():
    """The device-table check passes a consistent state and catches every
    drift flavor: wrong page, mapping past the host list, a stale row on an
    empty slot, a frontier mismatch, and a privately-aliased page."""
    import numpy as np
    rows = {0: [3, 5], 2: [7]}
    refcount = {3: 1, 5: 1, 7: 2}.get
    table = np.full((4, 4), -1, np.int32)
    table[0, :2] = [3, 5]
    table[2, 0] = 7
    lens = np.array([9, 0, 4, 0], np.int32)
    good = dict(exact_lens={0: 9}, min_lens={2: 4}, page_size=8)
    check_page_tables(table, lens, rows, refcount, **good)
    bad = table.copy()
    bad[0, 1] = 6                       # wrong page
    with pytest.raises(AuditError, match="host page list"):
        check_page_tables(bad, lens, rows, refcount, **good)
    bad = table.copy()
    bad[0, 2] = 9                       # mapped past the host list
    with pytest.raises(AuditError, match="past its host page list"):
        check_page_tables(bad, lens, rows, refcount, **good)
    bad = table.copy()
    bad[1, 0] = 2                       # stale row on an empty slot
    with pytest.raises(AuditError, match="holds no request"):
        check_page_tables(bad, lens, rows, refcount, **good)
    with pytest.raises(AuditError, match="write frontier"):
        check_page_tables(table, lens, rows, refcount,
                          exact_lens={0: 8}, page_size=8)
    with pytest.raises(AuditError, match="exceeds its mapped extent"):
        check_page_tables(table, np.array([17, 0, 4, 0], np.int32), rows,
                          refcount, exact_lens={0: 17}, page_size=8)
    with pytest.raises(AuditError, match="fell behind"):
        check_page_tables(table, np.array([9, 0, 3, 0], np.int32), rows,
                          refcount, min_lens={2: 4}, page_size=8)
    alias = np.full((4, 4), -1, np.int32)
    alias[0, 0] = alias[2, 0] = 3       # private page in two rows
    with pytest.raises(AuditError, match="aliased"):
        check_page_tables(alias, lens, {0: [3], 2: [3]}, refcount,
                          page_size=8)


victim_cands = st.lists(
    st.tuples(st.integers(0, 7),        # slot
              st.integers(0, 20),       # rid
              st.integers(1, 50),       # emitted
              st.integers(0, 100)),     # admitted_at
    min_size=1, max_size=8,
    unique_by=lambda c: c[0])


@settings(max_examples=200, deadline=None)
@given(cands=victim_cands,
       counts=st.dictionaries(st.integers(0, 20), st.integers(0, 5)),
       bound=st.integers(1, 4))
def test_victim_selection_deterministic_and_starvation_free(
        cands, counts, bound):
    v = pick_preemption_victim(cands, counts, bound)
    assert v == pick_preemption_victim(list(reversed(cands)), counts, bound)
    chosen = next(c for c in cands if c[0] == v)
    aged = [c for c in cands if counts.get(c[1], 0) >= bound]
    if len(aged) < len(cands):
        # an under-bound candidate exists: the aged are untouchable...
        assert counts.get(chosen[1], 0) < bound
        # ...and among the eligible, least decode progress is sacrificed
        eligible = [c for c in cands if counts.get(c[1], 0) < bound]
        assert chosen[2] == min(c[2] for c in eligible)
    else:
        assert chosen[2] == min(c[2] for c in cands)
