"""Distribution-layer tests on a virtual 8-device CPU mesh.

These run in subprocesses because the device count must be fixed before jax
initializes (the main test process keeps the default 1 device, per the
dry-run isolation rule).
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # subprocess-per-test: device count must be
#                                fixed before jax initializes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n: int = 8) -> str:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_int8_gradient_compression_allreduce():
    """Compressed psum-mean ≈ exact mean; error feedback recovers the rest."""
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compress import compressed_psum_mean

    mesh = jax.make_mesh((8,), ("data",))

    def body(g, e):
        mean, new_e = compressed_psum_mean(g, "data", bits=8, error=e)
        exact = jax.lax.pmean(g, "data")
        return mean, new_e, exact

    fn = jax.jit(jax.shard_map(body, mesh=mesh,
                 in_specs=(P("data"), P("data")),
                 out_specs=(P("data"), P("data"), P("data"))))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 0.01
    e = jnp.zeros_like(g)
    mean, new_e, exact = fn(g, e)
    # all shards agree on the mean
    m = np.asarray(mean)
    assert np.allclose(m, m[0:1], atol=0), "shards disagree"
    # int8 grid error is bounded by one quantization step of the shared grid
    ma = float(jnp.max(jnp.abs(g)))
    step = ma / 2**6   # n = frac bits for max|g| at 8 bits => resolution
    assert float(jnp.max(jnp.abs(m - np.asarray(exact)))) < step
    # error feedback: residual equals what quantization dropped
    re = np.asarray(new_e)
    assert np.all(np.abs(re) <= step)
    print("compress ok")
    """)


def test_error_feedback_converges():
    """Sum of compressed means over steps → sum of exact means (EF property)."""
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compress import compressed_grad_allreduce

    mesh = jax.make_mesh((8,), ("data",))
    G = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 32))}

    def body(g, e):
        cg, ne = compressed_grad_allreduce(g, "data", bits=8, error_state=e)
        return cg, ne, jax.tree_util.tree_map(
            lambda t: jax.lax.pmean(t, "data"), g)

    fn = jax.jit(jax.shard_map(body, mesh=mesh,
                 in_specs=(P("data"), P("data")),
                 out_specs=(P("data"), P("data"), P("data"))))
    e = {"w": jnp.zeros((8, 32))}
    tot_c = np.zeros((8, 32)); tot_x = np.zeros((8, 32))
    for step in range(20):
        cg, e, exact = fn(G, e)
        tot_c += np.asarray(cg["w"]); tot_x += np.asarray(exact["w"])
    # cumulative compressed mean tracks cumulative exact mean tightly
    denom = np.abs(tot_x).mean() + 1e-9
    rel = np.abs(tot_c - tot_x).mean() / denom
    assert rel < 0.02, rel
    print("EF ok", rel)
    """)


def test_pipeline_parallel_matches_sequential():
    """GPipe over 4 stages == sequential layer application."""
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.pipeline import make_pipelined_fn

    n_stages, n_micro, mb, d = 4, 8, 2, 16
    mesh = jax.make_mesh((4,), ("pod",))
    keys = jax.random.split(jax.random.PRNGKey(0), n_stages)
    Ws = jnp.stack([jax.random.normal(k, (d, d)) / np.sqrt(d) for k in keys])

    def stage_fn(W, x):
        return jnp.tanh(x @ W)

    run = make_pipelined_fn(stage_fn, mesh, axis_name="pod")
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
    got = run(Ws, x)

    ref = x
    for i in range(n_stages):
        ref = jax.vmap(lambda xb: stage_fn(Ws[i], xb))(ref)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("pipeline ok")
    """, n=4)


def test_sharded_train_step_matches_single_device():
    """DP+TP pjit train step computes the same loss as single-device."""
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.registry import get_config
    from repro.dist import sharding as shd
    from repro.optim import sgd
    from repro.train.trainer import make_train_step
    from repro.data.pipeline import markov_batch_fn

    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="none")
    opt = sgd(momentum=0.9)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = markov_batch_fn(cfg.vocab, 8, 32, seed=1)(0)

    # single device
    s1, m1 = jax.jit(make_train_step(model, opt, 0.01))(state, batch)

    # 4-data x 2-model mesh
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = shd.make_axis_rules(mesh)
    pspecs = shd.param_pspecs(params, mesh, rules)
    gstate = {"params": jax.device_put(params, pspecs),
              "opt": {"m": jax.device_put(opt.init(params)["m"],
                      shd.param_pspecs(opt.init(params)["m"], mesh, rules))},
              "step": jnp.zeros((), jnp.int32)}
    gbatch = jax.device_put(batch, shd.batch_pspecs(batch, mesh, rules))
    step = jax.jit(make_train_step(model, opt, 0.01, mesh=mesh,
                                   axis_rules=rules))
    s2, m2 = step(gstate, gbatch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, \
        (float(m1["loss"]), float(m2["loss"]))
    # params close after one step
    for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                    jax.tree_util.tree_leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)
    print("sharded step ok", float(m1["loss"]))
    """)


def test_shardmap_dp_with_compression_trains():
    run_with_devices("""
    import jax, jax.numpy as jnp
    from repro.models.registry import get_config
    from repro.optim import sgd
    from repro.train.trainer import make_dp_shardmap_train_step
    from repro.data.pipeline import markov_batch_fn

    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="none")
    opt = sgd(momentum=0.9)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    mesh = jax.make_mesh((8,), ("data",))
    step = make_dp_shardmap_train_step(model, opt, 0.05, mesh,
                                       compress_bits=8)
    bf = markov_batch_fn(cfg.vocab, 16, 32, seed=2)
    losses = []
    for s in range(8):
        state, m = step(state, bf(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses   # it learns through int8 grads
    print("compressed training ok", losses[0], "->", losses[-1])
    """)


def test_elastic_checkpoint_across_meshes(tmp_path):
    """Checkpoint written on a 8-dev mesh restores onto 2-dev and 1-dev."""
    script = f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train.checkpoint import CheckpointManager

    mesh = jax.make_mesh((MESHN,), ("data",))
    ck = CheckpointManager({str(tmp_path)!r})
    tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
    if MESHN == 8:
        tree = jax.device_put(tree, NamedSharding(mesh, P("data")))
        ck.save(1, tree)
        print("saved")
    else:
        target = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32,
                  sharding=NamedSharding(mesh, P("data")))}}
        out = ck.restore(1, target)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(64.0).reshape(8, 8))
        print("restored on", MESHN)
    """
    run_with_devices(script.replace("MESHN", "8"), n=8)
    run_with_devices(script.replace("MESHN", "2"), n=2)


def test_moe_weight_stationary_decode_matches_single_device():
    """The decode-step MoE dispatch (weight-stationary, §Perf kimi d1) must
    produce the same logits as the unsharded model, given the same cache.
    (Prefill routing *groups* differ by DP degree — capacity drops are
    group-local by design — so the comparison fixes the prefill cache.)"""
    run_with_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.registry import get_config
    from repro.dist import sharding as shd
    from repro.nn.module import Context

    cfg = get_config("phi3.5-moe-42b-a6.6b-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    b, s_max = 4, 16
    toks = jnp.arange(b * 8, dtype=jnp.int32).reshape(b, 8) % cfg.vocab

    # single device: prefill once, then one decode step (the reference)
    cache0 = model.init_cache(b, s_max, quantized_kv=False,
                              kv_dtype=jnp.float32)
    ctx = Context(train=False)
    lg, cache = model.apply(params, toks, ctx, cache=cache0, decode=True)
    nxt = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
    ref, _ = model.apply(params, nxt, ctx, cache=cache, decode=True)

    # 4x2 mesh, SAME cache, weight-stationary decode path active
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = shd.make_axis_rules(mesh)
    pp = jax.device_put(params, shd.param_pspecs(params, mesh, rules,
                                                 serve=True))
    cache_s = jax.device_put(cache, shd.cache_pspecs(cache, mesh, rules))
    ctx2 = Context(train=False, mesh=mesh, axis_rules=rules)

    @jax.jit
    def step(pp, cache_s, nxt):
        out, _ = model.apply(pp, nxt, ctx2, cache=cache_s, decode=True)
        return out

    got = step(pp, cache_s, nxt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("moe ws decode ok")
    """)
