"""Property-based tests (hypothesis) for the packed sub-int8 formats:
int4/int2 pack→unpack round-trips bit-exactly for every lane alignment
(odd K, blocks that don't divide K), per-block quantize→dequantize error is
bounded by one grid step, and the width-2/4 edge cases (saturation, sign,
all-zero blocks) land where the Qm.n math says they must."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'dev' extra (pip install -e .[dev])")
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import qformat

WIDTHS = st.sampled_from([2, 4])


# --------------------------------------------------------------------------
# pack -> unpack round trip: bit-exact for every lane alignment
# --------------------------------------------------------------------------

@settings(max_examples=150, deadline=None)
@given(width=WIDTHS, k=st.integers(1, 33), n=st.integers(1, 5),
       seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip_bit_exact(width, k, n, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(qformat.qmin(width), qformat.qmax(width) + 1,
                     size=(k, n)).astype(np.int8)
    packed = qformat.pack_subint8(jnp.asarray(q), width, axis=-2)
    lanes = qformat.lanes_per_byte(width)
    assert packed.shape == (-(-k // lanes), n)
    assert packed.dtype == jnp.int8
    back = qformat.unpack_subint8(packed, width, k, axis=-2)
    np.testing.assert_array_equal(np.asarray(back), q)


@settings(max_examples=60, deadline=None)
@given(width=WIDTHS, lead=st.integers(1, 3), k=st.integers(1, 17),
       seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip_stacked_leading_dims(width, lead, k, seed):
    """Scan-stacked weights (L, K, N) pack along -2 like their slices."""
    rng = np.random.default_rng(seed)
    q = rng.integers(qformat.qmin(width), qformat.qmax(width) + 1,
                     size=(lead, k, 3)).astype(np.int8)
    packed = qformat.pack_subint8(jnp.asarray(q), width, axis=-2)
    back = qformat.unpack_subint8(packed, width, k, axis=-2)
    np.testing.assert_array_equal(np.asarray(back), q)
    # each leading slice packs independently to the same bytes
    for i in range(lead):
        np.testing.assert_array_equal(
            np.asarray(qformat.pack_subint8(jnp.asarray(q[i]), width)),
            np.asarray(packed[i]))


# --------------------------------------------------------------------------
# block-scale quantize -> dequantize: error bounded by the grid step
# --------------------------------------------------------------------------

@settings(max_examples=100, deadline=None)
@given(width=WIDTHS, k=st.integers(1, 40),
       block_pow=st.integers(2, 4),        # block_size 4/8/16 (mult of lanes)
       scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**31 - 1))
def test_block_quantize_error_bounded_by_grid_step(width, k, block_pow,
                                                   scale, seed):
    """|x - dequant(quant(x))| < 2^-n per element, n the block's exponent:
    truncation loses < one step, and saturation can't exceed one either
    (the grid max is 2^m - 2^-n while every |x| in the block is < 2^m)."""
    block_size = 2 ** block_pow
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((k, 3)) * scale).astype(np.float32)
    t = qformat.quantize_tensor_packed(jnp.asarray(x), width,
                                       block_size=block_size)
    err = np.abs(np.asarray(t.dequantize()) - x)
    step = np.asarray(t.scales())            # broadcast (k, 3) of 2^-n
    step = np.broadcast_to(step, err.shape)
    assert (err < step + 1e-12).all(), (err / step).max()


@settings(max_examples=60, deadline=None)
@given(width=WIDTHS, k=st.integers(2, 24), seed=st.integers(0, 2**31 - 1))
def test_per_channel_packed_matches_qtensor_grid(width, k, seed):
    """Per-channel packed quantization lands on the same value grid as the
    unpacked QTensor route at the same width."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((k, 4)).astype(np.float32)
    packed = qformat.quantize_tensor_packed(jnp.asarray(x), width)
    plain = qformat.quantize_tensor(jnp.asarray(x), width, channel_axis=-1)
    np.testing.assert_array_equal(np.asarray(packed.unpack()),
                                  np.asarray(plain.q))
    np.testing.assert_allclose(np.asarray(packed.dequantize()),
                               np.asarray(plain.dequantize()), rtol=0, atol=0)


# --------------------------------------------------------------------------
# width-2/4 edge cases: saturation, sign, zero blocks
# --------------------------------------------------------------------------

@pytest.mark.parametrize("width", [2, 4])
def test_saturation_pins_to_grid_extremes(width):
    """Values far past the range saturate to qmin/qmax, and the saturated
    codes survive the pack→unpack trip with their sign."""
    n = jnp.int32(0)
    x = jnp.array([[1e6], [-1e6]], jnp.float32)
    q = qformat.quantize(x, n, width)
    assert int(q[0, 0]) == qformat.qmax(width)
    assert int(q[1, 0]) == qformat.qmin(width)
    back = qformat.unpack_subint8(qformat.pack_subint8(q, width), width, 2)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


@pytest.mark.parametrize("width", [2, 4])
def test_zero_block_gets_clamped_exponent_and_zero_codes(width):
    """An all-zero block drives Eq. 1 to -inf; the N_MAX clamp keeps the
    exponent finite and the codes exactly zero, so dequantize is exact."""
    x = jnp.zeros((8, 2), jnp.float32)
    t = qformat.quantize_tensor_packed(x, width, block_size=4)
    assert int(jnp.max(t.n)) == qformat.N_MAX
    assert not np.asarray(t.q).any()         # zero codes pack to zero bytes
    np.testing.assert_array_equal(np.asarray(t.dequantize()),
                                  np.zeros((8, 2), np.float32))


@pytest.mark.parametrize("width", [2, 4])
def test_sign_preserved_in_every_lane_position(width):
    """The minimum code (sign bit set, magnitude bits clear) survives in
    every lane slot — the sign-extension shift can't borrow across lanes."""
    lanes = qformat.lanes_per_byte(width)
    for pos in range(lanes):
        q = np.zeros((lanes, 1), np.int8)
        q[pos, 0] = qformat.qmin(width)
        back = qformat.unpack_subint8(
            qformat.pack_subint8(jnp.asarray(q), width), width, lanes)
        np.testing.assert_array_equal(np.asarray(back), q)


def test_block_size_must_respect_lane_count():
    x = jnp.ones((8, 2), jnp.float32)
    with pytest.raises(ValueError, match="block_size"):
        qformat.quantize_tensor_packed(x, 4, block_size=3)
    with pytest.raises(ValueError, match="block_size"):
        qformat.quantize_tensor_packed(x, 2, block_size=2)


def test_partial_trailing_block_ignores_padding():
    """The last (short) block's exponent ranges over its real elements only:
    zero-padding must not inflate max|x| (and can't shrink it either)."""
    x = jnp.concatenate([jnp.ones((4, 1), jnp.float32) * 0.01,
                         jnp.ones((2, 1), jnp.float32) * 100.0])
    t = qformat.quantize_tensor_packed(x, 4, block_size=4)
    n = np.asarray(t.n).ravel()
    assert n.shape == (2,)
    # first block scaled for 0.01, second for 100 — distinct grids
    assert n[0] > n[1]
    err = np.abs(np.asarray(t.dequantize()) - np.asarray(x))
    step = np.broadcast_to(np.asarray(t.scales()), err.shape)
    assert (err < step).all()
