from repro.optim.optimizers import Optimizer, adamw, multistep_lr, sgd  # noqa: F401
