"""Optimizers as pure (init, update) pairs on nested-dict param trees.

SGD + momentum + decoupled weight decay is the paper's choice (Sec. 6: "The
stability of the SGD optimizer has motivated this choice, especially for the
quantization-aware training") — it is the default for the paper-repro benches
*and* the large-arch dry-runs (1 aux buffer/param keeps the optimizer-state
HBM at 1× instead of Adam's 2×).  AdamW is provided for the LM examples.

Multi-step LR mirrors the paper's schedules (e.g. UCI-HAR: ×0.13 at epochs
100/200/250).  Optimizer state inherits the parameter sharding (ZeRO-style:
since params are FSDP-sharded over `data`, so is the momentum).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (new_params, new_state)


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def sgd(momentum: float = 0.9, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, lr):
        lr = jnp.asarray(lr, jnp.float32)

        def leaf(g, p, m):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if m is None:
                step = g
                new_m = None
            else:
                new_m = momentum * m + g
                step = (g + momentum * new_m) if nesterov else new_m
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_m

        if momentum == 0.0:
            new = _tmap(lambda g, p: leaf(g, p, None)[0], grads, params)
            return new, {}
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_m = treedef.flatten_up_to(state["m"])
        outs = [leaf(g, p, m) for g, p, m in zip(flat_g, flat_p, flat_m)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return new_p, {"m": new_m}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": _tmap(z, params), "v": _tmap(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        lr = jnp.asarray(lr, jnp.float32)
        t = state["t"] + 1
        c1 = 1.0 - b1 ** t.astype(jnp.float32)
        c2 = 1.0 - b2 ** t.astype(jnp.float32)

        def leaf(g, p, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        outs = [leaf(*a) for a in zip(flat_g, flat_p, flat_m, flat_v)]
        unf = lambda i: jax.tree_util.tree_unflatten(treedef, [o[i] for o in outs])
        return unf(0), {"m": unf(1), "v": unf(2), "t": t}

    return Optimizer(init, update)


@dataclasses.dataclass(frozen=True)
class multistep_lr:
    """Paper-style LR schedule: base_lr × gamma^(milestones passed)."""

    base_lr: float
    milestones: Sequence[int] = ()
    gamma: float = 0.1
    warmup_steps: int = 0

    def __call__(self, step) -> jax.Array:
        step = jnp.asarray(step, jnp.float32)
        lr = jnp.asarray(self.base_lr, jnp.float32)
        for m in self.milestones:
            lr = jnp.where(step >= m, lr * self.gamma, lr)
        if self.warmup_steps:
            lr = lr * jnp.minimum(1.0, (step + 1) / self.warmup_steps)
        return lr
