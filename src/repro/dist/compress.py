"""int8 gradient all-reduce on the paper's power-of-two Qm.n grid.

The same uniform, symmetric, pow2-scale quantizer the paper deploys on the
Cortex-M (``core/qformat``, Eqs. 1–4) doubles as a gradient-compression codec
for data-parallel training: every shard quantizes its local gradient onto a
*shared* grid (the exponent is derived from the pmax of the shard maxima, so
all shards agree bit-for-bit), the integer payloads are psum-reduced — exact,
integers add losslessly — and the mean is dequantized with one shift.  Wire
bytes drop 4× vs f32 (the DCN-crossing all-reduce is the scaling bottleneck,
see launch/mesh.py).

Error feedback (Seide et al. 2014; Karimireddy et al. 2019) makes the scheme
convergent: the residual each step's quantization dropped is carried into the
next step's gradient, so the *cumulative* compressed update tracks the
cumulative exact update to within one quantization step.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qformat


def compressed_psum_mean(g: jax.Array, axis_name: str, *, bits: int = 8,
                         error: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, jax.Array]:
    """Mean of ``g`` over ``axis_name`` through a ``bits``-wide integer
    all-reduce.  Must be called inside ``shard_map``/``pmap``.

    ``error`` is this leaf's error-feedback state (same shape as ``g``;
    zeros on the first step).  Returns ``(mean, new_error)`` where
    ``new_error`` is exactly what quantization dropped this step.
    """
    e = jnp.zeros_like(g) if error is None else error
    v = g + e
    # Shared grid: every shard derives the exponent from the *global* max so
    # the integer payloads are commensurable (psum of mismatched grids would
    # be meaningless).
    ma = jax.lax.pmax(jnp.max(jnp.abs(v)), axis_name)
    n = qformat.frac_bits_for(ma, bits)
    q = qformat.quantize(v, n, bits)
    new_error = v - qformat.dequantize(q, n)
    acc = jax.lax.psum(q.astype(qformat.accumulator_dtype(bits)), axis_name)
    world = jax.lax.psum(1, axis_name)
    mean = qformat.dequantize(acc, n) / world
    return mean.astype(g.dtype), new_error.astype(g.dtype)


def compressed_grad_allreduce(grads: Any, axis_name: str, *, bits: int = 8,
                              error_state: Optional[Any] = None
                              ) -> Tuple[Any, Any]:
    """Tree-wise :func:`compressed_psum_mean`: each leaf gets its own Qm.n
    grid (per-tensor exponents, the paper's per-layer granularity applied to
    gradients) and its own error-feedback slot.

    Returns ``(mean_tree, new_error_tree)``; ``error_state=None`` starts the
    feedback at zero.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if error_state is None:
        errs = [None] * len(leaves)
    else:
        errs = jax.tree_util.tree_leaves(error_state)
        assert len(errs) == len(leaves), "error_state must mirror grads"
    means, new_errs = [], []
    for g, e in zip(leaves, errs):
        m, ne = compressed_psum_mean(g, axis_name, bits=bits, error=e)
        means.append(m)
        new_errs.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, means),
            jax.tree_util.tree_unflatten(treedef, new_errs))
