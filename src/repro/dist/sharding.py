"""Mesh-axis rule inference: logical axes → physical mesh axes → PartitionSpecs.

The contract has two halves:

1. ``make_axis_rules(mesh, ...)`` returns the *logical→physical* mapping the
   model's activation constraints consume (``Context.constrain`` keys:
   ``batch``, ``seq``, ``heads``, ``kv_heads``, ``ff``, ``expert``, ``fsdp``,
   ``model``, ``kv_seq``).  A value is a mesh-axis name, a tuple of names
   (composed axes, e.g. DP over ``("data", "pod")``), or None (replicated).

2. ``_spec_for_path`` / ``param_pspecs`` / ``batch_pspecs`` / ``cache_pspecs``
   turn those rules into concrete :class:`~jax.sharding.NamedSharding` trees
   for whole param / batch / cache pytrees, by *path* (router and norm leaves
   stay replicated) and by *shape* (an axis whose dimension is not divisible
   by the mesh-axis size is dropped rather than padded — JAX would otherwise
   emit uneven shardings that show up as pathological all-gathers).

Layout conventions (DESIGN.md §3):

* dense kernels ``(..., D_in, D_out)``: FSDP on the second-to-last dim over
  ``data``, tensor parallelism on the last dim over ``model``; scan-stacked
  leading dims are replicated (every device steps every layer).
* stacked expert kernels ``(..., E, A, B)``: expert parallelism on E over
  ``model``; the FSDP axis *flips orientation* between train and serve —
  training shards the F (output) dim so the backward all-gathers overlap the
  wide GEMM, decode shards the D (contracting) dim so expert weights stay
  stationary and only small activation psums cross the wire.
* QTensor leaves: the int8 payload shards like the float kernel it replaced;
  per-channel exponents ``n`` ride whatever the payload's channel axis got.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.qformat import QTensor
from repro.nn.module import map_with_path

AxisEntry = Any  # str | tuple[str, ...] | None

# Param-path segments whose leaves stay replicated: tiny and/or
# precision-sensitive (router decision boundary, norm scales, ssm internals) —
# same family as repro.core.integerize._SKIP_SUBSTR.
_REPLICATED_SUBSTR = ("router", "ln", "rms", "norm", "bn",
                      "a_log", "dt_", "decay")


def make_axis_rules(mesh, *, seq_shard: bool = False,
                    decode_kv_shard: bool = True,
                    dp_only: bool = False) -> Dict[str, AxisEntry]:
    """Logical→physical axis rules for ``mesh``.

    ``dp_only``   — repurpose every mesh axis for data parallelism (the batch
                    rule becomes ``("data", "model", "pod")``; params
                    replicate).  Used for small models where TP is overhead.
    ``seq_shard`` — sequence-parallel activations (``seq`` → ``model``).
    ``decode_kv_shard`` — shard the KV-cache sequence dim over ``model``
                    (the decode default); off = replicate the cache.
    """
    names = tuple(getattr(mesh, "axis_names", ()))

    def have(a):
        return a in names

    if dp_only:
        batch = tuple(a for a in ("data", "model", "pod") if have(a))
        tensor = None
        fsdp = None
    else:
        batch = tuple(a for a in ("data", "pod") if have(a))
        tensor = "model" if have("model") else None
        fsdp = "data" if have("data") else None
    return {
        "batch": batch or None,
        "fsdp": fsdp,
        "model": tensor,
        "ff": tensor,
        "heads": tensor,
        "kv_heads": tensor,
        "expert": tensor,
        "seq": tensor if seq_shard else None,
        "kv_seq": tensor if decode_kv_shard else None,
        "stage": "pod" if have("pod") else None,
    }


def _fit(mesh, axes: AxisEntry, dim: int) -> Optional[Tuple[str, ...]]:
    """Longest prefix of ``axes`` whose total mesh size divides ``dim``.

    Returns the prefix as a tuple of axis names, or None when even the first
    axis does not divide (→ replicate).  Composed DP axes degrade gracefully:
    a 256-token batch on a (pod=2, data=16, model=16) dp-only mesh shards
    256-way over ("data", "model") and replicates over "pod".
    """
    if axes is None or dim <= 0:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    sizes = dict(mesh.shape)
    for k in range(len(axes), 0, -1):
        size = 1
        for a in axes[:k]:
            size *= int(sizes[a])
        if size > 1 and dim % size == 0:
            return tuple(axes[:k])
    return None


def _entry(fit: Optional[Tuple[str, ...]]) -> AxisEntry:
    if fit is None:
        return None
    return fit[0] if len(fit) == 1 else tuple(fit)


def _dedupe(entries: Tuple[AxisEntry, ...]) -> Tuple[AxisEntry, ...]:
    """Drop any mesh axis already used by an earlier dim (an axis may appear
    at most once in a PartitionSpec); later uses replicate instead."""
    used = set()
    out = []
    for e in entries:
        if e is None:
            out.append(None)
            continue
        names = (e,) if isinstance(e, str) else tuple(e)
        kept = tuple(a for a in names if a not in used)
        used.update(kept)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(kept)
    return tuple(out)


def _spec_for_path(path: str, shape, rules: Dict[str, AxisEntry], mesh,
                   serve: bool = False) -> P:
    """PartitionSpec for one param leaf, from its tree path and shape."""
    parts = path.lower().split("/")
    if any(any(s in seg for s in _REPLICATED_SUBSTR) for seg in parts):
        return P()
    ndim = len(shape)
    if ndim < 2:
        return P()
    entries: list = [None] * ndim
    if "experts" in parts and ndim >= 3:
        # (..., E, A, B): EP on E; FSDP on F (train) vs D (serve/decode).
        entries[ndim - 3] = _entry(_fit(mesh, rules.get("expert"),
                                        shape[ndim - 3]))
        fdim = ndim - 2 if serve else ndim - 1
        entries[fdim] = _entry(_fit(mesh, rules.get("fsdp"), shape[fdim]))
    else:
        # (..., D_in, D_out): FSDP on D_in, TP on D_out; stacked dims replicate.
        entries[ndim - 2] = _entry(_fit(mesh, rules.get("fsdp"),
                                        shape[ndim - 2]))
        entries[ndim - 1] = _entry(_fit(mesh, rules.get("model"),
                                        shape[ndim - 1]))
    return P(*_dedupe(tuple(entries)))


def _exponent_spec(qspec: P, qt: QTensor) -> P:
    """Spec for a QTensor's exponent leaf: per-channel ``n`` rides whatever
    mesh axis the payload's channel dim got; scalars replicate."""
    n_ndim = getattr(qt.n, "ndim", 0)
    if n_ndim == 0:
        return P()
    q_shape = qt.q.shape
    entries = list(qspec) + [None] * (len(q_shape) - len(tuple(qspec)))
    if qt.channel_axis is not None and n_ndim == 1:
        return P(entries[qt.channel_axis])
    if n_ndim == len(q_shape):
        # broadcast-shaped exponents (per-(layer, channel) stacked kernels)
        return P(*(entries[d] if qt.n.shape[d] == q_shape[d]
                   and qt.n.shape[d] > 1 else None
                   for d in range(n_ndim)))
    return P()


def param_pspecs(params, mesh, rules: Dict[str, AxisEntry], *,
                 serve: bool = False):
    """NamedSharding tree for a param (or optimizer-moment) tree.

    QTensor leaves return a QTensor whose ``q``/``n`` slots hold the payload
    and exponent shardings, so the result can be passed straight to
    ``jax.device_put`` / ``with_shardings`` against the matching value tree.
    """

    def leaf_spec(path, leaf):
        if isinstance(leaf, QTensor):
            qspec = _spec_for_path(path, leaf.q.shape, rules, mesh, serve=serve)
            return QTensor(q=NamedSharding(mesh, qspec),
                           n=NamedSharding(mesh, _exponent_spec(qspec, leaf)),
                           width=leaf.width, channel_axis=leaf.channel_axis)
        shape = getattr(leaf, "shape", ())
        return NamedSharding(mesh,
                             _spec_for_path(path, shape, rules, mesh,
                                            serve=serve))

    return map_with_path(
        leaf_spec,
        params) if isinstance(params, dict) else jax.tree_util.tree_map(
            lambda l: leaf_spec("", l), params,
            is_leaf=lambda x: isinstance(x, QTensor))


def batch_pspecs(batch, mesh, rules: Dict[str, AxisEntry]):
    """Shard dim 0 of every batch leaf over the (composed) DP axes; a batch
    that does not divide falls back to the longest divisible axis prefix."""

    def leaf(x):
        ndim = getattr(x, "ndim", 0)
        if ndim == 0:
            return NamedSharding(mesh, P())
        e = _entry(_fit(mesh, rules.get("batch"), x.shape[0]))
        return NamedSharding(mesh, P(e, *([None] * (ndim - 1))))

    return jax.tree_util.tree_map(leaf, batch)


def cache_pspecs(cache, mesh, rules: Dict[str, AxisEntry]):
    """NamedSharding tree for a decode cache.

    KV leaves ``k``/``v`` are ``(..., batch, seq, heads, head_dim)`` (a
    leading layer dim when scan-stacked): batch shards over DP, the sequence
    dim over ``model`` (``kv_seq`` rule — 32k-token caches dominate decode
    HBM), heads over whatever is left after dedupe.  Everything else
    (exponents, lengths, ssm states) replicates — those are small.
    """

    def leaf_spec(path, x):
        ndim = getattr(x, "ndim", 0)
        name = path.rsplit("/", 1)[-1]
        if name in ("k", "v") and ndim >= 4:
            entries: list = [None] * ndim
            entries[ndim - 4] = _entry(_fit(mesh, rules.get("batch"),
                                            x.shape[ndim - 4]))
            entries[ndim - 3] = _entry(_fit(mesh, rules.get("kv_seq"),
                                            x.shape[ndim - 3]))
            entries[ndim - 2] = _entry(_fit(mesh, rules.get("kv_heads"),
                                            x.shape[ndim - 2]))
            return NamedSharding(mesh, P(*_dedupe(tuple(entries))))
        return NamedSharding(mesh, P())

    return map_with_path(leaf_spec, cache)


def named(mesh, spec: Optional[P] = None) -> NamedSharding:
    """NamedSharding for a single leaf; default fully replicated."""
    return NamedSharding(mesh, spec if spec is not None else P())


def with_shardings(tree, shardings):
    """Attach a sharding tree to a ShapeDtypeStruct tree (AOT lowering inputs).

    The two trees must have the same structure (QTensor nodes included —
    ``param_pspecs`` produces exactly that)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        tree, shardings)
