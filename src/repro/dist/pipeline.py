"""GPipe-style pipeline parallelism over ``shard_map``.

The multi-pod mesh's ``pod`` axis (launch/mesh.py) is repurposed as a stage
axis: device *s* holds stage *s*'s weights (stacked on dim 0 and sharded over
the axis), microbatches flow stage-to-stage through a ``ppermute`` ring.  The
schedule is the classic GPipe fill/steady/drain: with M microbatches and S
stages it runs M + S − 1 ticks, each tick every device computes its stage on
the microbatch in flight and passes the activation to its successor, so the
bubble fraction is (S − 1) / (M + S − 1).

Numerically the pipeline is *exactly* the sequential composition of the
stage function — same ops in the same order per microbatch — which
``tests/test_dist.py::test_pipeline_parallel_matches_sequential`` pins down.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def make_pipelined_fn(stage_fn: Callable, mesh, *,
                      axis_name: str = "pod") -> Callable:
    """Build ``run(stage_params, x) -> y`` executing ``stage_fn`` as a
    pipeline over ``mesh[axis_name]``.

    ``stage_fn(params_s, x_mb)`` applies one stage to one microbatch.
    ``stage_params`` is a pytree whose leaves are stacked ``(n_stages, ...)``;
    ``x`` is ``(n_micro, microbatch, ...)``.  Output matches ``x``'s shape
    with every stage applied in order to every microbatch.
    """
    n_stages = int(dict(mesh.shape)[axis_name])
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(stage_params, x):
        stage = jax.lax.axis_index(axis_name)
        w = jax.tree_util.tree_map(lambda l: l[0], stage_params)
        n_micro = x.shape[0]
        ticks = n_micro + n_stages - 1

        def tick(carry, t):
            prev, outputs = carry
            # pass last tick's activation to the next stage (ring; stage 0's
            # incoming edge carries drain-phase garbage and is ignored below)
            recv = jax.lax.ppermute(prev, axis_name, ring)
            inp = jnp.where(stage == 0, x[jnp.clip(t, 0, n_micro - 1)], recv)
            y = stage_fn(w, inp)
            # the last stage emits microbatch t-(S-1) once the pipe is full
            out_t = t - (n_stages - 1)
            write = jnp.logical_and(stage == n_stages - 1, out_t >= 0)
            written = outputs.at[jnp.clip(out_t, 0, n_micro - 1)].set(y)
            outputs = jnp.where(write, written, outputs)
            return (y, outputs), None

        zero = jnp.zeros(x.shape[1:], x.dtype)
        (_, outputs), _ = jax.lax.scan(
            tick, (zero, jnp.zeros_like(x)), jnp.arange(ticks))
        # only the last stage holds the result; psum broadcasts it (all other
        # stages contribute zeros) and makes the output mesh-invariant
        outputs = jnp.where(stage == n_stages - 1, outputs,
                            jnp.zeros_like(outputs))
        return jax.lax.psum(outputs, axis_name)

    # One jitted wrapper per input rank: the specs depend only on x.ndim, and
    # rebuilding shard_map+jit per call would retrace/recompile every step.
    _jitted: dict = {}

    def run(stage_params: Any, x: jax.Array) -> jax.Array:
        fn = _jitted.get(x.ndim)
        if fn is None:
            fn = jax.jit(jax.shard_map(
                body, mesh=mesh,
                in_specs=(P(axis_name), P(*([None] * x.ndim))),
                out_specs=P(*([None] * x.ndim)),
                check_vma=False))
            _jitted[x.ndim] = fn
        return fn(stage_params, x)

    return run
