"""Version compatibility for the distribution layer.

The codebase targets the modern JAX surface (``jax.shard_map`` with
``check_vma``, ``AbstractMesh(axis_sizes, axis_names)``); the pinned
container ships jax 0.4.x where shard_map still lives in
``jax.experimental.shard_map`` under the ``check_rep`` spelling.  This module
polyfills the new names onto the old wheel — imported for its side effect by
``repro.dist.__init__`` so any caller that touches the dist layer gets the
uniform API.  On a new-enough jax it is a no-op.
"""
from __future__ import annotations

import functools

import jax


def _polyfill_shard_map():
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _legacy

    @functools.wraps(_legacy)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                  check_rep=None, **kwargs):
        check = True
        if check_vma is not None:
            check = check_vma
        if check_rep is not None:
            check = check_rep
        return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_rep=check, **kwargs)

    jax.shard_map = shard_map


def abstract_mesh(axis_sizes, axis_names):
    """``AbstractMesh`` under both calling conventions (>=0.5 takes
    ``(sizes, names)``; 0.4.x takes a ``((name, size), ...)`` tuple)."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_sizes)))


_polyfill_shard_map()
