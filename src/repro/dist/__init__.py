"""Distribution layer: sharding-rule inference, compressed collectives and
pipeline parallelism.

Three modules, one per concern:

* :mod:`repro.dist.sharding`  — logical→physical mesh-axis rules and
  path-based PartitionSpec inference for param / batch / KV-cache trees
  (QTensor-aware: per-channel exponents ride the channel axis).
* :mod:`repro.dist.compress`  — int8 gradient all-reduce on the paper's
  power-of-two Qm.n grid (``core/qformat``), with error feedback.
* :mod:`repro.dist.pipeline`  — GPipe-style microbatch schedule over
  ``shard_map`` (the multi-pod ``pod`` axis repurposed as a stage axis).
"""
from repro.dist import compat  # noqa: F401  (polyfills jax.shard_map on 0.4.x)
from repro.dist import compress, pipeline, sharding
from repro.dist.sharding import (batch_pspecs, cache_pspecs, make_axis_rules,
                                 named, param_pspecs, with_shardings)

__all__ = [
    "compress", "pipeline", "sharding",
    "make_axis_rules", "param_pspecs", "batch_pspecs", "cache_pspecs",
    "named", "with_shardings",
]
