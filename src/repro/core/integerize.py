"""Conversion of a trained float param tree to deployed integer form.

This is the framework's "KerasCNN2C" step (paper Sec. 5.8): after training
(and optional QAT) the float weights are converted to int8/int16 storage with
power-of-two exponents; calibrated activation exponents are baked next to each
layer as ``n_out`` so the engine can requantize with a single shift.

Two flavours:

* :func:`integerize` — the full integer engine (paper-faithful): kernels,
  biases and activation exponents all integerized; activations then flow as
  :class:`QTensor` (see ``nn/layers.py`` integer paths).
* :func:`integerize_weights_only` — TPU serving mode: matmul/conv/embed
  weights to int8 (+ per-channel exponents), everything else untouched;
  activations stay bf16/f32 (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qformat
from repro.core.policy import Granularity, QuantPolicy
from repro.core.qformat import QTensor

# Param leaf names that carry GEMM/conv weights (quantized), vs passthrough.
_WEIGHT_LEAVES = ("kernel", "table")
_BIAS_LEAVES = ("bias",)
# Leaves that must stay float (norms, router, ssm internals).
_SKIP_SUBSTR = ("ln", "rms", "norm", "router", "ssm", "bn", "a_log", "dt_", "decay")


def _is_skipped(path: str, policy: QuantPolicy) -> bool:
    parts = path.lower().split("/")
    return any(any(s in seg for s in _SKIP_SUBSTR) for seg in parts[:-1]) or any(
        k in parts for k in policy.skip_kinds
    )


def integerize(
    params,
    policy: QuantPolicy,
    qstate: Optional[Dict[str, jnp.ndarray]] = None,
    *,
    param_path_to_site: Optional[Dict[str, str]] = None,
) -> Dict:
    """Full integer conversion (paper's deployment, Sec. 5.8).

    ``qstate`` maps quant-site paths -> frozen output exponents.  Layer dicts
    containing a quantized kernel get an ``n_out`` entry; lookup is by the
    layer's param path with an optional explicit ``param_path_to_site`` remap.
    """
    wb, ab = policy.weight_bits, policy.act_bits
    n_net = policy.network_frac_bits if policy.granularity is Granularity.PER_NETWORK else None
    per_ch = policy.granularity is Granularity.PER_CHANNEL
    qstate = qstate or {}

    def site_for(layer_path: str) -> Optional[jnp.ndarray]:
        key = f"{layer_path}/out" if layer_path else "out"
        if param_path_to_site and layer_path in param_path_to_site:
            key = param_path_to_site[layer_path]
        if key in qstate:
            return jnp.asarray(qstate[key], jnp.int32)
        # fall back: match by suffix (scan-stacked / re-scoped layers)
        for k, v in qstate.items():
            if k.endswith(key):
                return jnp.asarray(v, jnp.int32)
        return None

    def rec(node, path):
        if isinstance(node, (list, tuple)):  # scanned-stack param lists
            out = [rec(v, f"{path}/{i}") for i, v in enumerate(node)]
            return type(node)(out) if isinstance(node, tuple) else out
        if not isinstance(node, dict):
            return node
        out = {}
        has_weight = any(k in node for k in _WEIGHT_LEAVES)
        for k, v in node.items():
            child_path = f"{path}/{k}" if path else k
            if isinstance(v, (dict, list, tuple)):
                out[k] = rec(v, child_path)
            elif k in _WEIGHT_LEAVES and not _is_skipped(child_path, policy):
                ca = (v.ndim - 1) if per_ch else None
                out[k] = qformat.quantize_tensor(
                    jnp.asarray(v), wb, channel_axis=ca,
                    n_override=None if n_net is None else jnp.int32(n_net))
            elif k in _BIAS_LEAVES and has_weight and not _is_skipped(child_path, policy):
                # Bias at operand width with its own exponent; aligned into the
                # int32 accumulator at run time (paper Sec. 5.8).
                out[k] = qformat.quantize_tensor(
                    jnp.asarray(v), wb,
                    n_override=None if n_net is None else jnp.int32(n_net))
            else:
                out[k] = v
        if has_weight and any(isinstance(x, QTensor) for x in out.values()):
            n_out = jnp.int32(n_net) if n_net is not None else site_for(path)
            if n_out is not None:
                out["n_out"] = jnp.asarray(n_out, jnp.int32)
        return out

    return rec(params, "")


def integerize_weights_only(params, *, bits: int = 8, per_channel: bool = True,
                            block_size: Optional[int] = None) -> Dict:
    """Weight-only int conversion for TPU serving (embeddings included).

    ``bits`` 8/9/16 store :class:`QTensor` leaves as before.  ``bits`` 4/2
    (beyond-paper sub-int8 frontier) pack GEMM ``kernel`` leaves into
    :class:`~repro.core.qformat.PackedQTensor` containers — two (or four)
    lanes per byte along K, with per-channel scales or, when ``block_size``
    is given, per-block (MX-style) scales.  Embedding ``table`` leaves stay
    unpacked :class:`QTensor` at the logical width, because the gather and
    tied-logits paths index rows directly; their container is int8 either
    way, so only kernels gain the packing byte win.
    """
    packed = bits in (2, 4)

    def rec(node, path):
        if isinstance(node, (list, tuple)):  # scanned-stack param lists
            out = [rec(v, f"{path}/{i}") for i, v in enumerate(node)]
            return type(node)(out) if isinstance(node, tuple) else out
        if not isinstance(node, dict):
            return node
        out = {}
        for k, v in node.items():
            child_path = f"{path}/{k}" if path else k
            if isinstance(v, (dict, list, tuple)):
                out[k] = rec(v, child_path)
            elif k in _WEIGHT_LEAVES and not _is_skipped(child_path, QuantPolicy.serve_int8()) \
                    and hasattr(v, "ndim") and v.ndim >= 2:
                if packed and k == "kernel":
                    out[k] = qformat.quantize_tensor_packed(
                        jnp.asarray(v), bits, block_size=block_size,
                        per_channel=per_channel)
                    continue
                if per_channel:
                    # per-out-channel; stacked leaves (scan layers / experts)
                    # additionally keep every leading dim distinct, so each
                    # layer/expert gets its own Qm.n grid (paper's per-layer
                    # scales survive the stacking)
                    ca = (tuple(range(v.ndim - 2)) + (v.ndim - 1,)
                          if v.ndim > 2 else v.ndim - 1)
                else:
                    ca = None
                out[k] = qformat.quantize_tensor(jnp.asarray(v), bits, channel_axis=ca)
            else:
                out[k] = v
        return out

    return rec(params, "")


def fake_int8_weights(params, *, mesh=None, rules=None) -> Dict:
    """int8-gather training: pass every GEMM/embed weight through
    :func:`repro.core.quantizers.ste_int8_weight` (materialized int8 +
    dequant, STE backward).  Same leaf selection as
    :func:`integerize_weights_only`; master params stay float (exact
    optimizer accumulation), the int8 copy exists only inside the step.

    With (mesh, rules) given, the int8 tensor is pinned to the master's
    FSDP sharding so the partitioner's gather-to-use transition crosses the
    s8 edge (wire ÷4 vs f32) rather than the dequantized f32 edge."""
    from repro.core.quantizers import ste_int8_weight

    constrain = None
    if mesh is not None and rules is not None:
        from repro.dist.sharding import _spec_for_path
        from jax.sharding import NamedSharding

        def constrain(path, q):  # noqa: F811
            spec = _spec_for_path(path, q.shape, rules, mesh)
            return jax.lax.with_sharding_constraint(
                q, NamedSharding(mesh, spec))

    def rec(node, path):
        if isinstance(node, (list, tuple)):
            out = [rec(v, f"{path}/{i}") for i, v in enumerate(node)]
            return type(node)(out) if isinstance(node, tuple) else out
        if not isinstance(node, dict):
            return node
        out = {}
        for k, v in node.items():
            child_path = f"{path}/{k}" if path else k
            if isinstance(v, (dict, list, tuple)):
                out[k] = rec(v, child_path)
            elif k in _WEIGHT_LEAVES and not _is_skipped(child_path, QuantPolicy.serve_int8()) \
                    and hasattr(v, "ndim") and v.ndim >= 2:
                keep = (tuple(range(v.ndim - 2)) + (v.ndim - 1,)
                        if v.ndim > 2 else (v.ndim - 1,))
                out[k] = ste_int8_weight(
                    v, keep,
                    (lambda q, p=child_path: constrain(p, q))
                    if constrain else None)
            else:
                out[k] = v
        return out

    return rec(params, "")


def quantize_input(x, qstate: Dict, site: str, width: int):
    """Entry-point conversion the engine expects from the caller (Sec. 5.6:
    ``x_fixed = clamp(x_float << INPUT_SCALE_FACTOR)``)."""
    n = jnp.asarray(qstate[site], jnp.int32)
    return QTensor(qformat.quantize(x, n, width), n, width)


def model_rom_bytes(params) -> int:
    """Deployed model size at logical widths (paper Table A3 semantics)."""
    import jax

    from repro.core.qformat import PackedQTensor

    total = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, (QTensor, PackedQTensor))
    ):
        if isinstance(leaf, (QTensor, PackedQTensor)):
            # logical payload + exponent-grid storage
            total += leaf.nbytes_model + 4 * int(np.prod(jnp.shape(leaf.n)))
        elif hasattr(leaf, "size"):
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total
