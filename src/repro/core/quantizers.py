"""Fake-quantization ops with straight-through-estimator gradients.

Paper Sec. 4.3: during QAT the forward pass constrains inputs/weights/biases to
the quantized value grid (while staying in float); the backward pass flows
through the *non-quantized* values.  That is exactly a straight-through
estimator, implemented here with ``jax.custom_vjp``.

Also provides the TFLite-style affine (non-pow2 scale + zero-point) quantizer
that the paper compares against (Sec. 7) — implemented so the comparison in
``benchmarks/quant_accuracy.py`` is runnable, and used by the beyond-paper
``asymmetric`` policy switch.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import qformat
from .policy import Granularity, QuantPolicy


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def fake_quant(x: jax.Array, n: jax.Array, width: int) -> jax.Array:
    """quantize->dequantize on the pow2 grid; identity gradient (STE)."""
    return qformat.quantize_dequantize(x, n, width)


def _fq_fwd(x, n, width):
    return qformat.quantize_dequantize(x, n, width), None


def _fq_bwd(width, res, g):
    del width, res
    # STE: pass gradients straight through to x; scale exponents get none.
    return g, None


fake_quant.defvjp(_fq_fwd, _fq_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def fake_quant_affine(x: jax.Array, scale: jax.Array, zero: jax.Array, width: int) -> jax.Array:
    """TFLite-style affine fake-quant: round(x/scale)+zero, clip, dequant."""
    q = jnp.clip(jnp.round(x / scale) + zero, qformat.qmin(width), qformat.qmax(width))
    return (q - zero) * scale


def _fqa_fwd(x, scale, zero, width):
    return fake_quant_affine(x, scale, zero, width), None


def _fqa_bwd(width, res, g):
    del width, res
    return g, None, None


fake_quant_affine.defvjp(_fqa_fwd, _fqa_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ste_int8_weight(x: jax.Array, keep_axes: tuple, q_constraint=None) -> jax.Array:
    """Weight fake-quant that MATERIALIZES the int8 form (STE backward).

    Unlike :func:`fake_quant` (which stays in float), the forward emits an
    actual int8 tensor + dequant, so under pjit the FSDP gather-to-use
    transition *can* ride the int8 operand — **weight-gather wire ÷4 vs
    f32** (the paper's ROM ÷4 applied to the interconnect; §Perf
    "int8-gather training").  ``q_constraint`` pins the int8 tensor to the
    master's sharding so the reshard edge sits after the s8 convert.
    ``keep_axes``: per-axis grids (e.g. (0, -1) on scan-stacked kernels =
    per-layer-per-channel).
    """
    return _ste_int8_fwd(x, keep_axes, q_constraint)[0]


def _ste_int8_fwd(x, keep_axes, q_constraint):
    t = qformat.quantize_tensor(x, 8, channel_axis=keep_axes or None)
    q = t.q if q_constraint is None else q_constraint(t.q)
    out = (q.astype(jnp.float32)
           * jnp.exp2(-t.n.astype(jnp.float32))).astype(x.dtype)
    return out, None


def _ste_int8_bwd(keep_axes, q_constraint, res, g):
    del keep_axes, q_constraint, res
    return (g,)


ste_int8_weight.defvjp(_ste_int8_fwd, _ste_int8_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def fake_quant_blocked(x: jax.Array, width: int, block_size: int,
                       axis: int = -2) -> jax.Array:
    """Sub-int8 fake-quant on a per-block (MX-style) pow2 grid; STE backward.

    Each ``block_size`` run of ``axis`` gets its own Eq. 1-2 exponent from
    the live values, then the run is quantize-dequantized at ``width`` bits
    (2 or 4).  The value set matches :func:`repro.core.qformat.
    quantize_tensor_packed` exactly, so QAT with this forward converges onto
    the grid the packed serving weights will actually store.
    """
    return _fqb_fwd(x, width, block_size, axis)[0]


def _fqb_fwd(x, width, block_size, axis):
    n = qformat.block_frac_bits(jax.lax.stop_gradient(x), width, block_size,
                                axis=axis)
    ax = axis % x.ndim
    nb = jnp.repeat(n, block_size, axis=ax)
    nb = jax.lax.slice_in_dim(nb, 0, x.shape[ax], axis=ax)
    return qformat.quantize_dequantize(x, nb, width), None


def _fqb_bwd(width, block_size, axis, res, g):
    del width, block_size, axis, res
    return (g,)


fake_quant_blocked.defvjp(_fqb_fwd, _fqb_bwd)


def dynamic_frac_bits(
    x: jax.Array, width: int, *, channel_axis: Optional[int] = None
) -> jax.Array:
    """Paper Eq. 1-2 applied to the live tensor (QAT range reassessment).

    The exponent is computed from the current values and treated as
    non-differentiable (it parameterizes the grid, not the function).
    """
    if channel_axis is None:
        ma = qformat.max_abs(jax.lax.stop_gradient(x))
    else:
        axes = tuple(a for a in range(x.ndim) if a != channel_axis % x.ndim)
        ma = qformat.max_abs(jax.lax.stop_gradient(x), axis=axes)
    return qformat.frac_bits_for(ma, width)


def _broadcast_n(n: jax.Array, x: jax.Array, channel_axis: Optional[int]) -> jax.Array:
    if channel_axis is None or jnp.ndim(n) == 0:
        return n
    shape = [1] * x.ndim
    shape[channel_axis % x.ndim] = -1
    return n.reshape(shape)


def quantize_value(
    x: jax.Array,
    policy: QuantPolicy,
    width: int,
    *,
    channel_axis: Optional[int] = None,
    frozen_n: Optional[jax.Array] = None,
) -> jax.Array:
    """Apply the policy's fake-quantization to a float tensor.

    - per-network granularity uses ``policy.network_frac_bits`` (e.g. Q7.9).
    - otherwise the exponent comes from ``frozen_n`` when given (EVAL/PTQ) or
      is reassessed from the live tensor (QAT), per the paper.
    - asymmetric / non-pow2 variants use the affine quantizer.
    """
    if not policy.enabled:
        return x
    if not policy.power_of_two or not policy.symmetric:
        sg = jax.lax.stop_gradient(x)
        if channel_axis is None:
            hi, lo = jnp.max(sg), jnp.min(sg)
        else:
            axes = tuple(a for a in range(x.ndim) if a != channel_axis % x.ndim)
            hi = jnp.max(sg, axis=axes, keepdims=True)
            lo = jnp.min(sg, axis=axes, keepdims=True)
        if policy.symmetric:
            amax = jnp.maximum(jnp.abs(hi), jnp.abs(lo))
            scale = jnp.maximum(amax, 1e-12) / qformat.qmax(width)
            zero = jnp.zeros_like(scale)
        else:
            scale = jnp.maximum(hi - lo, 1e-12) / (qformat.qmax(width) - qformat.qmin(width))
            zero = jnp.round(-lo / scale) + qformat.qmin(width)
        return fake_quant_affine(x, scale, zero, width)

    if policy.granularity is Granularity.PER_NETWORK and policy.network_frac_bits is not None:
        n = jnp.asarray(policy.network_frac_bits, jnp.int32)
    elif frozen_n is not None:
        n = frozen_n
    else:
        ca = channel_axis if policy.granularity is Granularity.PER_CHANNEL else None
        n = dynamic_frac_bits(x, width, channel_axis=ca)
    ca = channel_axis if policy.granularity is Granularity.PER_CHANNEL else None
    return fake_quant(x, _broadcast_n(n, x, ca), width)


def quantize_weight(x, policy: QuantPolicy, *, channel_axis=None, frozen_n=None):
    return quantize_value(
        x, policy, policy.weight_bits, channel_axis=channel_axis, frozen_n=frozen_n
    )


def quantize_activation(x, policy: QuantPolicy, *, frozen_n=None):
    # Activations are always per-tensor (per-layer) in the paper; per-channel
    # activation scales would break the single-shift requantization.
    return quantize_value(x, policy, policy.act_bits, channel_axis=None, frozen_n=frozen_n)
