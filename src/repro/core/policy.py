"""Quantization policy — which tensors get quantized, how wide, what granularity.

Mirrors the paper's configuration space (Sec. 4.1.2/4.1.3 + Sec. 7 discussion):

  * widths: 8 / 9 / 16 bits (int9 is the Appendix-B PTQ variant); 4 is a
    beyond-paper extension for weight-only serving.
  * granularity: per-network (single n, e.g. Q7.9 => n=9), per-layer
    (paper default for int8), per-channel (paper's future work; implemented).
  * mode: off | qat (fake-quant fwd, STE bwd, ranges reassessed every step)
          | calib (float fwd, record activation ranges)
          | eval (fake-quant with frozen scales)
          | integer (true int storage + int accumulators — serving path)
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class QMode(enum.Enum):
    OFF = "off"
    QAT = "qat"
    CALIB = "calib"
    EVAL = "eval"
    INTEGER = "integer"


class Granularity(enum.Enum):
    PER_NETWORK = "per_network"
    PER_LAYER = "per_layer"
    PER_CHANNEL = "per_channel"


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Static quantization configuration (hashable; safe as a jit static arg)."""

    mode: QMode = QMode.OFF
    weight_bits: int = 8
    act_bits: int = 8
    # Accumulators are 2x operand width (paper Sec. 5.8); bias stored at
    # accumulator width like TFLite/the paper's int32 biases.
    granularity: Granularity = Granularity.PER_LAYER
    # Per-network mode: one exponent for the whole net (paper's Q7.9 int16).
    network_frac_bits: Optional[int] = None
    # Asymmetric-range / non-pow2 scaling are the TFLite-style refinements the
    # paper benchmarks against and lists as future work; kept as explicit
    # switches so the comparison is runnable (beyond-paper).
    symmetric: bool = True
    power_of_two: bool = True
    # Skip quantizing these layer kinds (router logits, norms are fp per
    # DESIGN.md §5).
    skip_kinds: tuple = ("router", "norm", "ssm_state")

    @property
    def enabled(self) -> bool:
        return self.mode != QMode.OFF

    def with_mode(self, mode: QMode) -> "QuantPolicy":
        return dataclasses.replace(self, mode=mode)

    @staticmethod
    def float32() -> "QuantPolicy":
        return QuantPolicy(mode=QMode.OFF)

    @staticmethod
    def int16_ptq() -> "QuantPolicy":
        """Paper's int16 flow: PTQ, per-network Q7.9 (n = 9)."""
        return QuantPolicy(
            mode=QMode.EVAL,
            weight_bits=16,
            act_bits=16,
            granularity=Granularity.PER_NETWORK,
            network_frac_bits=9,
        )

    @staticmethod
    def int8_qat() -> "QuantPolicy":
        """Paper's int8 flow: QAT, per-layer pow2 scales."""
        return QuantPolicy(mode=QMode.QAT, weight_bits=8, act_bits=8)

    @staticmethod
    def int9_ptq() -> "QuantPolicy":
        """Appendix-B variant: int9 PTQ beats int8 QAT."""
        return QuantPolicy(mode=QMode.EVAL, weight_bits=9, act_bits=9)

    @staticmethod
    def serve_int8() -> "QuantPolicy":
        """Integer serving path (true int8 storage + int32 accumulation)."""
        return QuantPolicy(mode=QMode.INTEGER, weight_bits=8, act_bits=8)
