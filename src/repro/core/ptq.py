"""Post-training quantization (paper Sec. 4.2) + activation calibration.

Flow:
  1. Train the float network.
  2. (activations) run ``calibrate`` over a few batches with the policy in
     CALIB mode — the model records max-|x| per quant site; exponents are
     derived with Eq. 1-2 and frozen.
  3. (weights) exponents come analytically from the tensors (Sec. 4.1.4),
     or from ``network_frac_bits`` in per-network mode (the paper's Q7.9).
  4. Evaluate with EVAL mode (fake-quant on frozen scales) or deploy with
     :mod:`repro.core.integerize` (true integers).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.core import qformat
from repro.core.policy import Granularity, QMode, QuantPolicy


def ranges_to_qstate(
    ranges: Dict[str, jax.Array], policy: QuantPolicy
) -> Dict[str, jax.Array]:
    """Convert recorded max-|x| stats to frozen exponents (Eq. 1-2)."""
    if policy.granularity is Granularity.PER_NETWORK and policy.network_frac_bits is not None:
        n_fixed = jnp.asarray(policy.network_frac_bits, jnp.int32)
        return {k: n_fixed for k in ranges}
    return {k: qformat.frac_bits_for(v, policy.act_bits) for k, v in ranges.items()}


def calibrate(
    apply_fn: Callable,
    params,
    batches: Iterable,
    policy: QuantPolicy,
    *,
    existing: Optional[Dict[str, jax.Array]] = None,
    observer="minmax",
) -> Dict[str, jax.Array]:
    """Run CALIB-mode forward passes, return frozen activation exponents.

    ``apply_fn(params, batch, ctx) -> (out, stats)`` must thread a Context in
    CALIB mode and return the collected stats dict (see
    :func:`repro.train.trainer.make_calib_step` for the jit'd builder).

    ``observer`` picks the range-accumulation strategy — ``"minmax"``
    (default, the stream's true envelope; exactly the historical behavior),
    ``"ema"``, or an instance from :mod:`repro.core.observers`.
    """
    from repro.core.observers import make_observer
    from repro.nn.module import Context

    calib_policy = policy.with_mode(QMode.CALIB)

    @jax.jit
    def step(p, batch):
        ctx = Context(policy=calib_policy, train=False)
        apply_fn(p, batch, ctx)
        return ctx.stats

    obs = make_observer(observer)
    if existing:
        obs.observe(existing)
    for batch in batches:
        obs.observe(step(params, batch))
    return obs.qstate(policy)
