"""repro.core — the paper's contribution: Qm.n power-of-two fixed-point
quantization (PTQ + QAT, Sec. 4) and the integer inference-engine semantics
(Sec. 5.8), plus the MCU cost model (Appendix E)."""
from repro.core.policy import Granularity, QMode, QuantPolicy  # noqa: F401
from repro.core.qformat import (  # noqa: F401
    QTensor,
    dequantize,
    frac_bits_for,
    integer_bits,
    quantize,
    quantize_dequantize,
    quantize_tensor,
    requantize,
)
from repro.core.quantizers import (  # noqa: F401
    fake_quant,
    fake_quant_affine,
    quantize_activation,
    quantize_weight,
)
