"""Activation-range observers for PTQ calibration (prepare → observe → convert).

The torch-AO style flow: an observer object rides the calibration loop,
absorbing each batch's per-site max-|x| statistics (the model records them
under a CALIB-mode :class:`~repro.nn.module.Context`), then *converts* the
accumulated ranges into frozen pow2 exponents (the qstate consumed by EVAL
fake-quant and by :mod:`repro.core.integerize`).  Two strategies:

* :class:`MinMaxObserver` — running max over the whole stream.  Order- and
  permutation-invariant: shuffling the calibration batches cannot change the
  result.  This is what :func:`repro.core.ptq.calibrate` historically did
  inline, now factored so it is swappable.
* :class:`EMAObserver` — exponential moving average of per-batch maxima.
  A single outlier batch moves the range only by ``(1 - decay)`` of its
  excess, so the exponent tracks the stream's *typical* range rather than
  its worst spike — the standard sub-int8 calibration choice, where a grid
  of 8 or 4 values cannot afford to spend headroom on a one-off.

:func:`calibrate_tokens` runs the flow over a real token stream for LM
models (``model.apply(params, tokens, ctx)``), which is how the serve path
calibrates activation exponents before :func:`repro.core.integerize.
integerize_weights_only` packs sub-int8 weights.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Union

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class MinMaxObserver:
    """Running max-|x| per quant site — the stream's true envelope."""

    ranges: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)

    def observe(self, stats: Dict[str, jax.Array]) -> None:
        for k, v in stats.items():
            v = jnp.asarray(v, jnp.float32)
            self.ranges[k] = (jnp.maximum(self.ranges[k], v)
                              if k in self.ranges else v)

    def qstate(self, policy) -> Dict[str, jax.Array]:
        from repro.core.ptq import ranges_to_qstate

        return ranges_to_qstate(dict(self.ranges), policy)


@dataclasses.dataclass
class EMAObserver:
    """EMA of per-batch max-|x| — converges to the stream's running range.

    The first batch seeds the average directly (no zero-bias warmup), so a
    constant-range stream yields exactly that range at any decay.
    """

    decay: float = 0.9
    ranges: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)

    def observe(self, stats: Dict[str, jax.Array]) -> None:
        d = jnp.float32(self.decay)
        for k, v in stats.items():
            v = jnp.asarray(v, jnp.float32)
            self.ranges[k] = (d * self.ranges[k] + (1.0 - d) * v
                              if k in self.ranges else v)

    def qstate(self, policy) -> Dict[str, jax.Array]:
        from repro.core.ptq import ranges_to_qstate

        return ranges_to_qstate(dict(self.ranges), policy)


Observer = Union[MinMaxObserver, EMAObserver]

_OBSERVERS = {"minmax": MinMaxObserver, "ema": EMAObserver}


def make_observer(kind: Union[str, Observer] = "minmax", **kw) -> Observer:
    """``"minmax"`` / ``"ema"`` (plus kwargs) or a ready observer instance."""
    if not isinstance(kind, str):
        return kind
    try:
        return _OBSERVERS[kind](**kw)
    except KeyError:
        raise ValueError(
            f"unknown observer {kind!r}; expected one of {sorted(_OBSERVERS)}"
        ) from None


def calibrate_tokens(
    model,
    params,
    token_batches: Iterable,
    policy,
    *,
    observer: Union[str, Observer] = "minmax",
    existing: Optional[Dict[str, jax.Array]] = None,
) -> Dict[str, jax.Array]:
    """Calibrate activation exponents for an LM from a real token stream.

    ``token_batches`` yields int32 token arrays ``(B, T)``; each is run
    through ``model.apply`` under a CALIB-mode Context and the recorded
    max-|x| stats are folded into the observer.  Returns the frozen qstate
    dict ``{site: n}`` ready for EVAL / integerized serving.
    """
    from repro.core.policy import QMode
    from repro.nn.module import Context

    obs = make_observer(observer)
    if existing:
        obs.observe(existing)
    calib_policy = policy.with_mode(QMode.CALIB)

    @jax.jit
    def step(p, toks):
        ctx = Context(policy=calib_policy, train=False)
        model.apply(p, toks, ctx)
        return ctx.stats

    for toks in token_batches:
        obs.observe(step(params, jnp.asarray(toks, jnp.int32)))
    return obs.qstate(policy)
