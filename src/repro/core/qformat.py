"""Qm.n fixed-point format math — the paper's quantization scheme (Sec. 4.1).

The paper (Novac et al., Sensors 2021) quantizes with a *uniform, symmetric,
power-of-two* scale factor:

    m = 1 + floor(log2(max_i |x_i|))          (Eq. 1)  integer bits (incl. none)
    n = w - m - 1                             (Eq. 2)  fractional bits
    x_fixed = trunc(x * 2^n)                  (Eq. 3)
    s = 2^-n                                  (Eq. 4)  scale factor

`m` may be negative (leading unused fractional bits reclaimed as precision);
`n` may be negative (very large ranges).  All arithmetic on scale factors is
done on the *exponent* `n` (an int32), so rescaling is an exact bit-shift —
never a floating-point multiply — exactly as on the paper's Cortex-M4 target
and on the TPU integer path.

Everything here is pure jnp and jittable.  Granularity is expressed by the
shape of `n`: scalar (per-tensor / per-network) or a vector broadcast along a
channel axis (per-channel, the paper's declared future work, implemented here
as a beyond-paper extension).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Clamp for the fractional-bit exponent.  |n| beyond 30 makes 2^n overflow
# int32 shift semantics and never occurs for sane data; the clamp also handles
# all-zero tensors (max_abs == 0) gracefully.
N_MIN = -30
N_MAX = 30

_INT_DTYPES = {2: jnp.int8, 4: jnp.int8, 8: jnp.int8, 9: jnp.int16, 16: jnp.int16,
               32: jnp.int32}
_ACC_DTYPES = {2: jnp.int32, 4: jnp.int32, 8: jnp.int32, 9: jnp.int32, 16: jnp.int32,
               32: jnp.int64}


def storage_dtype(width: int):
    """Smallest machine integer dtype that holds a `width`-bit value.

    The paper stores int9 (Appendix B) in int16 containers; int4 (beyond-paper)
    packs into int8 containers.
    """
    return _INT_DTYPES[width]


def accumulator_dtype(width: int):
    """2x-operand-width accumulator dtype (paper Sec. 5.8)."""
    return _ACC_DTYPES[width]


def qmin(width: int) -> int:
    return -(2 ** (width - 1))


def qmax(width: int) -> int:
    return 2 ** (width - 1) - 1


def integer_bits(max_abs: jax.Array) -> jax.Array:
    """Eq. 1: required signed-integer bits m for a given max |x|.

    Uses floor(log2(.)) + 1.  For max_abs == 0 the result is driven to a large
    negative value and later clamped via N_MAX.
    """
    max_abs = jnp.asarray(max_abs, jnp.float32)
    safe = jnp.maximum(max_abs, 2.0 ** (-(N_MAX + 1)))
    return 1 + jnp.floor(jnp.log2(safe)).astype(jnp.int32)


def frac_bits_for(max_abs: jax.Array, width: int) -> jax.Array:
    """Eq. 2: fractional bits n = w - m - 1, clamped to [N_MIN, N_MAX]."""
    m = integer_bits(max_abs)
    n = jnp.int32(width) - m - 1
    return jnp.clip(n, N_MIN, N_MAX)


def max_abs(x: jax.Array, axis=None) -> jax.Array:
    """Range statistic used by the paper: max |x| (optionally per-channel)."""
    return jnp.max(jnp.abs(x), axis=axis)


def scale_from_n(n: jax.Array) -> jax.Array:
    """Eq. 4: s = 2^-n, as float32 (used only on the fake-quant/float path)."""
    return jnp.exp2(-n.astype(jnp.float32))


def quantize(x: jax.Array, n: jax.Array, width: int) -> jax.Array:
    """Eq. 3 + saturation: x_q = sat(trunc(x * 2^n)).

    Truncation (toward zero) matches the paper's `trunc`; saturation matches
    `clamp_to_number_t`.  Returns the storage dtype for `width`.
    """
    xf = x.astype(jnp.float32) * jnp.exp2(n.astype(jnp.float32))
    xq = jnp.trunc(xf)
    xq = jnp.clip(xq, qmin(width), qmax(width))
    return xq.astype(storage_dtype(width))


def dequantize(xq: jax.Array, n: jax.Array, width: int = 0) -> jax.Array:
    """x = x_q * 2^-n, as float32."""
    del width
    return xq.astype(jnp.float32) * jnp.exp2(-n.astype(jnp.float32))


def quantize_dequantize(x: jax.Array, n: jax.Array, width: int) -> jax.Array:
    """Fake-quantization: the value set of Qm.n, represented in float.

    This is the forward used during QAT (paper Sec. 4.3: computations stay in
    float but operands are constrained to the quantized value grid).
    """
    xf = x.astype(jnp.float32) * jnp.exp2(n.astype(jnp.float32))
    xq = jnp.clip(jnp.trunc(xf), qmin(width), qmax(width))
    return xq * jnp.exp2(-n.astype(jnp.float32))


def requantize(acc: jax.Array, n_in: jax.Array, n_out: jax.Array, width: int) -> jax.Array:
    """Shift a 2x-width accumulator from format n_in to n_out and saturate.

    Paper Sec. 5.8: after an integer multiply the fractional bits of the
    operands add up; the result is shifted right back to the output format and
    saturated to the operand width.  `n_in - n_out` is the right-shift amount;
    implemented as an exact arithmetic shift (with a left shift when the
    output format has more fractional bits).
    """
    shift = (n_in - n_out).astype(jnp.int32)
    shift_b = jnp.broadcast_to(shift, acc.shape)
    # Work at 2x the accumulator width: a left shift may overflow the
    # accumulator *before* saturation (found by hypothesis —
    # tests/test_properties.py::test_requantize_matches_float_semantics).
    # On the MCU/TPU engine this is the SSAT-before-write rule; here the
    # pre-saturation guard compares against qmax >> lshift instead.
    acc64 = acc.astype(jnp.int64)
    rsh = jnp.clip(shift_b, 0, 62)
    lsh = jnp.clip(-shift_b, 0, 62)
    right = jnp.right_shift(acc64, rsh.astype(jnp.int64))
    lim = jnp.right_shift(jnp.int64(qmax(width)), lsh.astype(jnp.int64))
    sat = jnp.where(acc64 >= 0, jnp.int64(qmax(width)), jnp.int64(qmin(width)))
    left = jnp.where(jnp.abs(acc64) > lim, sat,
                     jnp.left_shift(acc64, lsh.astype(jnp.int64)))
    out = jnp.where(shift_b >= 0, right, left)
    out = jnp.clip(out, qmin(width), qmax(width))
    return out.astype(storage_dtype(width))


def align(xq: jax.Array, n_x: jax.Array, n_common: jax.Array, acc_dtype=jnp.int32) -> jax.Array:
    """Align an operand to a common Qm.n before add/sub (paper Sec. 5.8).

    Returns the accumulator dtype; shifts are exact.
    """
    acc = xq.astype(acc_dtype)
    shift = (n_common - n_x).astype(jnp.int32)
    shift_b = jnp.broadcast_to(shift, acc.shape)
    left = jnp.left_shift(acc, jnp.maximum(shift_b, 0))
    right = jnp.right_shift(acc, jnp.maximum(-shift_b, 0))
    return jnp.where(shift_b >= 0, left, right)


@dataclasses.dataclass(frozen=True)
class QTensor:
    """An integerized tensor: storage integers + fractional-bit exponent(s).

    `n` is an int32 scalar (per-tensor) or a vector aligned with `channel_axis`
    (per-channel).  Registered as a pytree so it can live inside param trees,
    be donated, sharded and checkpointed like any other leaf pair.
    """

    q: jax.Array
    n: jax.Array
    width: int
    channel_axis: Optional[int] = None

    def dequantize(self) -> jax.Array:
        n = self.n
        if self.channel_axis is not None and jnp.ndim(n) > 0:
            shape = [1] * self.q.ndim
            shape[self.channel_axis] = -1
            n = n.reshape(shape)
        return dequantize(self.q, n)

    @property
    def shape(self):
        return self.q.shape

    @property
    def nbytes_model(self) -> int:
        """Model-ROM bytes at the *logical* width (paper Table A3 semantics)."""
        return int(np.prod(self.q.shape)) * self.width // 8


# --------------------------------------------------------------------------
# Sub-int8 packed storage (beyond-paper: int4/int2 weight frontier)
# --------------------------------------------------------------------------

def lanes_per_byte(width: int) -> int:
    """How many ``width``-bit lanes fit one int8 container byte (4->2, 2->4)."""
    if width not in (2, 4):
        raise ValueError(f"packed storage supports widths 2 and 4, got {width}")
    return 8 // width


def pack_subint8(q: jax.Array, width: int, axis: int = -2) -> jax.Array:
    """Pack ``width``-bit signed integers along ``axis`` into int8 bytes.

    Lane ``i`` of a byte holds logical element ``lanes*j + i`` in bits
    ``[width*i, width*(i+1))`` (two's complement), so lane 0 is the *low*
    nibble — the layout ``wq4_matmul``'s in-kernel unpack assumes.  A length
    not divisible by the lane count is zero-padded; the pad nibbles
    dequantize to 0 and are sliced away by :func:`unpack_subint8`.
    """
    lanes = lanes_per_byte(width)
    q = jnp.asarray(q)
    ax = axis % q.ndim
    k = q.shape[ax]
    pad = (-k) % lanes
    if pad:
        spec = [(0, 0)] * q.ndim
        spec[ax] = (0, pad)
        q = jnp.pad(q, spec)
    moved = jnp.moveaxis(q, ax, -1).astype(jnp.int32)
    grp = moved.reshape(*moved.shape[:-1], -1, lanes)
    mask = (1 << width) - 1
    acc = jnp.zeros(grp.shape[:-1], jnp.int32)
    for i in range(lanes):
        acc = acc | ((grp[..., i] & mask) << (width * i))
    packed = jax.lax.bitcast_convert_type(acc.astype(jnp.uint8), jnp.int8)
    return jnp.moveaxis(packed, -1, ax)


def unpack_subint8(packed: jax.Array, width: int, k: int, axis: int = -2) -> jax.Array:
    """Inverse of :func:`pack_subint8`: int8 bytes -> ``k`` signed lanes.

    Bit-exact round trip for any value on the ``width``-bit grid and any
    lane alignment (``k`` need not divide the lane count).
    """
    lanes = lanes_per_byte(width)
    ax = axis % packed.ndim
    moved = jnp.moveaxis(packed, ax, -1)
    u = jax.lax.bitcast_convert_type(moved, jnp.uint8).astype(jnp.int32)
    mask = (1 << width) - 1
    half = 1 << (width - 1)
    vals = jnp.stack([(u >> (width * i)) & mask for i in range(lanes)], axis=-1)
    vals = jnp.where(vals >= half, vals - (1 << width), vals)
    flat = vals.reshape(*vals.shape[:-2], -1)[..., :k].astype(jnp.int8)
    return jnp.moveaxis(flat, -1, ax)


def block_frac_bits(x: jax.Array, width: int, block_size: int,
                    axis: int = -2) -> jax.Array:
    """Per-block (MX-style) exponents: Eq. 1-2 over ``block_size`` runs of
    ``axis``.  Returns the exponent grid with ``axis`` shrunk to the number
    of blocks (the trailing partial block, if any, is ranged over its real
    elements only — zero-padding cannot inflate a block's scale).
    """
    ax = axis % x.ndim
    k = x.shape[ax]
    pad = (-k) % block_size
    if pad:
        spec = [(0, 0)] * x.ndim
        spec[ax] = (0, pad)
        x = jnp.pad(x, spec)
    moved = jnp.moveaxis(x, ax, -1)
    grp = moved.reshape(*moved.shape[:-1], -1, block_size)
    ma = jnp.max(jnp.abs(grp), axis=-1)
    return jnp.moveaxis(frac_bits_for(ma, width), -1, ax)


def _qtensor_flatten(t: QTensor):
    return (t.q, t.n), (t.width, t.channel_axis)


def _qtensor_unflatten(aux, children):
    q, n = children
    width, channel_axis = aux
    return QTensor(q=q, n=n, width=width, channel_axis=channel_axis)


jax.tree_util.register_pytree_node(QTensor, _qtensor_flatten, _qtensor_unflatten)


def quantize_tensor(
    x: jax.Array,
    width: int,
    *,
    channel_axis: Optional[int] = None,
    n_override: Optional[jax.Array] = None,
) -> QTensor:
    """Quantize a float tensor to a QTensor per the paper's method (Sec 4.1.4).

    channel_axis=None  -> per-tensor scale (paper's per-layer mode)
    channel_axis=k     -> per-channel scale along axis k (beyond-paper)
    channel_axis=(a,b) -> per-(a,b) scales, e.g. (0, -1) on scan-stacked
                          kernels = per-layer-per-channel (beyond-paper);
                          n is stored broadcast-shaped (kept dims + 1s)
    n_override         -> externally chosen exponent (paper's per-network mode,
                          e.g. Q7.9 => n = 9 for the whole net)
    """
    if n_override is not None:
        n = jnp.asarray(n_override, jnp.int32)
        nb = n
        if isinstance(channel_axis, int) and jnp.ndim(n) > 0:
            shape = [1] * x.ndim
            shape[channel_axis] = -1
            nb = n.reshape(shape)
        return QTensor(quantize(x, nb, width), n, width,
                       channel_axis if isinstance(channel_axis, int) else None)
    if channel_axis is None:
        n = frac_bits_for(max_abs(x), width)
        return QTensor(quantize(x, n, width), n, width, None)
    if isinstance(channel_axis, tuple):
        keep = tuple(a % x.ndim for a in channel_axis)
        axes = tuple(a for a in range(x.ndim) if a not in keep)
        ma = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
        n = frac_bits_for(ma, width)          # broadcast-shaped exponents
        return QTensor(quantize(x, n, width), n, width, None)
    axes = tuple(a for a in range(x.ndim) if a != channel_axis % x.ndim)
    n = frac_bits_for(max_abs(x, axis=axes), width)
    shape = [1] * x.ndim
    shape[channel_axis] = -1
    return QTensor(quantize(x, n.reshape(shape), width), n, width, channel_axis % x.ndim)


@dataclasses.dataclass(frozen=True)
class PackedQTensor:
    """A sub-int8 weight tensor: packed int8 container + pow2 exponents.

    Storage is ``width``-bit (4 or 2) two's-complement lanes packed along the
    *contraction* axis (axis -2 of a ``(..., K, N)`` GEMM weight — see
    :func:`pack_subint8`), so ``q`` has shape ``(..., ceil(K/lanes), N)`` and
    the container holds ``width/8`` bytes per logical element — the ROM /
    HBM-bandwidth halving below int8.

    ``n`` carries the exponents on the paper's pow2 grid:

    * scalar                       — per-tensor
    * ``(..., 1, N)``              — per-output-channel (``block_size=None``)
    * ``(..., ceil(K/bs), N)``     — per-block (MX-style), ``block_size=bs``
      runs of K share one exponent

    Registered as a pytree (``q``/``n`` are children; ``width``, ``k`` and
    ``block_size`` static aux), so packed weights ride param trees, jit
    donation and ``lax.scan`` stacking exactly like :class:`QTensor`.
    """

    q: jax.Array
    n: jax.Array
    width: int
    k: int
    block_size: Optional[int] = None

    @property
    def shape(self):
        """Logical (unpacked) shape ``(..., K, N)``."""
        return (*self.q.shape[:-2], self.k, self.q.shape[-1])

    @property
    def nbytes_packed(self) -> int:
        """Actual container bytes (int8 payload; scales excluded)."""
        return int(np.prod(self.q.shape))

    @property
    def nbytes_model(self) -> int:
        """Model-ROM bytes at the logical width (Table A3 semantics)."""
        return int(np.prod(self.shape)) * self.width // 8

    def unpack(self) -> jax.Array:
        """The int8-held ``width``-bit integers, unpacked to ``(..., K, N)``."""
        return unpack_subint8(self.q, self.width, self.k, axis=-2)

    def scales(self) -> jax.Array:
        """Float ``2^-n`` broadcastable against the unpacked ``(..., K, N)``."""
        n = self.n
        if self.block_size is not None and jnp.ndim(n) > 0:
            n = jnp.repeat(n, self.block_size, axis=-2)[..., : self.k, :]
        return jnp.exp2(-jnp.asarray(n, jnp.float32))

    def dequantize(self) -> jax.Array:
        """Float reconstruction: unpack * 2^-n (per-channel or per-block)."""
        return self.unpack().astype(jnp.float32) * self.scales()


def _packed_flatten(t: PackedQTensor):
    return (t.q, t.n), (t.width, t.k, t.block_size)


def _packed_unflatten(aux, children):
    q, n = children
    width, k, block_size = aux
    return PackedQTensor(q=q, n=n, width=width, k=k, block_size=block_size)


jax.tree_util.register_pytree_node(PackedQTensor, _packed_flatten, _packed_unflatten)


def quantize_tensor_packed(
    x: jax.Array,
    width: int,
    *,
    block_size: Optional[int] = None,
    per_channel: bool = True,
) -> PackedQTensor:
    """Quantize a ``(..., K, N)`` weight to packed ``width``-bit storage.

    ``block_size=None`` uses one exponent per output channel over the whole
    K axis (the per-channel Qm.n grid at sub-int8 width); ``block_size=bs``
    gives every ``bs``-run of K its own exponent (MX-style block scaling —
    tighter grids where a channel's dynamic range varies along K).
    ``per_channel=False`` with ``block_size=None`` collapses to a single
    per-tensor exponent.
    """
    if x.ndim < 2:
        raise ValueError(f"packed weights need ndim >= 2, got {x.ndim}")
    lanes = lanes_per_byte(width)
    k = x.shape[-2]
    if block_size is not None:
        if block_size < lanes or block_size % lanes:
            raise ValueError(
                f"block_size must be a positive multiple of {lanes} "
                f"(the byte lane count at width {width}), got {block_size}")
        n = block_frac_bits(x, width, block_size, axis=-2)
        nb = jnp.repeat(n, block_size, axis=-2)[..., :k, :]
    elif per_channel:
        n = frac_bits_for(jnp.max(jnp.abs(x), axis=-2, keepdims=True), width)
        nb = n
    else:
        n = frac_bits_for(max_abs(x), width)
        nb = n
    q = quantize(x, nb, width)
    return PackedQTensor(pack_subint8(q, width, axis=-2), n, width, k, block_size)
