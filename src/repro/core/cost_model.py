"""Analytic MCU cost model — the paper's Appendix E / Tables A4-A6, kept as a
first-class artifact so the original deployment story stays reproducible even
though this framework's runtime target is TPU.

Per-layer integer-ALU op counts (Appendix E, Table A6) with Cortex-M4 cycle
weights: MACC=1, add=1, shift=1, max/saturate=2 (the compiler's cmp+csel pair
— the paper notes SSAT is *not* emitted).  Energy model: E = I * V * t from
Table 3 board constants.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

# Cycle weights (Appendix E).
CYCLES = {"macc": 1, "add": 1, "shift": 1, "maxsat": 2}

# Table 3 board constants.
BOARDS = {
    # name: (run current A @3.3V 48MHz, supply V, clock Hz, coremark/MHz)
    "nucleo-l452re-p": (4.80e-3, 3.3, 48e6, 3.42),
    "sparkfun-edge": (0.82e-3, 3.3, 48e6, 2.479),
}


@dataclasses.dataclass
class OpCount:
    macc: int = 0
    add: int = 0
    shift: int = 0
    maxsat: int = 0

    def __add__(self, o: "OpCount") -> "OpCount":
        return OpCount(self.macc + o.macc, self.add + o.add,
                       self.shift + o.shift, self.maxsat + o.maxsat)

    @property
    def cycles(self) -> int:
        return (self.macc * CYCLES["macc"] + self.add * CYCLES["add"]
                + self.shift * CYCLES["shift"] + self.maxsat * CYCLES["maxsat"])


def conv1d_ops(f: int, s: int, c: int, k: int) -> OpCount:
    """Conv1D (Table A6): f*s*c*k MACs, 2*f*s shifts, f*s saturations."""
    return OpCount(macc=f * s * c * k, shift=2 * f * s, maxsat=f * s)


def relu_ops(c: int, s: int) -> OpCount:
    return OpCount(maxsat=c * s)


def maxpool_ops(c: int, s: int, k: int) -> OpCount:
    return OpCount(maxsat=c * s * k)


def add_ops(s: int, c: int, i: int = 2) -> OpCount:
    """Residual Add (Table A6): s*c*(i-1) adds, s*c*i shifts, c*s saturations."""
    return OpCount(add=s * c * (i - 1), shift=s * c * i, maxsat=c * s)


def fully_connected_ops(n: int, s: int) -> OpCount:
    return OpCount(macc=n * s, shift=2 * n, maxsat=n)


def resnet6_ops(filters: int, in_samples: int, in_channels: int,
                kernel: int = 3, pool: int = 4, classes: int = 6) -> OpCount:
    """Op count for the paper's ResNetv1-6 (Fig. 4) on 1D input.

    conv1(f,s,c,k) -> [conv2 -> conv3 + shortcut conv1x1 -> add] -> maxpool
    -> conv4 -> conv5 + add -> global-ish pooling -> FC.  Matches the layer
    list of Fig. 4 (6 convs incl. the 1x1 shortcut, 2 adds, 1 FC).
    """
    f, s, c, k = filters, in_samples, in_channels, kernel
    total = OpCount()
    total += conv1d_ops(f, s, c, k) + relu_ops(f, s)            # conv1 + relu
    total += conv1d_ops(f, s, f, k) + relu_ops(f, s)            # conv2 + relu
    total += conv1d_ops(f, s, f, k)                             # conv3
    total += conv1d_ops(f, s, f, 1)                             # shortcut 1x1
    total += add_ops(s, f) + relu_ops(f, s)                     # add1 + relu
    s2 = s // pool
    total += maxpool_ops(f, s * 1, pool)                        # maxpool k=pool
    total += conv1d_ops(f, s2, f, k) + relu_ops(f, s2)          # conv4 + relu
    total += conv1d_ops(f, s2, f, k)                            # conv5
    total += add_ops(s2, f) + relu_ops(f, s2)                   # add2 + relu
    total += maxpool_ops(f, s2, s2)                             # global maxpool
    total += fully_connected_ops(classes, f)                    # classifier
    return total


def inference_seconds(ops: OpCount, board: str = "nucleo-l452re-p",
                      cpi_overhead: float = 2.0) -> float:
    """Cycles -> seconds at the board clock.

    ``cpi_overhead`` folds loads/stores/branches around the ALU ops (the
    paper's measured times are ~2-3x the pure-ALU cycle count; the *shape*
    across filter sweeps is what Table A4 validates).
    """
    _, _, hz, _ = BOARDS[board]
    return ops.cycles * cpi_overhead / hz


def inference_energy_uwh(seconds: float, board: str = "nucleo-l452re-p") -> float:
    """Energy per inference in µWh (Table A5): E = I*V*t."""
    current, volts, _, _ = BOARDS[board]
    joules = current * volts * seconds
    return joules / 3600.0 * 1e6


def rom_bytes(n_params: int, width_bits: int, code_overhead: int = 40 * 1024) -> int:
    """Model ROM (Table A3): params at width + fixed inference-code overhead."""
    return n_params * width_bits // 8 + code_overhead


@dataclasses.dataclass
class PoolAllocator:
    """The paper's RAM-pool output-buffer allocator (Sec. 5.7).

    Greedy first-fit: each layer output goes to the first pool that neither
    overwrites the layer's own input nor a not-yet-consumed output.  Reports
    total RAM = sum of pool high-water marks — reproduced here because it is
    part of the paper's engine spec (and it doubles as a sanity model for
    activation-memory napkin math).
    """

    pools: List[int] = dataclasses.field(default_factory=list)

    def allocate(self, graph: List[Dict]) -> int:
        """graph: topo-ordered [{'name', 'inputs': [names], 'bytes': int}]."""
        consumers: Dict[str, int] = {}
        for node in graph:
            for inp in node["inputs"]:
                consumers[inp] = consumers.get(inp, 0) + 1
        placement: Dict[str, int] = {}
        live_in_pool: Dict[int, set] = {}
        remaining = dict(consumers)
        for node in graph:
            banned = set()
            for inp in node["inputs"]:
                if inp in placement:
                    banned.add(placement[inp])
            for pid, names in live_in_pool.items():
                if any(remaining.get(nm, 0) > 0 for nm in names):
                    banned.add(pid)
            pool_id = None
            for pid in range(len(self.pools)):
                if pid not in banned:
                    pool_id = pid
                    break
            if pool_id is None:
                pool_id = len(self.pools)
                self.pools.append(0)
                live_in_pool[pool_id] = set()
            self.pools[pool_id] = max(self.pools[pool_id], node["bytes"])
            live_in_pool.setdefault(pool_id, set()).clear()
            live_in_pool[pool_id] = {node["name"]}
            placement[node["name"]] = pool_id
            for inp in node["inputs"]:
                if inp in remaining:
                    remaining[inp] -= 1
        return sum(self.pools)
