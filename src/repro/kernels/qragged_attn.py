"""Pallas TPU kernel: ragged token-batch attention into an int8 KV pool.

The serve path's one-forward-per-tick kernel: a flat batch of T tokens —
decode tokens from every live slot *and* prefill-chunk tokens from several
concurrent admission lanes — attends in a single kernel launch.  Per-token
``slot_ids``/``positions`` vectors replace the mixed step's (scalar slot,
scalar start) chunk metadata: token ``t`` is logical row ``positions[t]`` of
slot ``slot_ids[t]``, its K/V row is quantized onto the paper's Qm.n grid
and written in place into the slot's pages (``input_output_aliases``), and
its query attends flash-style over positions ``<= positions[t]`` of that
slot.  Rows with ``positions[t] < 0`` are inert padding: nothing is written
and the output row is junk (callers gather only the rows they need).

One geometry serves both cache layouts: a paged pool is used as-is with its
page table, and a dense ``(B, S, Hkv, D)`` cache is *viewed* as a pool of
``B * (S // bs)`` pages with the identity table ``arange(B*steps)`` — the
caller (nn/attention.py) reshapes, so this file only ever sees
``(num_pages, page_size, Hkv, D)`` pools.

Correctness of intra-tick visibility (a chunk token attending to earlier
tokens of the *same* chunk, or a later lane row of the same slot) does not
rely on grid-step ordering: every (token, page) grid step re-merges **all**
batch rows of its slot that land in the fetched page in-register (one-hot
matmul, like the chunk kernels), so the pool writes are idempotent and the
flash mask ``pos <= positions[t]`` alone decides visibility.

Page-size note: as with ``qpaged_attn``, blocks are one page, so real-TPU
runs want ``page_size`` at sublane-tile granularity; tests run in interpret
mode where any size works.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
I8_MIN, I8_MAX = -128, 127


def _quantize_i8(x: jax.Array, inv_scale: jax.Array) -> jax.Array:
    """sat(trunc(x * 2^n)) on the paper grid; inv_scale = 2^n (exact pow2)."""
    xf = x * inv_scale
    xq = jnp.where(xf >= 0, jnp.floor(xf), jnp.ceil(xf))  # trunc toward zero
    return jnp.clip(xq, I8_MIN, I8_MAX).astype(jnp.int8)


def _qragged_kernel(
    table_ref, slots_ref, pos_ref, scales_ref, slv_ref, pvv_ref,
    q_ref, kc_ref, vc_ref, k_ref, v_ref,
    o_ref, ko_ref, vo_ref, m_ref, l_ref, acc_ref,
    *, g: int, ps: int, n_pages: int, sm_scale: float,
):
    it, ip = pl.program_id(1), pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    my_slot = slots_ref[it]
    my_pos = pos_ref[it]
    k_scale = scales_ref[0]
    v_scale = scales_ref[1]

    # Page blocks past the token's own page clamp onto it in the index maps
    # (no new DMA); the revisit re-merges idempotently and skips the flash.
    # Inert rows (my_pos < 0) degrade to last = 0 with an all-masked flash.
    last = jnp.minimum(jnp.maximum(my_pos, 0) // ps, n_pages - 1)
    ip_eff = jnp.minimum(ip, last)
    pos = ip_eff * ps + jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)[:, 0]

    # -- fused quantize-on-write: merge *every* batch row of my slot landing
    # in this logical page (one-hot matmul over the full token batch; pad
    # rows carry position -1 and can never match a page row >= 0).
    sl = slv_ref[:, 0]                                  # (T,) slot per token
    pv = pvv_ref[:, 0]                                  # (T,) position
    oh = (pos[:, None] == pv[None, :]) & (sl[None, :] == my_slot)
    ohf = oh.astype(jnp.float32)
    k_rows = jnp.dot(ohf, kc_ref[0], preferred_element_type=jnp.float32)
    v_rows = jnp.dot(ohf, vc_ref[0], preferred_element_type=jnp.float32)
    written = jnp.any(oh, axis=1)
    k8 = jnp.where(written[:, None],
                   _quantize_i8(k_rows, 1.0 / k_scale), k_ref[0, :, 0, :])
    v8 = jnp.where(written[:, None],
                   _quantize_i8(v_rows, 1.0 / v_scale), v_ref[0, :, 0, :])
    ko_ref[0, :, 0, :] = k8
    vo_ref[0, :, 0, :] = v8

    # -- flash update over the merged page: token t sees positions
    # <= positions[t] (its own row included — standard causal self-visit).
    # Inert rows skip the flash outright: a fully-masked block would push
    # p = exp(NEG_INF - NEG_INF) = 1 uniform junk; skipping leaves l = 0 so
    # the guarded division emits exact zeros, matching the oracle.
    @pl.when((ip <= last) & (my_pos >= 0))
    def _flash():
        kf = k8.astype(jnp.float32) * k_scale
        vf = v8.astype(jnp.float32) * v_scale
        q = q_ref[0, 0]                                 # (G, D)
        s_blk = jnp.dot(q, kf.T, preferred_element_type=jnp.float32) * sm_scale
        s_blk = jnp.where(pos[None, :] <= my_pos, s_blk, NEG_INF)

        m_prev = m_ref[...]                             # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s_blk, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s_blk - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, vf, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qragged_attn_pallas(
    q: jax.Array,          # (T, Hq, D) f32, RoPE'd ragged-batch queries
    k_new: jax.Array,      # (T, Hkv, D) f32, RoPE'd ragged-batch keys
    v_new: jax.Array,      # (T, Hkv, D) f32
    k_pool: jax.Array,     # (P, ps, Hkv, D) int8
    v_pool: jax.Array,
    k_n: jax.Array,        # scalar int32 dequant exponents (paper Qm.n grid)
    v_n: jax.Array,
    table: jax.Array,      # (slots, max_pages) int32 pool indices, -1 unmapped
    slot_ids: jax.Array,   # (T,) int32 target slot per token
    positions: jax.Array,  # (T,) int32 logical cache row per token; -1 = pad
    *,
    interpret: bool = False,
):
    """Ragged-batch attention + fused quantize-on-write into pool pages.

    Token ``t``'s K/V row lands at logical row ``positions[t]`` of slot
    ``slot_ids[t]`` (quantized in place through the page table); its query
    attends over that slot's positions ``<= positions[t]``.  All pages
    covering ``[0, positions[t]]`` must be mapped for active tokens — the
    serve allocator guarantees this at admission.  Rows with
    ``positions[t] < 0`` write nothing and produce junk output rows.

    Returns ``(out (T, Hq, D), k_pool', v_pool')`` — pools updated in place;
    pages holding no batch row pass through untouched via aliasing.
    """
    t, hq, d = q.shape
    n_pool, ps, hkv, _ = k_pool.shape
    g = hq // hkv
    max_pages = table.shape[1]
    sm_scale = 1.0 / (d ** 0.5)

    qg = q.reshape(t, hkv, g, d).transpose(1, 0, 2, 3)   # (Hkv, T, G, D)
    kc = k_new.transpose(1, 0, 2)                        # (Hkv, T, D)
    vc = v_new.transpose(1, 0, 2)
    table = jnp.asarray(table, jnp.int32)
    slots = jnp.asarray(slot_ids, jnp.int32).reshape(-1)
    posv = jnp.asarray(positions, jnp.int32).reshape(-1)
    scales = jnp.stack([jnp.exp2(-k_n.astype(jnp.float32)),
                        jnp.exp2(-v_n.astype(jnp.float32))])

    def _pool_idx(ih, it, ip, table, slots, pos):
        # clamp past-the-token's-page steps onto its page (the revisit skips
        # the DMA), then translate logical page -> pool page via the table;
        # unmapped (-1, only reachable for inert rows) clamps to pool page 0,
        # which the kernel reads and writes back byte-identical.
        last = jnp.minimum(jnp.maximum(pos[it], 0) // ps, max_pages - 1)
        page = table[slots[it], jnp.minimum(ip, last)]
        return (jnp.maximum(page, 0), 0, ih, 0)

    pool_spec = pl.BlockSpec((1, ps, 1, d), _pool_idx)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(hkv, t, max_pages),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # scales
            pl.BlockSpec((t, 1), lambda ih, it, ip, *_: (0, 0)),  # slot vec
            pl.BlockSpec((t, 1), lambda ih, it, ip, *_: (0, 0)),  # pos vec
            pl.BlockSpec((1, 1, g, d), lambda ih, it, ip, *_: (ih, it, 0, 0)),
            pl.BlockSpec((1, t, d), lambda ih, it, ip, *_: (ih, 0, 0)),
            pl.BlockSpec((1, t, d), lambda ih, it, ip, *_: (ih, 0, 0)),
            pool_spec,
            pool_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ih, it, ip, *_: (ih, it, 0, 0)),
            pool_spec,
            pool_spec,
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out, k_out, v_out = pl.pallas_call(
        functools.partial(_qragged_kernel, g=g, ps=ps, n_pages=max_pages,
                          sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((hkv, t, g, d), q.dtype),
            jax.ShapeDtypeStruct(k_pool.shape, jnp.int8),
            jax.ShapeDtypeStruct(v_pool.shape, jnp.int8),
        ],
        # indices count the three scalar-prefetch operands: 9/10 are pools.
        input_output_aliases={9: 1, 10: 2},
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(table, slots, posv, scales, slots.reshape(t, 1), posv.reshape(t, 1),
      qg, kc, vc, k_pool, v_pool)
    out = out.transpose(1, 0, 2, 3).reshape(t, hq, d)
    return out, k_out, v_out
