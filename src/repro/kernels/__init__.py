"""Pallas TPU kernels for the paper's quantized hot spots + jnp oracles.

Each kernel lives in its own module with a matching ``*_ref`` oracle in
``ref.py``; ``ops.py`` is the public dispatch surface (Pallas on TPU, oracle
elsewhere, ``FORCE``/``REPRO_KERNELS_FORCE=interpret`` to override).
"""

# The kernels target the modern Pallas surface (pltpu.CompilerParams); on
# 0.4.x wheels that class is still spelled TPUCompilerParams — alias it once
# here so every kernel module (and downstream caller) sees the same API.
try:  # pragma: no cover - depends on installed jax
    import jax.experimental.pallas.tpu as _pltpu

    if not hasattr(_pltpu, "CompilerParams"):
        _pltpu.CompilerParams = _pltpu.TPUCompilerParams
except ImportError:  # pallas not available on this backend
    pass
