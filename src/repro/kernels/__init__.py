# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

# The kernels target the modern Pallas surface (pltpu.CompilerParams); on
# 0.4.x wheels that class is still spelled TPUCompilerParams — alias it once
# here so every kernel module (and downstream caller) sees the same API.
try:  # pragma: no cover - depends on installed jax
    import jax.experimental.pallas.tpu as _pltpu

    if not hasattr(_pltpu, "CompilerParams"):
        _pltpu.CompilerParams = _pltpu.TPUCompilerParams
except ImportError:  # pallas not available on this backend
    pass
