"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` is the straightforward XLA expression of the same math; kernel
tests sweep shapes/dtypes and ``assert_allclose`` kernel-vs-oracle (exact for
the integer ops, tight rtol for the float ones).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qformat


def qmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """int (M,K) @ (K,N) with int32 accumulation."""
    return jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))


def qmm_requant_ref(x, w, shift, *, width: int = 8):
    acc = qmm_ref(x, w)
    shift = jnp.asarray(shift, jnp.int32)
    shifted = jnp.where(
        shift >= 0,
        jnp.right_shift(acc, jnp.maximum(shift, 0)),
        jnp.left_shift(acc, jnp.maximum(-shift, 0)),
    )
    return jnp.clip(shifted, qformat.qmin(width), qformat.qmax(width)).astype(
        qformat.storage_dtype(width)
    )


def wq_matmul_ref(x, wq, scale, out_dtype=jnp.float32):
    w = wq.astype(jnp.float32) * jnp.broadcast_to(
        jnp.asarray(scale, jnp.float32), (wq.shape[1],)
    )
    return jnp.matmul(x.astype(jnp.float32), w).astype(out_dtype)


def fake_quant_ref(x, n, *, width: int = 8):
    return qformat.quantize_dequantize(x, jnp.asarray(n, jnp.int32), width).astype(x.dtype)


def qconv1d_ref(x, w, *, stride: int = 1, padding: str = "SAME"):
    """x (B,W,C) int, w (K,C,F) int -> (B,W',F) int32 via lax.conv."""
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NWC", "WIO", "NWC"))
    return jax.lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32), (stride,), padding,
        dimension_numbers=dn, preferred_element_type=jnp.int32,
    )


def qchunk_attn_ref(q, k_chunk, v_chunk, k_cache, v_cache, k_n, v_n,
                    slot, start):
    """Chunked-prefill attention oracle: quantize the chunk's K/V onto the
    paper grid, write rows [start, start+C) of ``slot`` in the (B,S,Hkv,D)
    int8 caches, then attend each chunk query c over positions <= start+c
    (the slot's prefix plus the causally visible part of the chunk itself).

    Returns (out (C, Hq, D), k_cache', v_cache') like the Pallas kernel.
    """
    c, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    k_n = jnp.asarray(k_n, jnp.int32)
    v_n = jnp.asarray(v_n, jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    k8 = qformat.quantize(k_chunk, k_n, 8)
    v8 = qformat.quantize(v_chunk, v_n, 8)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k8[None], (slot, start, jnp.int32(0), jnp.int32(0)))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v8[None], (slot, start, jnp.int32(0), jnp.int32(0)))
    kf = jax.lax.dynamic_index_in_dim(k_cache, slot, axis=0, keepdims=False)
    vf = jax.lax.dynamic_index_in_dim(v_cache, slot, axis=0, keepdims=False)
    kf = kf.astype(jnp.float32) * jnp.exp2(-k_n.astype(jnp.float32))
    vf = vf.astype(jnp.float32) * jnp.exp2(-v_n.astype(jnp.float32))
    qg = q.reshape(c, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("chgd,shd->hgcs", qg, kf) / (d ** 0.5)
    pos = jnp.arange(s)[None, None, None, :]
    visible = pos <= (start + jnp.arange(c))[None, None, :, None]
    p = jax.nn.softmax(jnp.where(visible, scores, -1e30), axis=-1)
    out = jnp.einsum("hgcs,shd->chgd", p, vf)
    return out.reshape(c, hq, d).astype(q.dtype), k_cache, v_cache


def qdecode_attn_ref(q, k_cache, v_cache, k_n, v_n, kv_len):
    """Dequantize-everything flash-free reference decode attention.

    ``kv_len``: scalar or (B,) per-slot live lengths (scheduler cache).
    """
    b, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    k = k_cache.astype(jnp.float32) * jnp.exp2(-jnp.asarray(k_n, jnp.float32))
    v = v_cache.astype(jnp.float32) * jnp.exp2(-jnp.asarray(v_n, jnp.float32))
    qg = q.reshape(b, hkv, g, d)
    # scores: (B, Hkv, G, S)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k) / (d ** 0.5)
    pos = jnp.arange(s)
    if jnp.ndim(kv_len) == 1:
        kv_len = kv_len[:, None, None, None]
    scores = jnp.where(pos[None, None, None, :] < kv_len, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return out.reshape(b, hq, d).astype(q.dtype)
