"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` is the straightforward XLA expression of the same math; kernel
tests sweep shapes/dtypes and ``assert_allclose`` kernel-vs-oracle (exact for
the integer ops, tight rtol for the float ones).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qformat


def qmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """int (M,K) @ (K,N) with int32 accumulation."""
    return jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))


def qmm_requant_ref(x, w, shift, *, width: int = 8):
    """Integer matmul + shift-only requant, saturated to width-bit storage."""
    acc = qmm_ref(x, w)
    shift = jnp.asarray(shift, jnp.int32)
    shifted = jnp.where(
        shift >= 0,
        jnp.right_shift(acc, jnp.maximum(shift, 0)),
        jnp.left_shift(acc, jnp.maximum(-shift, 0)),
    )
    return jnp.clip(shifted, qformat.qmin(width), qformat.qmax(width)).astype(
        qformat.storage_dtype(width)
    )


def wq_matmul_ref(x, wq, scale, out_dtype=jnp.float32):
    """Float x @ dequantized int8 weights (weight-only int8 GEMM oracle)."""
    w = wq.astype(jnp.float32) * jnp.broadcast_to(
        jnp.asarray(scale, jnp.float32), (wq.shape[1],)
    )
    return jnp.matmul(x.astype(jnp.float32), w).astype(out_dtype)


def wq4_matmul_ref(x, wq, scale, *, k, width: int = 4, block_size: int = 0,
                   out_dtype=jnp.float32):
    """Packed sub-int8 weight-only GEMM oracle.

    ``wq`` is the int8 container from :func:`repro.core.qformat.pack_subint8`
    (``width``-bit lanes along K); ``scale`` is ``2^-n`` — per-channel
    (``block_size=0``, broadcastable to ``(1, N)``) or per-block
    (``(ceil(K/block_size), N)``, each row covering ``block_size`` K rows).
    """
    n_out = wq.shape[-1]
    w = qformat.unpack_subint8(wq, width, k, axis=-2).astype(jnp.float32)
    scale = jnp.asarray(scale, jnp.float32)
    if block_size:
        s = jnp.repeat(scale.reshape(-1, n_out), block_size, axis=0)[:k]
    else:
        s = jnp.broadcast_to(jnp.atleast_2d(scale), (1, n_out))
    return jnp.matmul(x.astype(jnp.float32), w * s).astype(out_dtype)


def fake_quant_ref(x, n, *, width: int = 8):
    """Quantize-dequantize on the pow2 grid 2^-n (QAT fake-quant oracle)."""
    return qformat.quantize_dequantize(x, jnp.asarray(n, jnp.int32), width).astype(x.dtype)


def qconv1d_ref(x, w, *, stride: int = 1, padding: str = "SAME"):
    """x (B,W,C) int, w (K,C,F) int -> (B,W',F) int32 via lax.conv."""
    dn = jax.lax.conv_dimension_numbers(x.shape, w.shape, ("NWC", "WIO", "NWC"))
    return jax.lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32), (stride,), padding,
        dimension_numbers=dn, preferred_element_type=jnp.int32,
    )


def qchunk_attn_ref(q, k_chunk, v_chunk, k_cache, v_cache, k_n, v_n,
                    slot, start):
    """Chunked-prefill attention oracle: quantize the chunk's K/V onto the
    paper grid, write rows [start, start+C) of ``slot`` in the (B,S,Hkv,D)
    int8 caches, then attend each chunk query c over positions <= start+c
    (the slot's prefix plus the causally visible part of the chunk itself).

    Returns (out (C, Hq, D), k_cache', v_cache') like the Pallas kernel.
    """
    c, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    k_n = jnp.asarray(k_n, jnp.int32)
    v_n = jnp.asarray(v_n, jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    k8 = qformat.quantize(k_chunk, k_n, 8)
    v8 = qformat.quantize(v_chunk, v_n, 8)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k8[None], (slot, start, jnp.int32(0), jnp.int32(0)))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v8[None], (slot, start, jnp.int32(0), jnp.int32(0)))
    kf = jax.lax.dynamic_index_in_dim(k_cache, slot, axis=0, keepdims=False)
    vf = jax.lax.dynamic_index_in_dim(v_cache, slot, axis=0, keepdims=False)
    kf = kf.astype(jnp.float32) * jnp.exp2(-k_n.astype(jnp.float32))
    vf = vf.astype(jnp.float32) * jnp.exp2(-v_n.astype(jnp.float32))
    qg = q.reshape(c, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("chgd,shd->hgcs", qg, kf) / (d ** 0.5)
    pos = jnp.arange(s)[None, None, None, :]
    visible = pos <= (start + jnp.arange(c))[None, None, :, None]
    p = jax.nn.softmax(jnp.where(visible, scores, -1e30), axis=-1)
    out = jnp.einsum("hgcs,shd->chgd", p, vf)
    return out.reshape(c, hq, d).astype(q.dtype), k_cache, v_cache


def gather_pages_ref(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Densify a paged pool: (P, ps, H, D) + (B, max_pages) -> (B, S', H, D).

    ``S' = max_pages * page_size``; unmapped (-1) table entries clamp to pool
    page 0, whose junk rows every consumer masks via the live length.
    """
    n_pages, ps, h, d = pool.shape
    pages = jnp.take(pool, jnp.maximum(page_table, 0), axis=0)
    return pages.reshape(page_table.shape[0], page_table.shape[1] * ps, h, d)


def qpaged_decode_attn_ref(q, k_pool, v_pool, k_n, v_n, page_table, kv_len):
    """Paged decode-attention oracle: gather each slot's pages into a dense
    (B, S', Hkv, D) view through the page table, then run the dense
    dequantize-everything reference.  Same signature contract as
    ``qpaged_attn.qpaged_decode_attn_pallas``.
    """
    k = gather_pages_ref(k_pool, page_table)
    v = gather_pages_ref(v_pool, page_table)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1),
                            (q.shape[0],))
    return qdecode_attn_ref(q, k, v, k_n, v_n, lens)


def qpaged_chunk_attn_ref(q, k_chunk, v_chunk, k_pool, v_pool, k_n, v_n,
                          page_row, start):
    """Paged chunked-prefill oracle: quantize the chunk onto the paper grid,
    scatter its rows into the pool pages named by the slot's ``page_row``,
    then attend each chunk query c over logical positions <= start + c.

    Returns (out (C, Hq, D), k_pool', v_pool') like the Pallas kernel.
    """
    c, hq, d = q.shape
    n_pages, ps, hkv, _ = k_pool.shape
    g = hq // hkv
    k_n = jnp.asarray(k_n, jnp.int32)
    v_n = jnp.asarray(v_n, jnp.int32)
    row = jnp.asarray(page_row, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    k8 = qformat.quantize(k_chunk, k_n, 8)
    v8 = qformat.quantize(v_chunk, v_n, 8)
    # flat scatter: logical row start+i -> pool row page*ps + (start+i) % ps;
    # unmapped (-1) or out-of-table positions redirect to an out-of-bounds
    # sentinel (dropped) — same contract as nn.attention.paged_flat_index.
    pos = start + jnp.arange(c)
    page = jnp.take(row, jnp.minimum(pos // ps, row.shape[0] - 1), axis=0)
    valid = (pos // ps < row.shape[0]) & (page >= 0)
    flat = jnp.where(valid, page * ps + pos % ps, n_pages * ps)
    k_pool = k_pool.reshape(n_pages * ps, hkv, d).at[flat].set(
        k8, mode="drop").reshape(k_pool.shape)
    v_pool = v_pool.reshape(n_pages * ps, hkv, d).at[flat].set(
        v8, mode="drop").reshape(v_pool.shape)
    kf = gather_pages_ref(k_pool, row[None])[0]          # (S', Hkv, D)
    vf = gather_pages_ref(v_pool, row[None])[0]
    kf = kf.astype(jnp.float32) * jnp.exp2(-k_n.astype(jnp.float32))
    vf = vf.astype(jnp.float32) * jnp.exp2(-v_n.astype(jnp.float32))
    s = kf.shape[0]
    qg = q.reshape(c, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("chgd,shd->hgcs", qg, kf) / (d ** 0.5)
    vis = jnp.arange(s)[None, None, None, :] \
        <= (start + jnp.arange(c))[None, None, :, None]
    p = jax.nn.softmax(jnp.where(vis, scores, -1e30), axis=-1)
    out = jnp.einsum("hgcs,shd->chgd", p, vf)
    return out.reshape(c, hq, d).astype(q.dtype), k_pool, v_pool


def qragged_attn_ref(q, k_new, v_new, k_pool, v_pool, k_n, v_n, table,
                     slot_ids, positions):
    """Ragged token-batch oracle: per-token scatter + per-token attention.

    Token ``t`` is logical row ``positions[t]`` of slot ``slot_ids[t]``: its
    K/V row is quantized onto the paper grid and scattered through the page
    table (``positions[t] < 0`` or unmapped pages redirect to the
    out-of-bounds sentinel and drop, like ``paged_flat_index``), then its
    query attends over that slot's positions ``<= positions[t]``.

    Returns (out (T, Hq, D), k_pool', v_pool') like the Pallas kernel.
    """
    t, hq, d = q.shape
    n_pages, ps, hkv, _ = k_pool.shape
    g = hq // hkv
    k_n = jnp.asarray(k_n, jnp.int32)
    v_n = jnp.asarray(v_n, jnp.int32)
    table = jnp.asarray(table, jnp.int32)
    slots = jnp.asarray(slot_ids, jnp.int32).reshape(-1)
    pos = jnp.asarray(positions, jnp.int32).reshape(-1)
    max_pages = table.shape[1]

    k8 = qformat.quantize(k_new, k_n, 8)
    v8 = qformat.quantize(v_new, v_n, 8)
    lpage = jnp.clip(pos, 0) // ps
    page = table[slots, jnp.minimum(lpage, max_pages - 1)]
    valid = (pos >= 0) & (lpage < max_pages) & (page >= 0)
    flat = jnp.where(valid, page * ps + jnp.clip(pos, 0) % ps, n_pages * ps)
    k_pool = k_pool.reshape(n_pages * ps, hkv, d).at[flat].set(
        k8, mode="drop").reshape(k_pool.shape)
    v_pool = v_pool.reshape(n_pages * ps, hkv, d).at[flat].set(
        v8, mode="drop").reshape(v_pool.shape)

    # densify each token's slot through the table, then mask to <= positions
    kf = gather_pages_ref(k_pool, table[slots])          # (T, S', Hkv, D)
    vf = gather_pages_ref(v_pool, table[slots])
    kf = kf.astype(jnp.float32) * jnp.exp2(-k_n.astype(jnp.float32))
    vf = vf.astype(jnp.float32) * jnp.exp2(-v_n.astype(jnp.float32))
    s = kf.shape[1]
    qg = q.reshape(t, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("thgd,tshd->thgs", qg, kf) / (d ** 0.5)
    rows = jnp.arange(s)[None, :]
    mapped = jnp.repeat(table[slots] >= 0, ps, axis=1)   # (T, S')
    vis = (rows <= pos[:, None]) & mapped
    p = jax.nn.softmax(jnp.where(vis[:, None, None, :], scores, -1e30),
                       axis=-1)
    # inert rows (positions < 0) see nothing: zero them instead of the
    # uniform junk a fully-masked softmax yields
    p = jnp.where(jnp.any(vis, axis=-1)[:, None, None, None], p, 0.0)
    out = jnp.einsum("thgs,tshd->thgd", p, vf)
    return out.reshape(t, hq, d).astype(q.dtype), k_pool, v_pool


def qdecode_attn_ref(q, k_cache, v_cache, k_n, v_n, kv_len):
    """Dequantize-everything flash-free reference decode attention.

    ``kv_len``: scalar or (B,) per-slot live lengths (scheduler cache).
    """
    b, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    k = k_cache.astype(jnp.float32) * jnp.exp2(-jnp.asarray(k_n, jnp.float32))
    v = v_cache.astype(jnp.float32) * jnp.exp2(-jnp.asarray(v_n, jnp.float32))
    qg = q.reshape(b, hkv, g, d)
    # scores: (B, Hkv, G, S)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k) / (d ** 0.5)
    pos = jnp.arange(s)
    if jnp.ndim(kv_len) == 1:
        kv_len = kv_len[:, None, None, None]
    scores = jnp.where(pos[None, None, None, :] < kv_len, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return out.reshape(b, hq, d).astype(q.dtype)
