"""Pallas TPU kernel: fused fake-quantization (quantize->dequantize) tile op.

QAT's inner elementwise op (paper Fig. 2): constrain a tensor to the Qm.n
grid.  Fusing trunc/clip/rescale into one VMEM pass avoids three HBM
round-trips that a naive jnp composition could incur when XLA fails to fuse
across the custom_vjp boundary.  The exponent ``n`` is a scalar in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import qformat


def _fq_kernel(n_ref, x_ref, o_ref, *, width: int):
    n = n_ref[0].astype(jnp.float32)
    scale = jnp.exp2(n)
    inv = jnp.exp2(-n)
    xf = x_ref[...].astype(jnp.float32) * scale
    xq = jnp.clip(jnp.trunc(xf), qformat.qmin(width), qformat.qmax(width))
    o_ref[...] = (xq * inv).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("width", "block_rows", "interpret"))
def fake_quant_pallas(
    x: jax.Array,
    n: jax.Array,
    *,
    width: int = 8,
    block_rows: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fake-quantize x (any shape) on the 2^-n grid at `width` bits."""
    orig_shape = x.shape
    flat = x.reshape(-1)
    lanes = 128
    rem = (-flat.size) % lanes
    if rem:
        flat = jnp.pad(flat, (0, rem))
    x2 = flat.reshape(-1, lanes)
    rows = x2.shape[0]
    br = min(block_rows, rows)
    remr = (-rows) % br
    if remr:
        x2 = jnp.pad(x2, ((0, remr), (0, 0)))
    grid = (x2.shape[0] // br,)
    n_arr = jnp.asarray(n, jnp.int32).reshape((1,))
    out = pl.pallas_call(
        functools.partial(_fq_kernel, width=width),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((br, lanes), lambda i: (i, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((br, lanes), lambda i: (i, 0), memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        compiler_params=pltpu.CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(n_arr, x2)
    return out.reshape(-1)[: x.size].reshape(orig_shape)
