"""Pallas TPU kernel: GQA decode attention over an int8-quantized KV cache.

The paper's memory argument (footprint / 2 or / 4) applied to the serving
bottleneck: at decode, attention is a pure HBM-bandwidth problem — every step
streams the whole KV cache.  Quantizing K/V to int8 with per-(head) pow2
exponents halves the bytes vs bf16 (4x vs f32); dequantization happens in
VMEM right before the flash-style online-softmax update.

Layout: q (B, Hq, D) f32; k/v caches (B, S, Hkv, D) int8; Hq = G * Hkv.
Grid: (B, Hkv, S/BS) with running (m, l, acc) scratch — the classic
flash-decoding split, S innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _qdecode_kernel(
    scales_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, s_steps: int, bs: int, sm_scale: float,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_scale = scales_ref[0]
    v_scale = scales_ref[1]
    kv_len = len_ref[pl.program_id(0)]     # per-slot live length

    q = q_ref[0, 0]                   # (G, D) f32
    k = k_ref[0, :, 0, :].astype(jnp.float32) * k_scale   # (BS, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32) * v_scale   # (BS, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale  # (G, BS)
    # Mask positions past the live cache length.
    pos = pl.program_id(2) * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < kv_len, s, NEG_INF)

    m_prev = m_ref[...]               # (G, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)            # (G, BS)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(pl.program_id(2) == s_steps - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def qdecode_attn_pallas(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    k_n: jax.Array,
    v_n: jax.Array,
    kv_len: jax.Array,
    *,
    bs: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q (B,Hq,D) f32, caches (B,S,Hkv,D) int8, exponents scalar -> (B,Hq,D).

    ``kv_len``: scalar (one shared length) or (B,) per-slot lengths — the
    continuous-batching scheduler's case, each slot masking its own prefix.
    """
    b, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    bs_ = min(bs, s)
    assert s % bs_ == 0, (s, bs_)
    s_steps = s // bs_
    sm_scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, g, d)
    scales = jnp.stack(
        [jnp.exp2(-k_n.astype(jnp.float32)), jnp.exp2(-v_n.astype(jnp.float32))]
    )
    len_arr = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    out = pl.pallas_call(
        functools.partial(_qdecode_kernel, s_steps=s_steps, bs=bs_, sm_scale=sm_scale),
        grid=(b, hkv, s_steps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, isz: (ib, ih, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, bs_, 1, d), lambda ib, ih, isz: (ib, isz, ih, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, bs_, 1, d), lambda ib, ih, isz: (ib, isz, ih, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda ib, ih, isz: (ib, ih, 0, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(scales, len_arr, qg, k_cache, v_cache)
    return out.reshape(b, hq, d)
