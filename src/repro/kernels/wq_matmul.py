"""Pallas TPU kernel: weight-only int8 matmul with in-kernel dequantization.

Serving path for the large assigned archs (DESIGN.md §2): weights live in HBM
as int8 with power-of-two exponents (paper's Qm.n storage — 4x less HBM
traffic than f32, 2x less than bf16), activations stay bf16/f32.  Each weight
block is dequantized *in VMEM* right before the MXU dot, so HBM sees only
int8 bytes.  For memory-bound decode GEMVs this moves the memory-roofline
term down by ~2x vs bf16 weights.

Scales: scalar (per-tensor) or per-output-channel vector (beyond-paper
per-filter mode) — passed as a precomputed f32 ``2^-n`` vector blocked along N.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wq_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Dequantize the int8 weight block in VMEM, then hit the MXU in f32.
    w = w_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        # Scale epilogue: per-channel 2^-n applied once at the end (exact —
        # pow2 scale commutes with the f32 accumulation).
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def _pad_to(x, multiple, axis):
    rem = (-x.shape[axis]) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret", "out_dtype"))
def wq_matmul_pallas(
    x: jax.Array,
    wq: jax.Array,
    scale: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """(M,K) f32/bf16 @ dequant((K,N) int8, scale) -> (M,N).

    ``scale`` is ``2^-n`` with shape () or (N,).
    """
    m, k = x.shape
    _, n = wq.shape
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (n,)).reshape(1, n)
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    xp = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    wp = _pad_to(_pad_to(wq, bk_, 0), bn_, 1)
    sp = _pad_to(scale, bn_, 1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    k_steps = kp // bk_
    grid = (mp // bm_, np_ // bn_, k_steps)
    out = pl.pallas_call(
        functools.partial(_wq_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk), memory_space=pltpu.VMEM),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j), memory_space=pltpu.VMEM),
            pl.BlockSpec((1, bn_), lambda i, j, kk: (0, j), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j), memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xp, wp, sp)
    return out[:m, :n]


# --------------------------------------------------------------------------
# Packed int4: two weight lanes per int8 byte, unpack-in-kernel
# --------------------------------------------------------------------------

def _wq4_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, k_steps: int,
                rows_per_scale: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Unpack the packed block in VMEM: byte row j holds logical weight rows
    # 2j (low nibble) and 2j+1 (high nibble), two's complement.  int32 shift
    # arithmetic sign-extends both nibbles exactly.
    w8 = w_ref[...].astype(jnp.int32)                    # (bk/2, bn)
    lo = jnp.right_shift(jnp.left_shift(w8, 28), 28)
    hi = jnp.right_shift(w8, 4)
    bkp, bn = w8.shape
    w = jnp.stack([lo, hi], axis=1).reshape(2 * bkp, bn)  # (bk, bn)
    # Scale rows cover rows_per_scale logical K rows each (block_size for
    # per-block grids, the whole bk for per-channel) — applied BEFORE the
    # dot, because a K-varying scale cannot ride the N epilogue.
    s = s_ref[...]                                        # (bk/rps, bn)
    wf = w.astype(jnp.float32) * jnp.repeat(s, rows_per_scale, axis=0)
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), wf, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k", "block_size", "bm", "bk",
                                             "bn", "interpret", "out_dtype"))
def wq4_matmul_pallas(
    x: jax.Array,
    wq: jax.Array,
    scale: jax.Array,
    *,
    k: int,
    block_size: int = 0,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """(M,K) f32/bf16 @ dequant((ceil(K/2),N) packed int4) -> (M,N).

    ``wq`` packs two int4 lanes per int8 byte along K (``qformat.
    pack_subint8`` layout: low nibble = even row).  ``scale`` is ``2^-n``:

    * ``block_size=0`` — per-channel, shape ``(1, N)`` (or ``()``/(N,));
    * ``block_size=bs`` — per-block (MX-style), shape ``(ceil(K/bs), N)``.

    The kernel unpacks each weight block in VMEM and applies the scale rows
    before the MXU dot, so HBM traffic is int4 bytes + the scale grid.
    """
    m = x.shape[0]
    n = wq.shape[1]
    kp2 = 2 * wq.shape[0]                     # logical K padded to lane pairs
    if block_size:
        if block_size % 2:
            raise ValueError(f"block_size must be even, got {block_size}")
        nblocks = -(-k // block_size)
        scale = jnp.asarray(scale, jnp.float32).reshape(nblocks, n)
        # pad the scale grid to the packed K extent (pad rows scale only
        # zero-nibble pad weights, so their value is irrelevant)
        scale = _pad_to(scale, -(-kp2 // block_size), 0)
        rps = block_size
        bk_ = max(block_size, min(bk, kp2) // block_size * block_size)
    else:
        scale = jnp.broadcast_to(
            jnp.atleast_2d(jnp.asarray(scale, jnp.float32)), (1, n))
        bk_ = min(bk, kp2)
        bk_ = bk_ - (bk_ % 2)
    bm_, bn_ = min(bm, m), min(bn, n)
    # widen x's K axis to the packed extent (the extra logical rows hold
    # zero nibbles, so the padding value is inert), then to the K tile
    xp = jnp.pad(x, ((0, 0), (0, kp2 - x.shape[1])))
    xp = _pad_to(_pad_to(xp, bm_, 0), bk_, 1)
    wp = _pad_to(_pad_to(wq, bk_ // 2, 0), bn_, 1)
    if block_size:
        sp = _pad_to(_pad_to(scale, bk_ // block_size, 0), bn_, 1)
        s_rows = bk_ // block_size
    else:
        sp = _pad_to(scale, bn_, 1)
        s_rows = 1
        rps = bk_
    mp, kpad = xp.shape
    np_ = wp.shape[1]
    k_steps = kpad // bk_
    grid = (mp // bm_, np_ // bn_, k_steps)
    out = pl.pallas_call(
        functools.partial(_wq4_kernel, k_steps=k_steps, rows_per_scale=rps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((bk_ // 2, bn_), lambda i, j, kk: (kk, j),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((s_rows, bn_),
                         (lambda i, j, kk: (kk, j)) if block_size
                         else (lambda i, j, kk: (0, j)),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xp, wp, sp)
    return out[:m, :n]
