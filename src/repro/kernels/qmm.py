"""Pallas TPU kernel: int8 x int8 -> int32 tiled matmul with an optional
power-of-two requantization epilogue.

This is the TPU adaptation of the paper's inner loop (Sec. 5.8 + Appendix E):
Cortex-M4 `SMLAD` (2x int16 MAC -> int32/cycle) becomes the MXU's native
int8 x int8 -> int32 systolic matmul (2x bf16 throughput on v5e), and the
"shift right + saturate" requantization becomes an exact in-register epilogue
executed on the final K step — no float multiply, no division, exactly the
paper's no-division rule.

Blocking: (BM x BK) @ (BK x BN) with an int32 VMEM accumulator scratch,
K innermost ("arbitrary" semantics) so the accumulator lives across K steps.
MXU-aligned tiles (multiples of 128 on the lane dim; int8 sublane packing is
handled by Mosaic).  Validated against ``ref.qmm_ref`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import qformat


def _qmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def _qmm_requant_kernel(shift_ref, x_ref, w_ref, o_ref, acc_ref, *, k_steps: int, width: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        # Paper Sec. 5.8: shift the 2x-width accumulator back to the output
        # format, then saturate to the operand width (SSAT analogue).
        shift = shift_ref[0]
        acc = acc_ref[...]
        shifted = jnp.where(
            shift >= 0,
            jnp.right_shift(acc, jnp.maximum(shift, 0)),
            jnp.left_shift(acc, jnp.maximum(-shift, 0)),
        )
        sat = jnp.clip(shifted, qformat.qmin(width), qformat.qmax(width))
        o_ref[...] = sat.astype(o_ref.dtype)


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def qmm_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """int8/int16 (M,K) @ (K,N) -> int32 (M,N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    xp = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    wp = _pad_to(_pad_to(w, bk_, 0), bn_, 1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    k_steps = kp // bk_
    grid = (mp // bm_, np_ // bn_, k_steps)
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk), memory_space=pltpu.VMEM),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j), memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


@functools.partial(
    jax.jit, static_argnames=("width", "bm", "bk", "bn", "interpret")
)
def qmm_requant_pallas(
    x: jax.Array,
    w: jax.Array,
    shift: jax.Array,
    *,
    width: int = 8,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Fused (x @ w) >> shift with saturation to `width` bits.

    ``shift`` is the per-layer ``n_acc - n_out`` (int32 scalar), living in
    SMEM so the epilogue needs no extra HBM traffic.
    """
    m, k = x.shape
    _, n = w.shape
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    xp = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    wp = _pad_to(_pad_to(w, bk_, 0), bn_, 1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    k_steps = kp // bk_
    grid = (mp // bm_, np_ // bn_, k_steps)
    out_dtype = qformat.storage_dtype(width)
    shift_arr = jnp.asarray(shift, jnp.int32).reshape((1,))
    out = pl.pallas_call(
        functools.partial(_qmm_requant_kernel, k_steps=k_steps, width=width),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk), memory_space=pltpu.VMEM),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j), memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(shift_arr, xp, wp)
    return out[:m, :n]
