"""Pallas TPU kernel: integer 1D convolution (the paper's primary layer).

The paper's engine computes Conv1D as f x s x c x k MACs into an int32
accumulator (Appendix E).  On TPU we restructure the same computation as K
shifted (W' x C) @ (C x F) MXU matmuls accumulated in a VMEM scratch — the
im2col is *implicit* (K shifted views of the same VMEM-resident row), so the
input is read from HBM once, not K times.

Blocking: one batch row per grid step (MCU-scale widths: W <= a few hundred,
C,F <= 128 — a full padded row fits VMEM comfortably), F blocked on the lane
dim.  Grid: (B, F/BF).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _qconv1d_kernel(x_ref, w_ref, o_ref, *, ksize: int, wout: int, stride: int):
    # x_ref: (1, Wpad, C) int8 ; w_ref: (K, C, BF) int8 ; o_ref: (1, Wout, BF) int32
    acc = jnp.zeros(o_ref.shape[1:], jnp.int32)
    for k in range(ksize):  # K is small & static: unrolled shifted matmuls
        if stride == 1:
            xs = x_ref[0, k : k + wout, :]
        else:
            xs = x_ref[0, k : k + (wout - 1) * stride + 1 : stride, :]
        acc += jnp.dot(xs, w_ref[k], preferred_element_type=jnp.int32)
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("stride", "padding", "bf", "interpret"))
def qconv1d_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x (B, W, C) int8, w (K, C, F) int8 -> (B, W', F) int32."""
    b, width, c = x.shape
    ksize, _, f = w.shape
    if padding == "SAME":
        wout = -(-width // stride)
        pad_total = max(0, (wout - 1) * stride + ksize - width)
        lo = pad_total // 2
        x = jnp.pad(x, ((0, 0), (lo, pad_total - lo), (0, 0)))
    elif padding == "VALID":
        wout = (width - ksize) // stride + 1
    else:
        raise ValueError(padding)
    bf_ = min(bf, f)
    remf = (-f) % bf_
    if remf:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, remf)))
    fpad = w.shape[-1]
    wpad = x.shape[1]
    grid = (b, fpad // bf_)
    out = pl.pallas_call(
        functools.partial(_qconv1d_kernel, ksize=ksize, wout=wout, stride=stride),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, wpad, c), lambda i, j: (i, 0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((ksize, c, bf_), lambda i, j: (0, 0, j), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, wout, bf_), lambda i, j: (i, 0, j), memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, wout, fpad), jnp.int32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(x, w)
    return out[:, :, :f]
