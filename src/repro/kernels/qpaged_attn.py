"""Pallas TPU kernels: attention over a *paged* int8 KV cache.

The serving cache stops being a dense ``(slots, max_len, Hkv, D)`` slab and
becomes a single shared pool ``(num_pages, page_size, Hkv, D)`` plus a
per-slot page table of pool indices (nn/attention.py
``init_paged_kv_cache``).  A slot's logical row ``p`` lives in pool page
``table[slot, p // page_size]`` at row ``p % page_size``; unallocated table
entries are ``-1``.  Both kernels here gather K/V blocks *through* the page
table, which arrives as scalar-prefetch metadata so the BlockSpec index maps
can turn a grid step into a pool-page DMA before the kernel body runs:

* :func:`qpaged_decode_attn_pallas` — the paged generalization of
  ``qdecode_attn``: one query per slot, flash over the slot's pages, per-slot
  live-length masking.  Grid ``(B, Hkv, max_pages)``; page blocks past the
  slot's last live page clamp onto the last one (the revisit skips the DMA)
  and their accumulation is guarded, so per-slot work is proportional to the
  slot's *live* length, not ``max_pages``.
* :func:`qpaged_chunk_attn_pallas` — the paged generalization of
  ``qchunk_attn``: a C-token prompt chunk attends flash-style over its
  slot's pages with causal-in-chunk masking, and the chunk's K/V rows are
  quantized onto the paper's Qm.n grid and written in place into the slot's
  pages inside the same kernel (``input_output_aliases`` on the pools).

Page-size note: blocks are one page, so on real TPU hardware ``page_size``
should be a multiple of the sublane tile (>= 128 ideally) to keep the DMA
engine busy; tests run both kernels in interpret mode where any size works.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
I8_MIN, I8_MAX = -128, 127


def _quantize_i8(x: jax.Array, inv_scale: jax.Array) -> jax.Array:
    """sat(trunc(x * 2^n)) on the paper grid; inv_scale = 2^n (exact pow2)."""
    xf = x * inv_scale
    xq = jnp.where(xf >= 0, jnp.floor(xf), jnp.ceil(xf))  # trunc toward zero
    return jnp.clip(xq, I8_MIN, I8_MAX).astype(jnp.int8)


def _last_live_page(kv_len, ps: int):
    """Index of the last page holding a live row (0 when the slot is empty)."""
    return jnp.maximum(jax.lax.div(kv_len - 1, ps), 0)


# --------------------------------------------------------------------------
# Paged decode
# --------------------------------------------------------------------------

def _qpaged_decode_kernel(
    table_ref, len_ref, scales_ref, q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref, *, ps: int, n_pages: int, sm_scale: float,
):
    ib, ip = pl.program_id(0), pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[ib]
    last = _last_live_page(kv_len, ps)

    # Page blocks past the slot's last live page clamp onto it in the index
    # maps (no new DMA) and skip the flash update entirely.
    @pl.when(ip <= last)
    def _flash():
        k_scale = scales_ref[0]
        v_scale = scales_ref[1]
        q = q_ref[0, 0]                                       # (G, D) f32
        k = k_ref[0, :, 0, :].astype(jnp.float32) * k_scale   # (ps, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32) * v_scale

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        pos = ip * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)

        m_prev = m_ref[...]                                   # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qpaged_decode_attn_pallas(
    q: jax.Array,           # (B, Hq, D) f32
    k_pool: jax.Array,      # (P, ps, Hkv, D) int8
    v_pool: jax.Array,
    k_n: jax.Array,         # scalar int32 dequant exponents (paper Qm.n grid)
    v_n: jax.Array,
    page_table: jax.Array,  # (B, max_pages) int32 pool indices, -1 = unmapped
    kv_len: jax.Array,      # (B,) per-slot live lengths
    *,
    interpret: bool = False,
) -> jax.Array:
    """GQA decode attention gathering the int8 KV cache through a page table.

    Args:
      q: ``(B, Hq, D)`` f32 queries, one token per slot (``Hq = G * Hkv``).
      k_pool / v_pool: ``(num_pages, page_size, Hkv, D)`` int8 shared pools.
      k_n / v_n: scalar int32 pow2 dequant exponents.
      page_table: ``(B, max_pages)`` int32; entry ``j`` of slot ``b`` names
        the pool page holding logical rows ``[j*ps, (j+1)*ps)``; ``-1`` =
        unmapped (only reachable past ``kv_len``, so it is never read live).
      kv_len: ``(B,)`` int32 live lengths (per-slot masking, like the dense
        kernel's vector form).

    Returns:
      ``(B, Hq, D)`` attention output in ``q.dtype``.
    """
    b, hq, d = q.shape
    n_pool, ps, hkv, _ = k_pool.shape
    g = hq // hkv
    max_pages = page_table.shape[1]
    sm_scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, g, d)
    table = jnp.asarray(page_table, jnp.int32)
    len_arr = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    scales = jnp.stack([jnp.exp2(-k_n.astype(jnp.float32)),
                        jnp.exp2(-v_n.astype(jnp.float32))])

    def _pool_idx(ib, ih, ip, table, lens):
        # clamp past-the-last-live-page steps onto the last live page (the
        # revisit skips the DMA; the kernel guards its accumulation), then
        # translate the logical page slot to a pool page via the table.
        last = _last_live_page(lens[ib], ps)
        page = table[ib, jnp.minimum(ip, last)]
        return (jnp.maximum(page, 0), 0, ih, 0)

    pool_spec = pl.BlockSpec((1, ps, 1, d), _pool_idx)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scales
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, ip, *_: (ib, ih, 0, 0)),
            pool_spec,
            pool_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ib, ih, ip, *_: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_qpaged_decode_kernel, ps=ps, n_pages=max_pages,
                          sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table, len_arr, scales, qg, k_pool, v_pool)
    return out.reshape(b, hq, d)


# --------------------------------------------------------------------------
# Paged chunked prefill
# --------------------------------------------------------------------------

def _qpaged_chunk_kernel(
    row_ref, start_ref, scales_ref, q_ref, kc_ref, vc_ref, k_ref, v_ref,
    o_ref, ko_ref, vo_ref, m_ref, l_ref, acc_ref,
    *, c: int, g: int, ps: int, n_pages: int, sm_scale: float,
):
    ip = pl.program_id(1)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = start_ref[0]
    k_scale = scales_ref[0]
    v_scale = scales_ref[1]

    # Early termination exactly like the dense qchunk kernel: page blocks
    # entirely past the last visible row (start + c - 1) clamp onto the last
    # needed page (index maps below), revisit the resident block with no new
    # DMA, re-merge idempotently, and skip the flash accumulation.
    last = jnp.minimum((start + c - 1) // ps, n_pages - 1)
    ip_eff = jnp.minimum(ip, last)
    pos = ip_eff * ps + jax.lax.broadcasted_iota(jnp.int32, (ps, 1), 0)[:, 0]
    in_chunk = (pos >= start) & (pos < start + c)

    # -- fused quantize-on-write: merge the chunk's rows into this page
    # (one-hot matmul gathers row pos-start; exact 0/1 selection).
    oh = (pos[:, None] == start + jax.lax.broadcasted_iota(
        jnp.int32, (ps, c), 1)).astype(jnp.float32)
    k_rows = jnp.dot(oh, kc_ref[0], preferred_element_type=jnp.float32)
    v_rows = jnp.dot(oh, vc_ref[0], preferred_element_type=jnp.float32)
    k8 = jnp.where(in_chunk[:, None],
                   _quantize_i8(k_rows, 1.0 / k_scale), k_ref[0, :, 0, :])
    v8 = jnp.where(in_chunk[:, None],
                   _quantize_i8(v_rows, 1.0 / v_scale), v_ref[0, :, 0, :])
    ko_ref[0, :, 0, :] = k8
    vo_ref[0, :, 0, :] = v8

    # -- flash update over the merged page (prefix + just-written chunk):
    # query c_i sees positions <= start + c_i (causal within the chunk).
    @pl.when(ip <= last)
    def _flash():
        kf = k8.astype(jnp.float32) * k_scale
        vf = v8.astype(jnp.float32) * v_scale
        q = q_ref[0]                                   # (C*G, D)
        s_blk = jnp.dot(q, kf.T, preferred_element_type=jnp.float32) * sm_scale
        qc = jax.lax.broadcasted_iota(jnp.int32, (c * g, ps), 0) // g
        s_blk = jnp.where(pos[None, :] <= start + qc, s_blk, NEG_INF)

        m_prev = m_ref[...]                            # (C*G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s_blk, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s_blk - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, vf, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def qpaged_chunk_attn_pallas(
    q: jax.Array,          # (C, Hq, D) f32, RoPE'd chunk queries
    k_chunk: jax.Array,    # (C, Hkv, D) f32, RoPE'd chunk keys
    v_chunk: jax.Array,    # (C, Hkv, D) f32
    k_pool: jax.Array,     # (P, ps, Hkv, D) int8
    v_pool: jax.Array,
    k_n: jax.Array,        # scalar int32 dequant exponents
    v_n: jax.Array,
    page_row: jax.Array,   # (max_pages,) int32: the target slot's table row
    start: jax.Array,      # int32: first logical cache row of this chunk
    *,
    interpret: bool = False,
):
    """Chunked-prefill attention + fused quantize-on-write into pool pages.

    The paged generalization of ``qchunk_attn_pallas``: the target slot's
    page-table row arrives as scalar-prefetch metadata, every grid step maps
    one *logical* page of the slot onto its pool page, and logical rows
    ``[start, start+C)`` receive the quantized chunk in place
    (``input_output_aliases`` on the pools).

    Args:
      q / k_chunk / v_chunk: the chunk's f32 queries / keys / values.
      k_pool / v_pool: ``(num_pages, page_size, Hkv, D)`` int8 shared pools.
      k_n / v_n: scalar int32 pow2 dequant exponents.
      page_row: ``(max_pages,)`` int32 pool indices for the target slot; all
        entries covering ``[0, start+C)`` must be allocated (>= 0) — the
        serve allocator guarantees this at admission.
      start: int32 first logical row of the chunk.

    Returns:
      ``(out (C, Hq, D), k_pool', v_pool')`` — pools updated in place; pages
      not owned by the slot pass through untouched via aliasing.
    """
    c, hq, d = q.shape
    n_pool, ps, hkv, _ = k_pool.shape
    g = hq // hkv
    max_pages = page_row.shape[0]
    sm_scale = 1.0 / (d ** 0.5)

    qg = q.reshape(c, hkv, g, d).transpose(1, 0, 2, 3).reshape(hkv, c * g, d)
    kc = k_chunk.transpose(1, 0, 2)                 # (Hkv, C, D)
    vc = v_chunk.transpose(1, 0, 2)
    row = jnp.asarray(page_row, jnp.int32)
    start_arr = jnp.asarray(start, jnp.int32).reshape(1)
    scales = jnp.stack([jnp.exp2(-k_n.astype(jnp.float32)),
                        jnp.exp2(-v_n.astype(jnp.float32))])

    def _pool_idx(ih, ip, row, start):
        last = jnp.minimum((start[0] + c - 1) // ps, max_pages - 1)
        page = row[jnp.minimum(ip, last)]
        return (jnp.maximum(page, 0), 0, ih, 0)

    pool_spec = pl.BlockSpec((1, ps, 1, d), _pool_idx)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(hkv, max_pages),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),    # scales
            pl.BlockSpec((1, c * g, d), lambda ih, ip, *_: (ih, 0, 0)),
            pl.BlockSpec((1, c, d), lambda ih, ip, *_: (ih, 0, 0)),
            pl.BlockSpec((1, c, d), lambda ih, ip, *_: (ih, 0, 0)),
            pool_spec,
            pool_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, c * g, d), lambda ih, ip, *_: (ih, 0, 0)),
            pool_spec,
            pool_spec,
        ],
        scratch_shapes=[
            pltpu.VMEM((c * g, 1), jnp.float32),
            pltpu.VMEM((c * g, 1), jnp.float32),
            pltpu.VMEM((c * g, d), jnp.float32),
        ],
    )
    out, k_new, v_new = pl.pallas_call(
        functools.partial(_qpaged_chunk_kernel, c=c, g=g, ps=ps,
                          n_pages=max_pages, sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((hkv, c * g, d), q.dtype),
            jax.ShapeDtypeStruct(k_pool.shape, jnp.int8),
            jax.ShapeDtypeStruct(v_pool.shape, jnp.int8),
        ],
        # indices count the two scalar-prefetch operands: 6/7 are the pools.
        input_output_aliases={6: 1, 7: 2},
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(row, start_arr, scales, qg, kc, vc, k_pool, v_pool)
    out = out.reshape(hkv, c, g, d).transpose(1, 0, 2, 3).reshape(c, hq, d)
    return out, k_new, v_new
