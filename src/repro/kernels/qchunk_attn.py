"""Pallas TPU kernel: chunked-prefill attention into an int8 per-slot cache.

Generalizes ``qdecode_attn`` from one query to a Q-block: one prompt chunk of
C tokens attends flash-style (online softmax) over its slot's int8 prefix,
with causal masking *within* the chunk — and the chunk's own K/V rows are
quantized to the paper's Qm.n grid and written **in place** into the slot's
cache slice inside the same kernel (``input_output_aliases``), so the fp32
chunk K/V never round-trips through HBM and no batch-1 scratch cache exists.
This is the serve path's admission kernel: every scheduler tick runs all live
decode slots *plus* one such chunk (serve/engine.make_mixed_step).

Layout: q (Hkv, C*G, D) f32 (queries grouped per KV head); chunk k/v
(Hkv, C, D) f32; caches (B, S, Hkv, D) int8.  Grid (Hkv, S/BS) with running
(m, l, acc) scratch; the target slot and the chunk's start row arrive as
scalar-prefetch metadata so the BlockSpecs only ever touch the target slot's
rows — other slots' cache blocks are neither read nor written.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
I8_MIN, I8_MAX = -128, 127


def _quantize_i8(x: jax.Array, inv_scale: jax.Array) -> jax.Array:
    """sat(trunc(x * 2^n)) on the paper grid; inv_scale = 2^n (exact pow2)."""
    xf = x * inv_scale
    xq = jnp.where(xf >= 0, jnp.floor(xf), jnp.ceil(xf))  # trunc toward zero
    return jnp.clip(xq, I8_MIN, I8_MAX).astype(jnp.int8)


def _qchunk_kernel(
    meta_ref, scales_ref, q_ref, kc_ref, vc_ref, k_ref, v_ref,
    o_ref, ko_ref, vo_ref, m_ref, l_ref, acc_ref,
    *, c: int, g: int, bs: int, s_steps: int, sm_scale: float,
):
    isz = pl.program_id(1)

    @pl.when(isz == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = meta_ref[1]
    k_scale = scales_ref[0]
    v_scale = scales_ref[1]

    # Early termination: blocks entirely past the last visible row
    # (start + c - 1) carry no chunk rows and are fully masked.  The cache
    # BlockSpecs clamp their index to ``last_block`` (see the index maps),
    # so those grid steps revisit the already-resident block — no new DMA —
    # and the merge below is idempotent; only the flash accumulation is
    # guarded.  Total work per chunk then matches one-shot causal prefill
    # instead of scanning the whole max_len cache every time.
    last_block = jnp.minimum((start + c - 1) // bs, s_steps - 1)
    isz_eff = jnp.minimum(isz, last_block)
    pos = isz_eff * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)[:, 0]
    in_chunk = (pos >= start) & (pos < start + c)

    # -- fused quantize-on-write: merge the chunk's rows into this cache
    # block (one-hot matmul gathers row pos-start; exact 0/1 selection).
    oh = (pos[:, None] == start + jax.lax.broadcasted_iota(
        jnp.int32, (bs, c), 1)).astype(jnp.float32)
    k_rows = jnp.dot(oh, kc_ref[0], preferred_element_type=jnp.float32)
    v_rows = jnp.dot(oh, vc_ref[0], preferred_element_type=jnp.float32)
    k8 = jnp.where(in_chunk[:, None],
                   _quantize_i8(k_rows, 1.0 / k_scale), k_ref[0, :, 0, :])
    v8 = jnp.where(in_chunk[:, None],
                   _quantize_i8(v_rows, 1.0 / v_scale), v_ref[0, :, 0, :])
    ko_ref[0, :, 0, :] = k8
    vo_ref[0, :, 0, :] = v8

    # -- flash update over the merged block (prefix + just-written chunk):
    # query c_i sees positions <= start + c_i (causal within the chunk).
    @pl.when(isz <= last_block)
    def _flash():
        kf = k8.astype(jnp.float32) * k_scale
        vf = v8.astype(jnp.float32) * v_scale
        q = q_ref[0]                               # (C*G, D)
        s_blk = jnp.dot(q, kf.T, preferred_element_type=jnp.float32) * sm_scale
        qc = jax.lax.broadcasted_iota(jnp.int32, (c * g, bs), 0) // g
        s_blk = jnp.where(pos[None, :] <= start + qc, s_blk, NEG_INF)

        m_prev = m_ref[...]                        # (C*G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s_blk, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s_blk - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, vf, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(isz == s_steps - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def qchunk_attn_pallas(
    q: jax.Array,        # (C, Hq, D) f32, RoPE'd chunk queries
    k_chunk: jax.Array,  # (C, Hkv, D) f32, RoPE'd chunk keys
    v_chunk: jax.Array,  # (C, Hkv, D) f32
    k_cache: jax.Array,  # (B, S, Hkv, D) int8
    v_cache: jax.Array,
    k_n: jax.Array,      # scalar int32 dequant exponents (paper Qm.n grid)
    v_n: jax.Array,
    slot: jax.Array,     # int32: target batch slot
    start: jax.Array,    # int32: first cache row of this chunk
    *,
    bs: int = 512,
    interpret: bool = False,
):
    """Returns (out (C, Hq, D), k_cache', v_cache') — caches updated in place.

    Rows [start, start+C) of ``slot`` receive the quantized chunk; all other
    rows and slots pass through untouched via input/output aliasing.  Junk
    queries past the chunk's valid length produce junk output rows (callers
    gather only the rows they need); their K/V rows land past the slot's live
    length where the scheduler's masking invariant already ignores them.
    """
    c, hq, d = q.shape
    b, s, hkv, _ = k_cache.shape
    g = hq // hkv
    # the S grid needs bs_ | s: take the largest divisor <= bs (cache
    # max_len is operator-chosen, e.g. 560 = prompt 512 + horizon 48 — a
    # fixed 512 would not divide it).  Fail loudly rather than silently
    # degrade to tiny blocks when max_len has no usable divisor (a prime
    # 521 would otherwise run S grid steps over 1-row blocks).
    bs_ = min(bs, s)
    while s % bs_:
        bs_ -= 1
    if bs_ < min(16, s):
        raise ValueError(
            f"cache max_len {s} has no block divisor in [16, {bs}]; pick a "
            f"max_len that is a multiple of a reasonable power of two "
            f"(qchunk_attn grids the cache length into equal blocks)")
    s_steps = s // bs_
    sm_scale = 1.0 / (d ** 0.5)

    qg = q.reshape(c, hkv, g, d).transpose(1, 0, 2, 3).reshape(hkv, c * g, d)
    kc = k_chunk.transpose(1, 0, 2)                 # (Hkv, C, D)
    vc = v_chunk.transpose(1, 0, 2)
    meta = jnp.stack([jnp.asarray(slot, jnp.int32),
                      jnp.asarray(start, jnp.int32)])
    scales = jnp.stack([jnp.exp2(-k_n.astype(jnp.float32)),
                        jnp.exp2(-v_n.astype(jnp.float32))])

    def _cache_idx(ih, isz, m):
        # clamp past-the-last-visible-row steps onto the last needed block:
        # the revisit skips the DMA and the kernel guards its accumulation
        last = jnp.minimum((m[1] + c - 1) // bs_, s_steps - 1)
        return (m[0], jnp.minimum(isz, last), ih, 0)

    cache_spec = pl.BlockSpec((1, bs_, 1, d), _cache_idx)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(hkv, s_steps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # scales
            pl.BlockSpec((1, c * g, d), lambda ih, isz, m: (ih, 0, 0)),
            pl.BlockSpec((1, c, d), lambda ih, isz, m: (ih, 0, 0)),
            pl.BlockSpec((1, c, d), lambda ih, isz, m: (ih, 0, 0)),
            cache_spec,
            cache_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, c * g, d), lambda ih, isz, m: (ih, 0, 0)),
            cache_spec,
            cache_spec,
        ],
        scratch_shapes=[
            pltpu.VMEM((c * g, 1), jnp.float32),
            pltpu.VMEM((c * g, 1), jnp.float32),
            pltpu.VMEM((c * g, d), jnp.float32),
        ],
    )
    out, k_new, v_new = pl.pallas_call(
        functools.partial(_qchunk_kernel, c=c, g=g, bs=bs_, s_steps=s_steps,
                          sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((hkv, c * g, d), q.dtype),
            jax.ShapeDtypeStruct(k_cache.shape, jnp.int8),
            jax.ShapeDtypeStruct(v_cache.shape, jnp.int8),
        ],
        # indices count the scalar-prefetch operand: 5/6 are the caches.
        input_output_aliases={5: 1, 6: 2},
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(meta, scales, qg, kc, vc, k_cache, v_cache)
    out = out.reshape(hkv, c, g, d).transpose(1, 0, 2, 3).reshape(c, hq, d)
    return out, k_new, v_new
