"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: the Pallas path targets TPU (and is validated on CPU in
interpret mode by the kernel tests); everywhere else the pure-jnp oracle from
``ref.py`` runs — it is the same math, so the framework is backend-portable
exactly like the paper's "portable C library" claim for KerasCNN2C.

Set ``repro.kernels.ops.FORCE`` to "pallas" / "ref" / "interpret" to override
(used by tests and benchmarks).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qformat import QTensor

from . import ref
from .fake_quant import fake_quant_pallas
from .qchunk_attn import qchunk_attn_pallas
from .qconv1d import qconv1d_pallas
from .qdecode_attn import qdecode_attn_pallas
from .qmm import qmm_pallas, qmm_requant_pallas
from .wq_matmul import wq_matmul_pallas

FORCE: Optional[str] = None  # None | "pallas" | "ref" | "interpret"


def _mode() -> str:
    if FORCE is not None:
        return FORCE
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _2d(x):
    """Collapse leading dims to rows for GEMM wrappers."""
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def qmm(x: jax.Array, w: jax.Array) -> jax.Array:
    """Integer matmul with int32 accumulation; x (..., K), w (K, N)."""
    x2, lead = _2d(x)
    mode = _mode()
    if mode == "pallas":
        out = qmm_pallas(x2, w)
    elif mode == "interpret":
        out = qmm_pallas(x2, w, interpret=True)
    else:
        out = ref.qmm_ref(x2, w)
    return out.reshape(*lead, w.shape[-1])


def qmm_requant(x, w, shift, *, width: int = 8):
    x2, lead = _2d(x)
    mode = _mode()
    if mode == "pallas":
        out = qmm_requant_pallas(x2, w, shift, width=width)
    elif mode == "interpret":
        out = qmm_requant_pallas(x2, w, shift, width=width, interpret=True)
    else:
        out = ref.qmm_requant_ref(x2, w, shift, width=width)
    return out.reshape(*lead, w.shape[-1])


def wq_matmul(x: jax.Array, w: QTensor, *, transpose: bool = False) -> jax.Array:
    """x (..., K) float @ dequant(w) — weight-only int8 path.

    ``transpose=True`` computes x @ w.Tᵀ-style logits against an embedding
    table stored (V, D): returns x @ table.T.
    """
    if transpose:
        # Logits path: dequantize per-row exponents cannot ride the N axis of
        # the kernel (they'd be per-K); fall back to dequant + matmul.
        t = w.dequantize()
        return jnp.matmul(x, t.T.astype(x.dtype))
    x2, lead = _2d(x)
    scale = jnp.squeeze(jnp.exp2(-w.n.astype(jnp.float32)))
    if scale.ndim > 1:  # exotic multi-axis grids: dequant outside the kernel
        y = jnp.matmul(x2.astype(jnp.float32),
                       w.q.astype(jnp.float32)
                       * jnp.exp2(-w.n.astype(jnp.float32))).astype(x.dtype)
        return y.reshape(*lead, w.q.shape[-1])
    mode = _mode()
    if mode == "pallas":
        out = wq_matmul_pallas(x2, w.q, scale, out_dtype=x.dtype)
    elif mode == "interpret":
        out = wq_matmul_pallas(x2, w.q, scale, out_dtype=x.dtype, interpret=True)
    else:
        out = ref.wq_matmul_ref(x2, w.q, scale, out_dtype=x.dtype)
    return out.reshape(*lead, w.q.shape[-1])


def fake_quant_fused(x, n, *, width: int = 8):
    mode = _mode()
    if mode == "pallas":
        return fake_quant_pallas(x, n, width=width)
    if mode == "interpret":
        return fake_quant_pallas(x, n, width=width, interpret=True)
    return ref.fake_quant_ref(x, n, width=width)


def qconv1d(x, w, *, strides: int = 1, padding: str = "SAME"):
    mode = _mode()
    if mode == "pallas":
        return qconv1d_pallas(x, w, stride=strides, padding=padding)
    if mode == "interpret":
        return qconv1d_pallas(x, w, stride=strides, padding=padding, interpret=True)
    return ref.qconv1d_ref(x, w, stride=strides, padding=padding)


def qdecode_attn(q, k_cache, v_cache, k_n, v_n, kv_len):
    mode = _mode()
    if mode == "pallas":
        return qdecode_attn_pallas(q, k_cache, v_cache, k_n, v_n, kv_len)
    if mode == "interpret":
        return qdecode_attn_pallas(q, k_cache, v_cache, k_n, v_n, kv_len, interpret=True)
    return ref.qdecode_attn_ref(q, k_cache, v_cache, k_n, v_n, kv_len)


def qchunk_attn(q, k_chunk, v_chunk, k_cache, v_cache, k_n, v_n, slot, start):
    """Chunked-prefill attention + fused int8 quantize-on-write (serve path).

    Returns (out (C, Hq, D), k_cache', v_cache'): rows [start, start+C) of
    ``slot`` hold the quantized chunk; everything else passes through (the
    Pallas path aliases the cache buffers, so the write is in place).
    """
    mode = _mode()
    if mode == "pallas":
        return qchunk_attn_pallas(q, k_chunk, v_chunk, k_cache, v_cache,
                                  k_n, v_n, slot, start)
    if mode == "interpret":
        return qchunk_attn_pallas(q, k_chunk, v_chunk, k_cache, v_cache,
                                  k_n, v_n, slot, start, interpret=True)
    return ref.qchunk_attn_ref(q, k_chunk, v_chunk, k_cache, v_cache,
                               k_n, v_n, slot, start)
