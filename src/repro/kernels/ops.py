"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: the Pallas path targets TPU (and is validated on CPU in
interpret mode by the kernel tests); everywhere else the pure-jnp oracle from
``ref.py`` runs — it is the same math, so the framework is backend-portable
exactly like the paper's "portable C library" claim for KerasCNN2C.

Debug override — two equivalent spellings:

* in-process: set ``repro.kernels.ops.FORCE`` to ``"pallas"`` / ``"ref"`` /
  ``"interpret"`` (what the kernel tests do);
* from the shell: export ``REPRO_KERNELS_FORCE=interpret`` before launching —
  the canonical way to debug a Pallas kernel end-to-end on a CPU box (the
  interpreter runs the exact kernel logic, DMAs and scalar prefetch
  included, just slowly).  See docs/serving.md "Debugging kernels".
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qformat import PackedQTensor, QTensor

from . import ref
from .fake_quant import fake_quant_pallas
from .qchunk_attn import qchunk_attn_pallas
from .qconv1d import qconv1d_pallas
from .qdecode_attn import qdecode_attn_pallas
from .qmm import qmm_pallas, qmm_requant_pallas
from .qpaged_attn import qpaged_chunk_attn_pallas, qpaged_decode_attn_pallas
from .qragged_attn import qragged_attn_pallas
from .wq_matmul import wq4_matmul_pallas, wq_matmul_pallas

# None | "pallas" | "ref" | "interpret"; seeded from the environment so a
# plain `REPRO_KERNELS_FORCE=interpret python -m ...` flips every dispatch.
FORCE: Optional[str] = os.environ.get("REPRO_KERNELS_FORCE") or None


def _mode() -> str:
    if FORCE is not None:
        return FORCE
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def is_hardware_dispatch() -> bool:
    """True when kernels dispatch as *compiled* Pallas (TPU default, or
    ``FORCE="pallas"``) — the regime where per-page DMA size governs HBM
    efficiency.  The interpreter and the jnp oracle return False: they are
    correctness paths, not performance paths.  Callers gate
    hardware-geometry warnings (e.g. the serving page-size guard) on this;
    tests stub it by setting ``FORCE``."""
    return _mode() == "pallas"


def _2d(x):
    """Collapse leading dims to rows for GEMM wrappers."""
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def qmm(x: jax.Array, w: jax.Array) -> jax.Array:
    """Integer matmul with int32 accumulation; x (..., K), w (K, N)."""
    x2, lead = _2d(x)
    mode = _mode()
    if mode == "pallas":
        out = qmm_pallas(x2, w)
    elif mode == "interpret":
        out = qmm_pallas(x2, w, interpret=True)
    else:
        out = ref.qmm_ref(x2, w)
    return out.reshape(*lead, w.shape[-1])


def qmm_requant(x, w, shift, *, width: int = 8):
    """Integer matmul + shift-only requantization to ``width``-bit storage.

    x (..., K) int, w (K, N) int; ``shift`` >= 0 right-shifts the int32
    accumulator (the paper's pow2 rescale), < 0 left-shifts.  Returns
    (..., N) saturated to the Qm.n storage dtype.
    """
    x2, lead = _2d(x)
    mode = _mode()
    if mode == "pallas":
        out = qmm_requant_pallas(x2, w, shift, width=width)
    elif mode == "interpret":
        out = qmm_requant_pallas(x2, w, shift, width=width, interpret=True)
    else:
        out = ref.qmm_requant_ref(x2, w, shift, width=width)
    return out.reshape(*lead, w.shape[-1])


def wq_matmul(x: jax.Array, w: QTensor, *, transpose: bool = False) -> jax.Array:
    """x (..., K) float @ dequant(w) — weight-only int8 path.

    ``transpose=True`` computes x @ w.Tᵀ-style logits against an embedding
    table stored (V, D): returns x @ table.T.
    """
    if transpose:
        # Logits path: dequantize per-row exponents cannot ride the N axis of
        # the kernel (they'd be per-K); fall back to dequant + matmul.
        t = w.dequantize()
        return jnp.matmul(x, t.T.astype(x.dtype))
    x2, lead = _2d(x)
    scale = jnp.squeeze(jnp.exp2(-w.n.astype(jnp.float32)))
    if scale.ndim > 1:  # exotic multi-axis grids: dequant outside the kernel
        y = jnp.matmul(x2.astype(jnp.float32),
                       w.q.astype(jnp.float32)
                       * jnp.exp2(-w.n.astype(jnp.float32))).astype(x.dtype)
        return y.reshape(*lead, w.q.shape[-1])
    mode = _mode()
    if mode == "pallas":
        out = wq_matmul_pallas(x2, w.q, scale, out_dtype=x.dtype)
    elif mode == "interpret":
        out = wq_matmul_pallas(x2, w.q, scale, out_dtype=x.dtype, interpret=True)
    else:
        out = ref.wq_matmul_ref(x2, w.q, scale, out_dtype=x.dtype)
    return out.reshape(*lead, w.q.shape[-1])


def wq4_matmul(x: jax.Array, w: PackedQTensor) -> jax.Array:
    """x (..., K) float @ dequant(w) — packed sub-int8 weight-only path.

    ``w`` stores ``w.width``-bit lanes packed into int8 bytes along K with
    per-channel or per-block (MX-style) pow2 scales.  The Pallas kernel
    covers the serving-critical 2-D int4 case (unpack-in-VMEM, scales
    applied before the dot); width-2 and exotic grids take the pure-JAX
    dequant fallback, which is also what sharded paths trace.
    """
    if w.q.ndim != 2:
        # stacked / sharded layouts: dequantize outside any kernel
        return jnp.matmul(x, w.dequantize().astype(x.dtype))
    x2, lead = _2d(x)
    k = w.k
    n_out = w.q.shape[-1]
    scale = jnp.exp2(-w.n.astype(jnp.float32))
    mode = _mode()
    if w.width != 4 or mode not in ("pallas", "interpret", "ref"):
        out = ref.wq4_matmul_ref(x2, w.q, scale, k=k, width=w.width,
                                 block_size=w.block_size or 0,
                                 out_dtype=x.dtype)
        return out.reshape(*lead, n_out)
    bs = w.block_size or 0
    if bs:
        scale = scale.reshape(-1, n_out)
    if mode == "pallas":
        out = wq4_matmul_pallas(x2, w.q, scale, k=k, block_size=bs,
                                out_dtype=x.dtype)
    elif mode == "interpret":
        out = wq4_matmul_pallas(x2, w.q, scale, k=k, block_size=bs,
                                out_dtype=x.dtype, interpret=True)
    else:
        out = ref.wq4_matmul_ref(x2, w.q, scale, k=k, width=4,
                                 block_size=bs, out_dtype=x.dtype)
    return out.reshape(*lead, n_out)


def fake_quant_fused(x, n, *, width: int = 8):
    """Quantize-dequantize ``x`` on the pow2 grid 2^-n (QAT fake-quant).

    One fused kernel instead of XLA's quantize + dequantize pair; shape and
    dtype preserved.
    """
    mode = _mode()
    if mode == "pallas":
        return fake_quant_pallas(x, n, width=width)
    if mode == "interpret":
        return fake_quant_pallas(x, n, width=width, interpret=True)
    return ref.fake_quant_ref(x, n, width=width)


def qconv1d(x, w, *, strides: int = 1, padding: str = "SAME"):
    """Integer 1-D convolution with int32 accumulation.

    x (B, W, C_in) int, w (K, C_in, C_out) int -> (B, W', C_out) int32 —
    the paper's MCU conv path at TPU tile sizes.
    """
    mode = _mode()
    if mode == "pallas":
        return qconv1d_pallas(x, w, stride=strides, padding=padding)
    if mode == "interpret":
        return qconv1d_pallas(x, w, stride=strides, padding=padding, interpret=True)
    return ref.qconv1d_ref(x, w, stride=strides, padding=padding)


def qdecode_attn(q, k_cache, v_cache, k_n, v_n, kv_len):
    """Decode attention over a dense int8 KV cache, dequant-in-VMEM.

    q (B, Hq, D) f32; caches (B, S, Hkv, D) int8; k_n/v_n scalar int32 pow2
    exponents; kv_len scalar or (B,) live lengths.  Returns (B, Hq, D).
    """
    mode = _mode()
    if mode == "pallas":
        return qdecode_attn_pallas(q, k_cache, v_cache, k_n, v_n, kv_len)
    if mode == "interpret":
        return qdecode_attn_pallas(q, k_cache, v_cache, k_n, v_n, kv_len, interpret=True)
    return ref.qdecode_attn_ref(q, k_cache, v_cache, k_n, v_n, kv_len)


def qpaged_decode_attn(q, k_pool, v_pool, k_n, v_n, page_table, kv_len):
    """Paged decode attention: gather int8 K/V pages through a page table.

    q (B, Hq, D) f32; pools (num_pages, page_size, Hkv, D) int8; page_table
    (B, max_pages) int32 (-1 = unmapped); kv_len (B,) live lengths.  Returns
    (B, Hq, D).  The Pallas path DMAs one pool page per grid step via a
    scalar-prefetched table lookup; the ref path densifies per slot first.
    """
    mode = _mode()
    if mode == "pallas":
        return qpaged_decode_attn_pallas(q, k_pool, v_pool, k_n, v_n,
                                         page_table, kv_len)
    if mode == "interpret":
        return qpaged_decode_attn_pallas(q, k_pool, v_pool, k_n, v_n,
                                         page_table, kv_len, interpret=True)
    return ref.qpaged_decode_attn_ref(q, k_pool, v_pool, k_n, v_n,
                                      page_table, kv_len)


def qpaged_chunk_attn(q, k_chunk, v_chunk, k_pool, v_pool, k_n, v_n,
                      page_row, start):
    """Paged chunked-prefill attention + fused int8 quantize-on-write.

    Like :func:`qchunk_attn` but against a paged pool: ``page_row``
    ((max_pages,) int32) is the target slot's page-table row, and logical
    rows [start, start+C) of the slot receive the quantized chunk inside
    their pool pages.  Returns (out (C, Hq, D), k_pool', v_pool'); the
    Pallas path aliases the pool buffers so the write is in place.
    """
    mode = _mode()
    if mode == "pallas":
        return qpaged_chunk_attn_pallas(q, k_chunk, v_chunk, k_pool, v_pool,
                                        k_n, v_n, page_row, start)
    if mode == "interpret":
        return qpaged_chunk_attn_pallas(q, k_chunk, v_chunk, k_pool, v_pool,
                                        k_n, v_n, page_row, start,
                                        interpret=True)
    return ref.qpaged_chunk_attn_ref(q, k_chunk, v_chunk, k_pool, v_pool,
                                     k_n, v_n, page_row, start)


def qragged_attn(q, k_new, v_new, k_pool, v_pool, k_n, v_n, table,
                 slot_ids, positions):
    """Ragged token-batch attention + fused int8 quantize-on-write.

    The one-forward-per-tick serve kernel: q/k_new/v_new are (T, H*, D) flat
    token batches mixing decode tokens and prefill-chunk tokens from several
    slots; ``slot_ids``/``positions`` ((T,) int32) name each token's logical
    cache row (-1 = inert pad row); ``table`` ((slots, max_pages) int32) maps
    logical pages to pool pages — a dense cache passes the identity table
    over its block-reshaped view (see nn/attention.py).  Returns
    (out (T, Hq, D), k_pool', v_pool'); the Pallas path aliases the pools so
    the write is in place.
    """
    mode = _mode()
    if mode == "pallas":
        return qragged_attn_pallas(q, k_new, v_new, k_pool, v_pool,
                                   k_n, v_n, table, slot_ids, positions)
    if mode == "interpret":
        return qragged_attn_pallas(q, k_new, v_new, k_pool, v_pool,
                                   k_n, v_n, table, slot_ids, positions,
                                   interpret=True)
    return ref.qragged_attn_ref(q, k_new, v_new, k_pool, v_pool,
                                k_n, v_n, table, slot_ids, positions)


def qchunk_attn(q, k_chunk, v_chunk, k_cache, v_cache, k_n, v_n, slot, start):
    """Chunked-prefill attention + fused int8 quantize-on-write (serve path).

    Returns (out (C, Hq, D), k_cache', v_cache'): rows [start, start+C) of
    ``slot`` hold the quantized chunk; everything else passes through (the
    Pallas path aliases the cache buffers, so the write is in place).
    """
    mode = _mode()
    if mode == "pallas":
        return qchunk_attn_pallas(q, k_chunk, v_chunk, k_cache, v_cache,
                                  k_n, v_n, slot, start)
    if mode == "interpret":
        return qchunk_attn_pallas(q, k_chunk, v_chunk, k_cache, v_cache,
                                  k_n, v_n, slot, start, interpret=True)
    return ref.qchunk_attn_ref(q, k_chunk, v_chunk, k_cache, v_cache,
                               k_n, v_n, slot, start)
