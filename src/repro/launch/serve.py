"""Serving driver: batched generation with the quantized deployment options.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m-smoke \\
        --batch 4 --prompt-len 16 --max-new 32 [--wq] [--qkv]

--wq   int8 weight-only storage (integerize_weights_only → wq_matmul path)
--qkv  int8 KV cache on the paper's Qm.n grid
Both reproduce the paper's deployment flow (train fp → quantize → deploy) at
the serving layer.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.registry import get_config
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--wq", action="store_true")
    ap.add_argument("--qkv", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(args.seed))

    engine = ServeEngine(model=model, params=params,
                         max_len=args.prompt_len + args.max_new,
                         batch_slots=args.batch, quantized_kv=args.qkv,
                         weight_quant=args.wq, temperature=args.temperature)

    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab,
                                 dtype=jnp.int32)
    t0 = time.time()
    out = engine.generate(prompts, args.max_new, seed=args.seed)
    out.block_until_ready()
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print(out[:, :16])
    return out


if __name__ == "__main__":
    main()
