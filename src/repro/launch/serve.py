"""Serving driver: continuous batching under an arrival-schedule workload.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m-smoke \\
        --slots 4 --prompt-len 16 --requests 12 --max-new 32 --max-new-min 8 \\
        --arrival-spacing 2 [--wq] [--qkv] [--policy scheduler]

--wq   weight-only storage (bare = int8 → wq_matmul; int4[-block] /
       int2[-block] pack sub-int8 lanes → wq4_matmul; --wq-block sets the
       per-block scale granularity)
--qkv  int8 KV cache on the paper's Qm.n grid
Both reproduce the paper's deployment flow (train fp → quantize → deploy) at
the serving layer — now under realistic traffic instead of one lockstep batch.

Policies:
  chunked    continuous batching with chunked-prefill admission: every tick
             is ONE fused mixed step = all live decode slots + one
             --chunk-size prompt chunk written in place into its slot's KV
             slice.  Decode never stalls more than a chunk and every prompt
             length shares one compile shape.  --token-budget caps per-tick
             tokens (live slots + chunk; decode always runs)
  ragged     chunked, but every tick is ONE ragged forward over a flat token
             batch: all live decode tokens plus up to --prefill-lanes prompt
             chunks from *different* queued requests, routed by per-token
             slot/position vectors (one GEMM per layer per tick, one compile
             shape for the whole run).  --token-budget is split across lanes
             in admission order, so bursts drain --prefill-lanes times
             faster without stalling decode
  scheduler  continuous batching with one-shot admission: a freed slot is
             refilled by a stop-the-world batch-1 prefill + write_kv_slot
             copy (every live slot stalls for the full prompt)
  restart    restart-the-batch baseline: lockstep generate() per gathered
             batch, everyone waits for the longest request
  lockstep   the legacy single-batch generate() (no queue; --requests is
             clamped to --slots)

--paged (chunked/ragged) swaps the dense per-slot KV slabs for a shared page
pool + per-slot page tables: admission block-allocates ceil(extent /
--page-size) pages and defers on exhaustion instead of crashing;
--pool-pages sizes the pool (default dense parity).  Prefix sharing is on
by default in paged mode: requests whose prompt prefix matches resident
pages map them (refcounted, copy-on-write at the divergence page) instead
of allocating copies — --no-prefix-sharing measures the unshared baseline.
--oversubscribe switches admission to lazy decode pages (reserve the prompt
extent only, grow one page per crossed boundary) with --preempt-policy
{recompute,swap} deciding what happens when the pool runs dry mid-decode.
docs/serving.md walks the geometry and the knobs.

Hardening knobs (docs/serving.md "Failure semantics"): --deadline-steps puts
a per-request latency bound on the workload, --max-queue/--reject-policy
bound the waiting queue (backpressure), --audit runs the pool/state
invariant auditor every tick and arms the NaN/Inf logit sentinel, and
--fault-plan injects a deterministic failure schedule (serve/faults.py) for
chaos drills.  Every request always comes back with a terminal status.

Timing is reported as warmup/compile seconds and steady-state tok/s
*separately* — jit compile no longer pollutes the throughput figure.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_config
from repro.serve import Request, ServeEngine, run_restart_batching


def build_workload(args, vocab: int):
    """Arrival schedule: request i arrives at tick i*spacing with a prompt of
    --prompt-len tokens and max_new alternating across [min, max] (length
    spread is what continuous batching exploits)."""
    rng = np.random.default_rng(args.seed + 1)
    lo = args.max_new_min or args.max_new
    deadline = getattr(args, "deadline_steps", 0) or None
    reqs = []
    for i in range(args.requests):
        max_new = lo if (lo == args.max_new or i % 2 == 0) else args.max_new
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab, size=args.prompt_len,
                                dtype=np.int32),
            max_new=int(max_new),
            arrival=i * args.arrival_spacing,
            deadline_steps=deadline))
    return reqs


def report(name: str, stats) -> None:
    s = stats.summary()
    extra = ""
    if s.get("p99_latency_ms"):
        extra += (f" | latency p50/p99 {s['p50_latency_ms']:.1f}/"
                  f"{s['p99_latency_ms']:.1f} ms")
    if s.get("prefill_chunks"):
        extra += (f" | chunks {s['prefill_chunks']} "
                  f"(stalled {s['stalled_chunks']})")
    if s.get("num_jit_compiles"):
        extra += f" | jit shapes {s['num_jit_compiles']}"
    if s.get("peak_pages_in_use"):
        extra += (f" | pages peak {s['peak_pages_in_use']} "
                  f"(stalls {s['page_stalls']}, "
                  f"fill {s['page_occupancy']:.2f})")
    if s.get("prefix_hits"):
        extra += (f" | prefix hits {s['prefix_hits']} "
                  f"(shared {s['shared_pages_mapped']} pages, "
                  f"cow {s['cow_copies']})")
    if s.get("grown_pages"):
        extra += (f" | grown {s['grown_pages']} pages "
                  f"(preempt {s['preemptions']}, resume {s['resumes']}, "
                  f"swapped {s['swapped_pages']})")
    if s.get("p99_ttft_steps"):
        extra += (f" | ttft p50/p99 {s['p50_ttft_steps']:.0f}/"
                  f"{s['p99_ttft_steps']:.0f} steps")
    degraded = (s.get("rejections", 0) + s.get("timeouts", 0)
                + s.get("cancellations", 0) + s.get("failed", 0))
    if degraded:
        extra += (f" | completion {s['completion_rate']:.2f} "
                  f"(rej {s['rejections']}, timeout {s['timeouts']}, "
                  f"cancel {s['cancellations']}, failed {s['failed']})")
    if s.get("state_kinds"):
        extra += f" | state {s['state_kinds']}"
    if s.get("audited_ticks"):
        extra += f" | audited {s['audited_ticks']} ticks clean"
    if s.get("fault_events"):
        extra += (f" | faults {s['fault_events']} "
                  f"(swap refusals {s['swap_refusals']})")
    print(f"[{name}] warmup(compile) {s['compile_s']:.2f}s | "
          f"steady {s['steady_tok_s']:.1f} tok/s over {s['steady_s']:.3f}s | "
          f"occupancy {s['occupancy']:.2f} | "
          f"latency p50/p99 {s['p50_latency_steps']:.0f}/"
          f"{s['p99_latency_steps']:.0f} steps | "
          f"cache {s['peak_cache_bytes']/1024:.0f} KiB{extra}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", "--batch", type=int, default=4, dest="slots")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-new-min", type=int, default=0,
                    help="alternate request horizons in [min, max] "
                         "(0 = uniform --max-new)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--arrival-spacing", type=int, default=2,
                    help="decode-step ticks between request arrivals")
    ap.add_argument("--policy", default="scheduler",
                    choices=["chunked", "ragged", "scheduler", "restart",
                             "lockstep"])
    ap.add_argument("--prefill-lanes", type=int, default=2,
                    help="concurrent prompt-chunk lanes per ragged tick "
                         "(ragged policy; 1 reproduces chunked admission "
                         "order with the ragged kernel)")
    ap.add_argument("--chunk-size", type=int, default=16,
                    help="prefill chunk tokens per mixed/ragged step "
                         "(chunked and ragged policies; the last chunk's "
                         "padded rows must fit max_len, so keep it "
                         "<= --prompt-len)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="per-tick token cap for chunked admission "
                         "(0 = unbounded; must fit one chunk)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: shared page pool + per-slot page "
                         "tables with block-allocated admission (chunked "
                         "policy only; see docs/serving.md)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="tokens per KV page (paged mode; 0 = auto: 128 on "
                         "hardware Pallas dispatch, 16 elsewhere)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="KV pool pages shared by all slots (0 = dense "
                         "parity: slots * ceil(max_len/page_size)); smaller "
                         "pools trade headroom for more slots per byte")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable prompt-prefix page sharing in paged mode "
                         "(on by default: same-prefix requests map the same "
                         "pool pages, COW at the divergence page)")
    ap.add_argument("--oversubscribe", action="store_true",
                    help="lazy decode pages (paged mode): admission reserves "
                         "only the prompt extent, decode grows one page per "
                         "crossed boundary and preempts a victim when the "
                         "pool runs dry (see --preempt-policy)")
    ap.add_argument("--preempt-policy", default="recompute",
                    choices=["recompute", "swap"],
                    help="mid-decode pool-exhaustion policy (with "
                         "--oversubscribe): 'recompute' re-queues the victim "
                         "as a continuation prompt re-prefilled later; "
                         "'swap' copies its private pages to host memory "
                         "and restores them bit-exactly on resume")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="per-request deadline in decode-step ticks "
                         "(0 = none): a request unfinished this many ticks "
                         "after arrival is returned status='timeout' with "
                         "its tokens so far")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the arrived-and-waiting queue (0 = "
                         "unbounded): arrivals past the bound are shed "
                         "per --reject-policy as status='rejected'")
    ap.add_argument("--reject-policy", default="reject",
                    choices=["reject", "shed_oldest"],
                    help="bounded-queue backpressure: reject the new "
                         "arrival, or shed the oldest waiting request "
                         "in its favor")
    ap.add_argument("--audit", action="store_true",
                    help="run the pool/state invariant auditor every tick "
                         "and arm the NaN/Inf logit sentinel "
                         "(serve/audit.py; costs a per-tick host readback)")
    ap.add_argument("--fault-plan", default="",
                    help="deterministic fault injection: inline JSON "
                         "(starting '{') or a JSON file path — see "
                         "serve/faults.py FaultPlan.from_spec")
    ap.add_argument("--time-ticks", action="store_true",
                    help="block per tick and report wall-clock p50/p99 "
                         "request latency (ms)")
    ap.add_argument("--prompt-bucket", type=int, default=0,
                    help="round prompt lengths up to this multiple "
                         "(0 = exact lengths; one jit compile per length; "
                         "scheduler policy only)")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="stop a slot when this token is sampled (-1 = off)")
    ap.add_argument("--wq", nargs="?", const="int8", default=False,
                    choices=["int8", "int4", "int4-block", "int2",
                             "int2-block"],
                    help="weight-only storage format (bare --wq = int8; "
                         "int4/int2 pack two/four lanes per byte, -block "
                         "adds per-block scales)")
    ap.add_argument("--wq-block", type=int, default=32,
                    help="K rows per scale block for --wq *-block formats")
    ap.add_argument("--qkv", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.paged and args.policy not in ("chunked", "ragged"):
        raise SystemExit("--paged requires --policy chunked or ragged "
                         "(block-allocated admission rides the fused step)")
    engine = ServeEngine(model=model, params=params,
                         max_len=args.prompt_len + args.max_new,
                         batch_slots=args.slots, quantized_kv=args.qkv,
                         weight_quant=args.wq, weight_block=args.wq_block,
                         temperature=args.temperature,
                         paged_kv=args.paged,
                         page_size=args.page_size or None,
                         kv_pool_pages=args.pool_pages or None)

    if args.policy == "lockstep":
        import time

        n = min(args.requests, args.slots)
        prompts = jax.random.randint(
            jax.random.PRNGKey(args.seed + 1), (args.slots, args.prompt_len),
            0, cfg.vocab, dtype=jnp.int32)
        t0 = time.perf_counter()
        jax.block_until_ready(engine.generate(prompts, args.max_new,
                                              seed=args.seed))
        warm = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = engine.generate(prompts, args.max_new, seed=args.seed)
        out.block_until_ready()
        dt = time.perf_counter() - t0
        toks = n * args.max_new
        print(f"[lockstep] warmup(compile) {warm:.2f}s | "
              f"steady {toks/dt:.1f} tok/s over {dt:.3f}s")
        print(out[:n, :16])
        return out

    fault_plan = None
    if args.fault_plan:
        from repro.serve import FaultPlan

        fault_plan = FaultPlan.from_spec(args.fault_plan)
        if args.policy in ("restart", "lockstep"):
            raise SystemExit("--fault-plan requires a scheduler policy "
                             "(chunked/ragged/scheduler)")
        if fault_plan.nan and not args.audit:
            raise SystemExit("--fault-plan with nan events requires --audit "
                             "(the NaN sentinel is audit mode's health "
                             "readback)")
    reqs = build_workload(args, cfg.vocab)
    if args.policy == "restart":
        results, stats = run_restart_batching(
            engine, reqs, seed=args.seed,
            eos_id=None if args.eos_id < 0 else args.eos_id)
        report("restart", stats)
    else:
        sched = engine.scheduler(
            eos_id=None if args.eos_id < 0 else args.eos_id,
            prompt_bucket=args.prompt_bucket or None,
            chunk_size=(args.chunk_size
                        if args.policy in ("chunked", "ragged") else None),
            token_budget=(args.token_budget or None)
            if args.policy in ("chunked", "ragged") else None,
            ragged=args.policy == "ragged",
            prefill_lanes=(args.prefill_lanes
                           if args.policy == "ragged" else 1),
            prefix_sharing=not args.no_prefix_sharing,
            oversubscribe=args.oversubscribe,
            preempt_policy=args.preempt_policy,
            max_queue=args.max_queue or None,
            reject_policy=args.reject_policy,
            audit=args.audit)
        results, stats = sched.run(reqs, seed=args.seed,
                                   time_ticks=args.time_ticks,
                                   fault_plan=fault_plan)
        report(args.policy, stats)
    first = results[min(results)]
    print(f"request {first.rid}: {len(first.tokens)} tokens "
          f"({first.status}), first-10 {first.tokens[:10]}")
    return results


if __name__ == "__main__":
    main()
