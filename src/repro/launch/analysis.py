"""Post-compile HLO analysis: collective-bytes accounting + memory stats.

``cost_analysis()`` does not expose collective traffic, so the dry-run parses
the optimized (post-SPMD) HLO text and sums the result sizes of every
communication op.  Wire-byte heuristics (ring algorithms, per participant):

  all-gather         ≈ result_bytes × (n−1)/n            → counted as result
  all-reduce         ≈ 2 × tensor_bytes × (n−1)/n        → counted as 2×result
  reduce-scatter     ≈ input_bytes × (n−1)/n             → result × group_size
  all-to-all         ≈ tensor_bytes × (n−1)/n            → counted as result
  collective-permute ≈ tensor_bytes                      → counted as result

These are the standard ring/torus estimates; group sizes are parsed from
``replica_groups`` (iota or explicit form).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(?P<shapes>[^=]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\(")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPL_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-category {count, result_bytes, wire_bytes} from optimized HLO."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        op = m.group("op")
        rb = _shape_bytes(m.group("shapes"))
        gs = _group_size(line)
        if op == "all-reduce":
            wire = 2 * rb * max(gs - 1, 1) / max(gs, 1)
        elif op == "reduce-scatter":
            wire = rb * max(gs - 1, 1)
        elif op == "collective-permute":
            wire = rb
        else:  # all-gather / all-to-all
            wire = rb * max(gs - 1, 1) / max(gs, 1)
        d = out.setdefault(op, {"count": 0, "result_bytes": 0.0,
                                "wire_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += rb
        d["wire_bytes"] += wire
    return out


def total_wire_bytes(collectives: Dict[str, Dict[str, float]]) -> float:
    return sum(d["wire_bytes"] for d in collectives.values())


def memory_stats(compiled) -> Dict[str, float]:
    """Extract whatever memory_analysis exposes on this backend."""
    out: Dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes", "host_generated_code_size_in_bytes",
                 "host_argument_size_in_bytes", "host_output_size_in_bytes",
                 "host_temp_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def cost_stats(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" in k.lower()
                or k in ("transcendentals",))}
