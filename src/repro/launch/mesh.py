"""Production mesh builders.

Functions (never module-level constants) so importing this module does not
touch jax device state — the 512-placeholder-device XLA flag must be set by
the *entry point* (dryrun.py) before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod: (data=16, model=16); two pods: (pod=2, data=16, model=16).

    The `pod` axis composes with `data` for DP (the gradient all-reduce is the
    only DCN-crossing collective) and can be re-purposed as a pipeline axis
    (repro.dist.pipeline).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over the actually-present devices (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants for the roofline terms (per chip).
HW = {
    "name": "tpu-v5e",
    "peak_bf16_flops": 197e12,     # FLOP/s
    "peak_int8_ops": 394e12,       # OP/s (MXU int8 = 2x bf16)
    "hbm_bytes_per_s": 819e9,      # HBM bandwidth
    "ici_bytes_per_s_per_link": 50e9,
    "ici_links": 4,                # 2D torus on v5e
    "hbm_bytes": 16 * 1024**3,
}
