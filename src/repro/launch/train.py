"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m-smoke \\
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt [--qat]

Production behaviours exercised here (scaled to the container):
  * restart-from-latest: the driver always tries to restore before training —
    kill it at any step and re-launch to resume (tests/test_system.py does
    exactly that with a simulated preemption),
  * atomic async checkpoints every --ckpt-every steps,
  * deterministic data: batch content is a pure function of (seed, step),
  * straggler watchdog: steps slower than --straggler-factor × the running
    median are logged (on real fleets this feeds the health controller that
    triggers elastic down-scaling; here it logs),
  * elastic restore: --mesh data,model can differ between runs — the restore
    path device_puts onto the new topology.
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.data.pipeline import DataPipeline, markov_batch_fn
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models.registry import get_config
from repro.optim import adamw, multistep_lr, sgd
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--qat", action="store_true", help="int8 QAT (paper 4.3)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="1,1", help="data,model")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    model = cfg.build(dtype=jnp.float32, remat="none")
    dm, tp = (int(x) for x in args.mesh.split(","))
    mesh = make_host_mesh(dm, tp) if dm * tp > 1 else None
    rules = shd.make_axis_rules(mesh) if mesh else None

    optimizer = (adamw(weight_decay=0.01) if args.optimizer == "adamw"
                 else sgd(momentum=0.9, weight_decay=5e-4))
    schedule = multistep_lr(args.lr, milestones=(args.steps * 2 // 3,
                                                 args.steps * 5 // 6))
    policy = QuantPolicy.int8_qat() if args.qat else QuantPolicy.float32()
    step_fn = jax.jit(make_train_step(model, optimizer, schedule,
                                      policy=policy, mesh=mesh,
                                      axis_rules=rules,
                                      microbatch_split=args.microbatch),
                      donate_argnums=(0,))

    pipe = DataPipeline(markov_batch_fn(cfg.vocab, args.batch, args.seq,
                                        seed=args.seed))

    params = model.init(jax.random.PRNGKey(args.seed))
    state = {"params": params, "opt": optimizer.init(params),
             "step": jnp.zeros((), jnp.int32)}

    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, state)
            pipe.restore({"step": latest})
            print(f"[restore] resumed from step {latest}")

    times = []
    start_step = int(state["step"])
    for step in range(start_step, args.steps):
        batch = next(pipe)
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        metrics = jax.device_get(metrics)
        dt = time.time() - t0
        times.append(dt)
        if len(times) > 20:
            times.pop(0)
        med = statistics.median(times)
        if dt > args.straggler_factor * med and len(times) > 5:
            print(f"[straggler] step {step}: {dt:.2f}s vs median {med:.2f}s")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"acc {metrics['accuracy']:.3f} lr {metrics['lr']:.2e} "
                  f"{dt*1e3:.0f}ms")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, state)
    if ckpt:
        ckpt.save(args.steps, state)
        ckpt.wait()
    print("done")
    return state


if __name__ == "__main__":
    main()
