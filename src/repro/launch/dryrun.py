import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first init).

DOC = """Multi-pod dry-run: AOT-lower + compile every (arch × shape × mesh) cell.

For each cell this builds the real step function (train_step with optimizer,
or the serving prefill/decode step), attaches NamedShardings to
ShapeDtypeStruct stand-ins (zero allocation), runs ``.lower().compile()``
against the 256-chip single-pod / 512-chip two-pod mesh, and records:

  * memory_analysis()  — per-device argument/output/temp/code bytes,
  * cost_analysis()    — HLO FLOPs + bytes accessed,
  * the collective schedule (parsed from post-SPMD HLO) with wire bytes.

Results land in experiments/dryrun/<arch>__<shape>__<mesh>[__variant].json;
benchmarks/roofline.py turns them into the §Roofline table.

Variants are the §Perf levers:
  --params-dtype bf16      (vs paper-faithful f32 master)
  --wq                     int8 weight-only serving (Pallas wq_matmul path)
  --qkv                    int8 KV cache (paper grid) for decode
  --remat {full,dots,none,off}
  --microbatch N           gradient-accumulation split
  --seq-shard              sequence-parallel activations
  --no-decode-kv-shard     replicate the KV cache instead of model-sharding it
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.core.integerize import integerize_weights_only
from repro.dist import sharding as shd
from repro.launch import analysis
from repro.launch.mesh import HW, make_production_mesh
from repro.models.registry import get_config, list_archs
from repro.optim import sgd
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.trainer import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _cast_float(tree, dtype):
    def leaf(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, dtype) \
                if isinstance(x, jax.ShapeDtypeStruct) else x.astype(dtype)
        return x
    return jax.tree_util.tree_map(leaf, tree)


def _sds_tree(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def probe_cfg(cfg, k: int):
    """Depth-k probe: identical per-layer shapes, k periods, unrolled."""
    import dataclasses

    repl = {"arch_id": f"{cfg.arch_id}-probe{k}",
            "n_layers": cfg.first_k_dense + k * len(cfg.layout)}
    if cfg.is_encdec:
        repl["enc_layers"] = k
    return dataclasses.replace(cfg, **repl)


def lower_cell(cfg, shape_name: str, mesh, opts, *, scan_layers: bool = True):
    """Build the cell's step fn + sharded SDS args and AOT-lower it."""
    sh = SHAPES[shape_name]
    rules = shd.make_axis_rules(mesh, seq_shard=opts.seq_shard,
                                decode_kv_shard=not opts.no_decode_kv_shard,
                                dp_only=opts.dp_only)
    model = cfg.build(dtype=jnp.bfloat16, remat=opts.remat,
                      scan_layers=scan_layers)

    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

    if sh.kind == "train":
        if opts.params_dtype != "float32":
            params_sds = _cast_float(params_sds, jnp.dtype(opts.params_dtype))
        optimizer = sgd(momentum=0.9, weight_decay=5e-4)
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
        state_sds = {"params": params_sds, "opt": opt_sds,
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
        pspecs = shd.param_pspecs(params_sds, mesh, rules)
        state_sh = {"params": pspecs,
                    "opt": {"m": shd.param_pspecs(opt_sds["m"], mesh, rules)},
                    "step": shd.named(mesh)}
        batch_sds = cfg.input_specs(shape_name)
        batch_sh = shd.batch_pspecs(batch_sds, mesh, rules)
        step = make_train_step(model, optimizer, 0.01, mesh=mesh,
                               axis_rules=rules,
                               microbatch_split=opts.microbatch,
                               int8_weight_gather=getattr(opts, "wq_train",
                                                          False))
        args = (shd.with_shardings(state_sds, state_sh),
                shd.with_shardings(batch_sds, batch_sh))
        return jax.jit(step, donate_argnums=(0,)).lower(*args)
    else:
        # serving: bf16 weights baseline; --wq = int8 weight-only QTensors
        if opts.wq:
            params_sds = jax.eval_shape(
                lambda: integerize_weights_only(model.init(jax.random.PRNGKey(0))))
        else:
            params_sds = _cast_float(params_sds, jnp.bfloat16)
        pspecs = shd.param_pspecs(params_sds, mesh, rules,
                                  serve=(sh.kind == "decode"))
        specs = cfg.input_specs(shape_name)
        if sh.kind == "prefill":
            cache_sds = jax.eval_shape(lambda: model.init_cache(
                sh.global_batch, sh.seq_len, quantized_kv=opts.qkv,
                kv_dtype=jnp.bfloat16))
            cache_sh = shd.cache_pspecs(cache_sds, mesh, rules)
            tokens = specs["tokens"]
            tok_sh = shd.batch_pspecs(tokens, mesh, rules)
            step = make_prefill_step(model, mesh=mesh, axis_rules=rules)
            args = [shd.with_shardings(params_sds, pspecs),
                    shd.with_shardings(tokens, tok_sh),
                    shd.with_shardings(cache_sds, cache_sh)]
            kw = {}
            if "embeds" in specs:
                emb_sh = shd.batch_pspecs(specs["embeds"], mesh, rules)
                key = "enc" if cfg.is_encdec else "embeds"
                kw[key] = shd.with_shardings(specs["embeds"], emb_sh)
            return jax.jit(step, donate_argnums=(2,)).lower(*args, **kw)
        else:  # decode
            # build the cache from THIS model (scan vs unrolled probe layouts
            # differ; specs["cache"] assumes the scanned layout)
            cache_sds = jax.eval_shape(lambda: model.init_cache(
                sh.global_batch, sh.seq_len, quantized_kv=opts.qkv,
                kv_dtype=jnp.bfloat16))
            cache_sh = shd.cache_pspecs(cache_sds, mesh, rules)
            tok_sh = shd.batch_pspecs(specs["tokens"], mesh, rules)
            rng_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
            step = make_decode_step(model, mesh=mesh, axis_rules=rules)
            args = [shd.with_shardings(params_sds, pspecs),
                    shd.with_shardings(specs["tokens"], tok_sh),
                    shd.with_shardings(cache_sds, cache_sh),
                    rng_sds]
            kw = {}
            if "enc" in specs:
                enc_sh = shd.batch_pspecs(specs["enc"], mesh, rules)
                kw["enc"] = shd.with_shardings(specs["enc"], enc_sh)
            return jax.jit(step, donate_argnums=(2,)).lower(*args, **kw)


def _compile_and_analyze(lowered):
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = analysis.memory_stats(compiled)
    cost = analysis.cost_stats(compiled)
    hlo = compiled.as_text()
    coll = analysis.parse_collectives(hlo)
    return {"memory": mem, "cost": cost, "collectives": coll,
            "collective_wire_bytes": analysis.total_wire_bytes(coll),
            "hlo_bytes": len(hlo), "compile_s": round(t_compile, 2)}


def build_cell(arch: str, shape_name: str, mesh, opts) -> dict:
    """Lower + compile one cell (full scanned model + 2 unrolled depth probes).

    XLA's cost_analysis counts a while-loop body ONCE, so the scanned stack's
    FLOPs/bytes/collectives are under-reported by ~n_periods.  The two probes
    (1 and 2 periods, unrolled) give exact per-period deltas:
        total(N) = probe1 + (N - 1) × (probe2 - probe1)
    Memory analysis comes from the full scanned compile (the real artifact).
    """
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    n_chips = mesh.devices.size

    t0 = time.time()
    lowered = lower_cell(cfg, shape_name, mesh, opts, scan_layers=True)
    t_lower = time.time() - t0
    full = _compile_and_analyze(lowered)

    n_periods = (cfg.n_layers - cfg.first_k_dense) // len(cfg.layout)
    probes = {}
    extrap = {}
    if opts.probe and n_periods > 1:
        for k in (1, 2):
            pl = lower_cell(probe_cfg(cfg, k), shape_name, mesh, opts,
                            scan_layers=False)
            pr = _compile_and_analyze(pl)
            probes[k] = {"cost": pr["cost"],
                         "collective_wire_bytes": pr["collective_wire_bytes"],
                         "collectives": pr["collectives"],
                         "compile_s": pr["compile_s"]}

        def lin(v1, v2):
            return v1 + (n_periods - 1) * (v2 - v1)

        for key in ("flops", "bytes accessed"):
            v1 = probes[1]["cost"].get(key, 0.0)
            v2 = probes[2]["cost"].get(key, 0.0)
            extrap[key] = lin(v1, v2)
        extrap["wire_bytes"] = lin(probes[1]["collective_wire_bytes"],
                                   probes[2]["collective_wire_bytes"])
        extrap["n_periods"] = n_periods

    record = {
        "arch": arch, "shape": shape_name, "kind": sh.kind,
        "mesh": {"shape": dict(mesh.shape), "n_chips": int(n_chips)},
        "variant": opts.variant_name(),
        "seq_len": sh.seq_len, "global_batch": sh.global_batch,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "memory": full["memory"], "cost": full["cost"],
        "collectives": full["collectives"],
        "collective_wire_bytes": full["collective_wire_bytes"],
        "probes": probes, "extrapolated": extrap,
        "hlo_bytes": full["hlo_bytes"],
        "lower_s": round(t_lower, 2), "compile_s": full["compile_s"],
        "hw": HW,
    }
    return record


def cell_path(arch, shape_name, multi_pod, variant, out_dir):
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    v = f"__{variant}" if variant and variant != "baseline" else ""
    return os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}{v}.json")


class Opts(argparse.Namespace):
    def variant_name(self):
        parts = []
        if self.params_dtype != "float32":
            parts.append(self.params_dtype)
        if self.wq:
            parts.append("wq")
        if getattr(self, "wq_train", False):
            parts.append("wqt")
        if self.qkv:
            parts.append("qkv")
        if self.remat != "full":
            parts.append(f"remat-{self.remat}")
        if self.microbatch != 1:
            parts.append(f"mb{self.microbatch}")
        if self.seq_shard:
            parts.append("sp")
        if self.dp_only:
            parts.append("dponly")
        if self.no_decode_kv_shard:
            parts.append("nokvs")
        return "-".join(parts) or "baseline"


def all_cells():
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name in SHAPES:
            if cfg.supports(shape_name):
                yield arch, shape_name


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every supported cell (subprocess per cell)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    # variants
    ap.add_argument("--params-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--wq", action="store_true")
    ap.add_argument("--wq-train", action="store_true",
                    help="int8 weight-gather training (STE, f32 master)")
    ap.add_argument("--qkv", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none", "off"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--dp-only", action="store_true")
    ap.add_argument("--no-decode-kv-shard", action="store_true")
    ap.add_argument("--no-probe", dest="probe", action="store_false",
                    help="skip the depth-probe compiles (cost extrapolation)")
    ap.add_argument("--timeout", type=int, default=3600)
    opts = ap.parse_args(argv, namespace=Opts())
    os.makedirs(opts.out, exist_ok=True)

    if opts.all:
        cells = list(all_cells())
        meshes = [False, True] if opts.both_meshes else [opts.multi_pod]
        failures = []
        for arch, shape_name in cells:
            for mp in meshes:
                path = cell_path(arch, shape_name, mp, opts.variant_name(),
                                 opts.out)
                if opts.skip_existing and os.path.exists(path):
                    print(f"skip {path}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--out", opts.out]
                if mp:
                    cmd.append("--multi-pod")
                for flag in ("wq", "wq_train", "qkv", "seq_shard", "dp_only",
                             "no_decode_kv_shard"):
                    if getattr(opts, flag):
                        cmd.append("--" + flag.replace("_", "-"))
                if opts.params_dtype != "float32":
                    cmd += ["--params-dtype", opts.params_dtype]
                if opts.remat != "full":
                    cmd += ["--remat", opts.remat]
                if opts.microbatch != 1:
                    cmd += ["--microbatch", str(opts.microbatch)]
                print(">>", " ".join(cmd), flush=True)
                r = subprocess.run(cmd, timeout=opts.timeout)
                if r.returncode != 0:
                    failures.append((arch, shape_name, mp))
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    assert opts.arch and opts.shape, "--arch and --shape required (or --all)"
    mesh = make_production_mesh(multi_pod=opts.multi_pod)
    path = cell_path(opts.arch, opts.shape, opts.multi_pod,
                     opts.variant_name(), opts.out)
    try:
        record = build_cell(opts.arch, opts.shape, mesh, opts)
    except Exception:
        record = {"arch": opts.arch, "shape": opts.shape,
                  "variant": opts.variant_name(),
                  "mesh": {"multi_pod": opts.multi_pod},
                  "error": traceback.format_exc()}
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        print(record["error"], file=sys.stderr)
        sys.exit(1)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    mb = record["memory"].get("argument_size_in_bytes", 0) / 2**20
    ex = record.get("extrapolated", {})
    print(f"OK {path}\n   args/device={mb:.1f}MiB "
          f"temp/device={record['memory'].get('temp_size_in_bytes', 0)/2**20:.1f}MiB "
          f"flops={ex.get('flops', record['cost'].get('flops', 0)):.3e} "
          f"wire={ex.get('wire_bytes', record['collective_wire_bytes']):.3e}B "
          f"compile={record['compile_s']}s")


if __name__ == "__main__":
    main()
