"""Serving layer: jitted step engine, continuous-batching scheduler, paging.

``ServeEngine`` owns the jitted prefill/decode/mixed steps and the cache
geometry (dense slabs or a paged pool); ``Scheduler`` owns batch policy
(admission, eviction, page allocation) over the per-slot decode-state
adapters in ``serve/slot_state.py`` (paged/dense KV, recurrent SSM/RWKV
state, cached EncDec cross-attention); ``PageAllocator`` is the host-side
free list behind paged admission.  See docs/serving.md for the architecture.
"""
from repro.serve.admission import (AdmissionPlanner,  # noqa: F401
                                   pick_preemption_victim)
from repro.serve.audit import (AuditError, check_allocator,  # noqa: F401
                               check_cross_lens, check_page_tables,
                               check_recurrent_rows, check_swap)
from repro.serve.engine import (ServeEngine, make_decode_step,  # noqa: F401
                                make_mixed_step, make_prefill_step,
                                mask_vocab_tail, sample_tokens)
from repro.serve.faults import FaultPlan  # noqa: F401
from repro.serve.lanes import assemble_ragged_tick  # noqa: F401
from repro.serve.paging import (PageAllocator, PrefixIndex,  # noqa: F401
                                SwapArea)
from repro.serve.scheduler import (STATUSES, Request,  # noqa: F401
                                   RequestResult, Scheduler, ServeStats,
                                   run_restart_batching)
from repro.serve.slot_state import (CrossAttnState,  # noqa: F401
                                    DenseKVState, PagedKVState,
                                    RecurrentState, SlotState, adapters_for,
                                    state_bytes_per_slot, state_kinds)
