from repro.serve.engine import (ServeEngine, make_decode_step,  # noqa: F401
                                make_mixed_step, make_prefill_step,
                                mask_vocab_tail, sample_tokens)
from repro.serve.scheduler import (Request, RequestResult,  # noqa: F401
                                   Scheduler, ServeStats,
                                   run_restart_batching)
