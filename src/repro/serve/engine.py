"""Batched serving engine: prefill + decode steps with quantized options.

The serving path is where the paper's memory claims cash out at TPU scale
(DESIGN.md §2): ``weight_quant`` stores all GEMM weights as int8 QTensors
(HBM ÷4 — the 1T-param kimi-k2 fits a 512×16GiB fleet only this way) and
``quantized_kv`` stores the KV cache as int8 on the paper's Qm.n grid
(cache bytes ÷2 vs bf16; the decode-bound cell's dominant roofline term).

Steps are jit-compiled once per shape; the engine drives a fixed-slot batch
(continuous-batching-lite): finished sequences are replaced host-side while
the device tensors keep their static shapes.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.integerize import integerize_weights_only
from repro.core.policy import QuantPolicy
from repro.nn.module import Context

# The sublane tile below which a per-page DMA stops amortizing on real
# hardware: pages shorter than this make paged attention DMA-bound.
HW_MIN_PAGE_SIZE = 128
_small_page_warned = False


def _warn_small_page(page_size: int) -> None:
    """One explicit warning per process when a paged engine is built with a
    sub-sublane page size while kernels dispatch as compiled Pallas — each
    page is a separate DMA, so tiny pages run silently slow on hardware
    (interpret/ref dispatch is unaffected; tests reset the latch via
    ``engine._small_page_warned``)."""
    global _small_page_warned
    if _small_page_warned:
        return
    _small_page_warned = True
    warnings.warn(
        f"paged KV with page_size={page_size} on a hardware Pallas "
        f"backend: every page is a separate DMA and {page_size} rows is "
        f"below the {HW_MIN_PAGE_SIZE}-row sublane tile — attention will "
        f"be DMA-bound; use page_size >= {HW_MIN_PAGE_SIZE} on hardware",
        RuntimeWarning, stacklevel=3)


def _weight_quant_kwargs(spec: Union[bool, str], weight_block: int) -> dict:
    """Map an engine ``weight_quant`` spec to ``integerize_weights_only``
    kwargs.  ``True``/``"int8"`` keep the historical per-channel int8 path;
    ``"int4"``/``"int2"`` pack sub-int8 per-channel; the ``"-block"``
    suffix switches to per-block scales of ``weight_block`` K rows."""
    if spec is True or spec == "int8":
        return {}
    if isinstance(spec, str):
        base, _, tail = spec.partition("-")
        bits = {"int4": 4, "int2": 2}.get(base)
        if bits is not None and tail in ("", "block"):
            return {"bits": bits,
                    "block_size": weight_block if tail == "block" else None}
    raise ValueError(
        f"weight_quant={spec!r}: expected True, 'int8', 'int4[-block]' "
        f"or 'int2[-block]'")


def mask_vocab_tail(logits: jax.Array, vocab: int) -> jax.Array:
    """-inf the padded-vocab tail so it can never be sampled (pad is purely a
    TP-shardability artifact; see models/lm.py)."""
    v_iota = jax.lax.broadcasted_iota(jnp.int32, (logits.shape[-1],), 0)
    return jnp.where(v_iota >= vocab, -jnp.inf, logits)


def sample_tokens(logits: jax.Array, rng, vocab: int,
                  temperature: float) -> jax.Array:
    """(..., V) masked-tail greedy/categorical sample -> (..., 1) int32.

    ``vocab`` outside (0, V) means "no padded tail" (models that don't
    expose a true vocab size): sample over the full logits width.
    """
    if 0 < vocab < logits.shape[-1]:
        logits = mask_vocab_tail(logits, vocab)
    if temperature > 0.0:
        nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
    else:
        nxt = jnp.argmax(logits, axis=-1)
    return nxt[..., None].astype(jnp.int32)


def make_prefill_step(model, *, mesh=None, axis_rules=None,
                      policy: Optional[QuantPolicy] = None) -> Callable:
    """(params, tokens, cache, [embeds/enc]) -> (logits, cache').

    Default: last-position logits (lockstep generate).  ``logit_pos``
    (runtime arg) instead returns (B, 1, V) at that position, slicing the
    hidden states *before* the LM head — admission prefills sample one
    token, so the head (the dominant term at small batch) runs over 1
    position, not S; a slot-targeted prefill over a padded prompt bucket
    passes its true last-token position (scheduler).
    """

    def prefill(params, tokens, cache, embeds=None, enc=None, logit_pos=None):
        ctx = Context(policy=policy or QuantPolicy.float32(), train=False,
                      mesh=mesh, axis_rules=axis_rules)
        kw: Dict[str, Any] = {}
        if enc is not None:
            kw["enc"] = enc
        if embeds is not None:
            kw["embeds"] = embeds
        logits, new_cache = model.apply(params, tokens, ctx, cache=cache,
                                        decode=True, logit_pos=logit_pos,
                                        **kw)
        if logit_pos is not None:
            return logits, new_cache          # (B, 1, V) at logit_pos
        return logits[:, -1], new_cache

    return prefill


def make_decode_step(model, *, mesh=None, axis_rules=None,
                     policy: Optional[QuantPolicy] = None,
                     temperature: float = 0.0,
                     with_health: bool = False) -> Callable:
    """(params, token (B,1), cache, rng, [enc]) -> (next (B,1), cache').

    ``with_health=True`` (the scheduler's audit mode) adds a per-row logit
    health flag and an additive ``poison`` hook: the step becomes
    ``(params, token, cache, rng, enc, poison (B,) f32) ->
    (next, healthy (B,) bool, cache')`` where ``healthy[b]`` is False iff
    row b's last-position logits hold any NaN/Inf.  ``poison`` is added to
    the logits before sampling — all-zeros is an exact no-op, a NaN entry
    is the fault harness's injection seam (serve/faults.py).
    """

    def decode(params, token, cache, rng, enc=None, poison=None):
        ctx = Context(policy=policy or QuantPolicy.float32(), train=False,
                      mesh=mesh, axis_rules=axis_rules)
        kw = {"enc": enc} if enc is not None else {}
        logits, new_cache = model.apply(params, token, ctx, cache=cache,
                                        decode=True, **kw)
        vocab = getattr(model, "vocab", logits.shape[-1])
        row = logits[:, -1]
        if poison is not None:
            row = row + poison[:, None]
        nxt = sample_tokens(row, rng, vocab, temperature)
        if with_health:
            return nxt, jnp.all(jnp.isfinite(row), axis=-1), new_cache
        return nxt, new_cache

    return decode


def make_mixed_step(model, *, mesh=None, axis_rules=None,
                    policy: Optional[QuantPolicy] = None,
                    temperature: float = 0.0,
                    with_health: bool = False,
                    merge: Optional[Callable] = None) -> Callable:
    """Chunked-prefill mixed step: one fused jitted computation that advances
    *all* live decode slots by one token AND prefills one fixed-size prompt
    chunk in place into a target slot's KV slice (nn KVChunk path — no
    batch-1 scratch cache, no ``write_kv_slot`` copy, and because the chunk
    shape is static there is exactly one compile regardless of prompt length).

    (params, tok (B,1), cache, rng, chunk_tok (1,C), slot, start, length)
      -> (next (B,1), first (1,1), cache')

    ``length`` is the chunk's valid token count (< C only on the last,
    padded chunk); ``first`` samples the logits at position length-1 and is
    only meaningful on that last chunk (the prompt's first generated token).
    The decode half runs first, so its per-slot cache append for the
    mid-prefill slot lands exactly on the row the chunk then overwrites —
    the scheduler's masking invariant (junk only at rows >= len) holds.

    ``enc`` (EncDec serving): per-slot encoder outputs ``(B, S_enc, D)``.
    The decode half cross-attends each slot to its own row; the batch-1
    chunk half slices the target slot's row — handing it the full batch
    would shape-mismatch (and silently decode against the wrong context).

    ``with_health=True`` (audit mode): the step gains a trailing ``poison``
    arg (a (B,) f32 vector added to the decode logits — see
    ``make_decode_step``) and returns
    ``(next, first, dec_healthy (B,), first_healthy (1,), cache')``.

    ``merge`` (recurrent-state models): ``merge(old, new, active) -> cache``
    runs BETWEEN the decode half and the chunk half, with the step's
    trailing ``active`` arg ((B,) bool).  KV caches tolerate the decode
    half's masked junk appends (rows >= ``len`` are dead), but a recurrence
    has no position axis — one junk step through an inactive slot corrupts
    its state, so the merge restores every inactive slot's recurrent rows
    to their pre-step values before the chunk half reads/writes the lane
    slot's row (serve/slot_state.py ``merge_inactive``).
    """
    from repro.nn.attention import KVChunk

    decode = make_decode_step(model, mesh=mesh, axis_rules=axis_rules,
                              policy=policy, temperature=temperature,
                              with_health=with_health)

    def mixed(params, tok, cache, rng, chunk_tok, slot, start, length,
              enc=None, poison=None, active=None):
        old = cache
        rng_d, rng_c = jax.random.split(rng)
        if with_health:
            nxt, dec_ok, cache = decode(params, tok, cache, rng_d, enc,
                                        poison)
        else:
            nxt, cache = decode(params, tok, cache, rng_d, enc)
        if merge is not None and active is not None:
            cache = merge(old, cache, active)
        ctx = Context(policy=policy or QuantPolicy.float32(), train=False,
                      mesh=mesh, axis_rules=axis_rules)
        kw = {}
        if enc is not None:
            kw["enc"] = jax.lax.dynamic_index_in_dim(
                enc, jnp.asarray(slot, jnp.int32), axis=0, keepdims=True)
        logits, cache = model.apply(
            params, chunk_tok, ctx, cache=cache, decode=True,
            chunk=KVChunk(slot=slot, start=start, length=length),
            logit_pos=length - 1, **kw)
        vocab = getattr(model, "vocab", logits.shape[-1])
        first = sample_tokens(logits[:, 0], rng_c, vocab, temperature)
        if with_health:
            first_ok = jnp.all(jnp.isfinite(logits[:, 0]), axis=-1)
            return nxt, first, dec_ok, first_ok, cache
        return nxt, first, cache

    return mixed


def make_ragged_step(model, *, mesh=None, axis_rules=None,
                     policy: Optional[QuantPolicy] = None,
                     temperature: float = 0.0,
                     with_health: bool = False) -> Callable:
    """One ragged forward per tick: decode tokens for *all* live slots and
    prefill-chunk tokens from up to L concurrent admission lanes flatten into
    a single (1, T) token batch, T = B + L*C, so every layer runs exactly one
    GEMM per tick no matter how many lanes are active (vs the mixed step's
    two applies and single chunk).

    (params, tok (B,1), cache, rng, chunk_tok (L,C), slot_ids (T,),
     positions (T,), logit_rows (R,), enc=None) -> (next (R,1), cache')

    Per-token addressing replaces the mixed step's scalar chunk metadata:
    token ``t`` is logical row ``positions[t]`` of slot ``slot_ids[t]``;
    rows with position -1 are inert padding (idle decode slots, lane tail
    past the prompt) — they write nothing and their outputs are junk.
    ``logit_rows`` ((R,) int32, R = B + L) picks the rows that sample: row r
    < B is decode slot r's token, row B+l is lane l's last valid chunk token
    (only meaningful on a lane's final chunk).  The LM head runs over R
    rows, not T — the same before-the-head slicing win as ``logit_pos``.

    Every shape is a function of (B, L, C) alone, so the step compiles once
    per scheduler geometry — O(1) compiles over prompt length, lane count
    in use, and arrival pattern.

    ``enc`` (EncDec serving): per-slot encoder outputs (B, S_enc, D); the
    ragged block gathers each token's own slot row (nn/transformer.py).

    ``with_health=True`` (audit mode): the step gains a trailing ``poison``
    arg ((R,) f32 added to the sampled logit rows — rows < B are decode
    slots, row B+l is lane l) and returns
    ``(next (R,1), healthy (R,) bool, cache')``.
    """
    from repro.nn.attention import RaggedBatch

    def ragged_step(params, tok, cache, rng, chunk_tok, slot_ids, positions,
                    logit_rows, enc=None, poison=None):
        ctx = Context(policy=policy or QuantPolicy.float32(), train=False,
                      mesh=mesh, axis_rules=axis_rules)
        flat = jnp.concatenate(
            [tok[:, 0], jnp.reshape(chunk_tok, (-1,))])[None, :]   # (1, T)
        rb = RaggedBatch(slots=jnp.asarray(slot_ids, jnp.int32),
                         positions=jnp.asarray(positions, jnp.int32))
        kw = {"enc": enc} if enc is not None else {}
        logits, new_cache = model.apply(
            params, flat, ctx, cache=cache, decode=True, ragged=rb,
            logit_rows=jnp.asarray(logit_rows, jnp.int32), **kw)
        vocab = getattr(model, "vocab", logits.shape[-1])
        rows = logits[0]                                           # (R, V)
        if poison is not None:
            rows = rows + poison[:, None]
        nxt = sample_tokens(rows, rng, vocab, temperature)         # (R, 1)
        if with_health:
            return nxt, jnp.all(jnp.isfinite(rows), axis=-1), new_cache
        return nxt, new_cache

    return ragged_step


@dataclasses.dataclass
class ServeEngine:
    """Fixed-slot batched generation over a (possibly quantized) model.

    The engine owns the jitted steps and the cache geometry; *batch policy*
    lives elsewhere: ``generate()`` is the legacy lockstep wrapper (every slot
    starts together and runs a fixed horizon — kept as the token-identity
    baseline for tests), ``scheduler()`` hands the same steps to the
    continuous-batching ``Scheduler`` (serve/scheduler.py), which admits
    queued requests into freed slots and evicts on EOS/length per slot.
    """

    model: Any
    params: Any
    max_len: int
    batch_slots: int
    quantized_kv: bool = False
    # Weight format for serving: False = float, True / "int8" = per-channel
    # int8 QTensors, "int4" / "int2" = packed sub-int8 per-channel,
    # "int4-block" / "int2-block" = packed with per-block (MX-style) scales
    # of ``weight_block`` K rows each.
    weight_quant: Union[bool, str] = False
    weight_block: int = 32
    temperature: float = 0.0
    mesh: Any = None
    axis_rules: Any = None
    # -- paged KV cache (serving only; lockstep generate() stays dense) ------
    # paged_kv=True makes new_cache(per_slot=True) a shared page pool + per-
    # slot page tables instead of (slots, max_len) slabs; the Scheduler then
    # block-allocates pages per request (serve/paging.py).  kv_pool_pages is
    # the capacity knob: None = dense parity (slots * ceil(max_len/page_size)
    # pages); smaller pools trade worst-case headroom for more slots at the
    # same bytes — the continuous-batching capacity lever.
    # page_size=None resolves to HW_MIN_PAGE_SIZE under compiled-Pallas
    # dispatch (each page is one DMA on hardware) and to 16 elsewhere;
    # explicit small values are honored but warned about on hardware.
    paged_kv: bool = False
    page_size: Optional[int] = None
    kv_pool_pages: Optional[int] = None
    # -- EncDec cross-attention cache (serving only) -------------------------
    # True (default): per-slot caches carry projected cross-attention K/V
    # rows ("xkv" nodes), written once at admission (EncDecLM.write_cross_kv)
    # instead of re-projecting the encoder output every decode step.  False
    # drops the nodes and recomputes from ``enc`` each tick — the bench
    # baseline the cached path is gated against (benchmarks/serve_bench.py).
    cross_attn_cache: bool = True

    def __post_init__(self):
        from repro.kernels import ops as _kops

        if self.page_size is None:
            self.page_size = (HW_MIN_PAGE_SIZE
                              if self.paged_kv and _kops.is_hardware_dispatch()
                              else 16)
        elif self.paged_kv:
            if self.page_size < 1:
                raise ValueError(
                    f"page_size must be >= 1, got {self.page_size}")
            if self.page_size < HW_MIN_PAGE_SIZE and _kops.is_hardware_dispatch():
                _warn_small_page(self.page_size)
        if self.weight_quant:
            self.params = integerize_weights_only(
                self.params, **_weight_quant_kwargs(self.weight_quant,
                                                    self.weight_block))
        self._prefill = jax.jit(make_prefill_step(
            self.model, mesh=self.mesh, axis_rules=self.axis_rules))
        self._decode = jax.jit(make_decode_step(
            self.model, mesh=self.mesh, axis_rules=self.axis_rules,
            temperature=self.temperature))

    @property
    def vocab(self) -> int:
        """True vocab size for tail masking (0 = no padded tail known)."""
        return getattr(self.model, "vocab",
                       getattr(self.model, "vocab_padded", 0))

    @property
    def kv_max_pages(self) -> int:
        """Page-table width: the per-slot logical length ceiling in pages."""
        return -(-self.max_len // self.page_size)

    @property
    def kv_num_pages(self) -> int:
        """Pool pages actually allocated (kv_pool_pages or dense parity)."""
        if self.kv_pool_pages is not None:
            return self.kv_pool_pages
        return self.batch_slots * self.kv_max_pages

    def new_cache(self, *, per_slot: bool = False, batch: Optional[int] = None):
        """A fresh serving cache tree for this engine's geometry.

        ``per_slot=True`` is the scheduler's cache (per-slot ``len`` vector;
        paged when ``paged_kv``); the default is the lockstep ``generate()``
        slab.  ``batch`` overrides ``batch_slots`` (slot-targeted prefills).
        """
        dt = getattr(self.model, "dtype", jnp.float32)
        kw = {}
        if self.paged_kv and per_slot:
            kw = dict(page_size=self.page_size, num_pages=self.kv_num_pages)
        if hasattr(self.model, "encode"):
            kw["cross_attn_cache"] = self.cross_attn_cache
        return self.model.init_cache(batch or self.batch_slots, self.max_len,
                                     quantized_kv=self.quantized_kv,
                                     kv_dtype=dt, per_slot_len=per_slot, **kw)

    def cache_bytes(self) -> int:
        """Device bytes of one full serving cache (the paper's memory win:
        int8 KV halves/quarters this vs bf16/f32). Shape-only — nothing is
        allocated."""
        shapes = jax.eval_shape(self.new_cache)
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(shapes))

    def scheduler(self, **kwargs):
        """A continuous-batching Scheduler bound to this engine's steps."""
        from repro.serve.scheduler import Scheduler

        return Scheduler(self, **kwargs)

    def generate(self, prompts: jax.Array, max_new_tokens: int,
                 *, seed: int = 0, enc: Optional[jax.Array] = None,
                 ) -> jax.Array:
        """prompts: (batch_slots, S_prompt) int32 → (batch_slots, max_new)."""
        cache = self.new_cache()
        rng = jax.random.PRNGKey(seed)
        last_logits, cache = self._prefill(self.params, prompts, cache,
                                           None, enc)
        rng, sub = jax.random.split(rng)
        tok = sample_tokens(last_logits, sub, self.vocab, self.temperature)
        out = [tok]
        for _ in range(max_new_tokens - 1):
            rng, sub = jax.random.split(rng)
            tok, cache = self._decode(self.params, tok, cache, sub, enc)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
