"""Per-slot decode-state adapters: one slot lifecycle, many state shapes.

The continuous-batching scheduler (serve/scheduler.py) manages *slots* — it
admits a request into a slot, advances it every tick, preempts it, audits it,
and evicts it.  What a slot's device state *is* differs by architecture:

===============  ==========================================================
adapter          per-slot device state
===============  ==========================================================
DenseKVState     a ``max_len`` slice of each layer's (B, S, H, D) K/V slab
                 plus a per-slot ``len`` scalar (``{"k","v","len"}`` nodes)
PagedKVState     a page-table row into a shared K/V pool plus ``len``
                 (``{"k","v","page_table","len"}`` nodes, serve/paging.py)
RecurrentState   a fixed-size recurrence row — Mamba ``{"h","conv"}``,
                 RWKV6 ``{"s","shift"}`` / channel-mix ``{"shift"}`` under
                 block-cache keys ``"ssm"``/``"cm"`` — constant in sequence
                 length (nn/ssm.py)
CrossAttnState   projected encoder K/V rows written once at admission —
                 ``{"xk","xv","xlen"}`` under block-cache key ``"xkv"``
                 (nn/attention.py init_cross_cache)
===============  ==========================================================

The scheduler never branches on architecture: the whole-cache-tree operations
below (``evict_cache_slot``, ``admit_cache_slot``, ``merge_inactive`` …) walk
the cache once and dispatch per node kind, so a hybrid model (jamba:
attention + mamba layers) gets every lifecycle event applied to every kind of
state it carries.  All operations are jit-friendly pure functions over the
cache pytree and ride the scheduler's existing donation paths — applying one
never changes the tree's structure, only leaf values.

Lifecycle contract (what each adapter must support):

* ``init_state`` — build the per-slot nodes (``model.init_cache`` with
  ``per_slot_len=True``; the adapters only *describe* the nodes).
* ``admit_write`` — install a prefilled batch-1 state into a slot (one-shot
  admission) or accept in-place chunk writes (chunked admission).
* ``evict`` — O(1) slot teardown: the slot's state becomes inert (KV ``len``
  and cross ``xlen`` to 0; recurrent rows zeroed) without touching other
  slots.
* ``preempt_pack`` / ``resume_unpack`` — park/restore state across a
  preemption.  Paged KV swaps page contents host-side; recurrent and dense
  states only support recompute preemption (re-prefill the continuation).
* ``audit_check`` — host-side invariants over the device state
  (serve/audit.py hosts the checkers; the per-tick auditor calls them).
* ``bytes_per_slot`` — the state's per-slot device footprint, the
  quality-vs-memory number serve_bench reports (recurrent state is constant
  in sequence length; KV grows linearly).

The scan-stacked layer axis (nn/transformer.py ``Stack``) is handled here,
outside the model: stacked leaves carry a leading layer dim, detected per
node (``len``/``xlen`` rank for KV/cross, leaf rank vs ``REC_BASE_RANK`` for
recurrent rows).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.attention import (copy_kv_page, gather_pool_pages,
                                reset_kv_slot, scatter_pool_pages,
                                set_kv_slot_len, set_page_entry, set_page_row,
                                write_kv_slot)

#: Unstacked rank of each recurrent-state leaf (nn/ssm.py ``init_state``):
#: ``h`` (B, d_inner, N), ``conv`` (B, K-1, d_inner), ``s`` (B, H, N, N),
#: ``shift`` (B, 1, D).  A leaf one rank higher carries the scan-stacked
#: layer axis in front and its slot axis is axis 1.
REC_BASE_RANK: Dict[str, int] = {"h": 3, "conv": 3, "s": 4, "shift": 3}


# --------------------------------------------------------------------------
# Node predicates
# --------------------------------------------------------------------------

def _is_kv(node) -> bool:
    return isinstance(node, dict) and "k" in node and "len" in node


def _is_xkv(node) -> bool:
    return isinstance(node, dict) and "xk" in node and "xlen" in node


def _is_recurrent(node) -> bool:
    if not isinstance(node, dict) or not node:
        return False
    return set(node) <= set(REC_BASE_RANK)


def _rec_slot_axis(key: str, leaf) -> int:
    """Slot axis of one recurrent leaf: 1 under a scan-stacked layer dim."""
    return 1 if jnp.ndim(leaf) == REC_BASE_RANK[key] + 1 else 0


def _find_paged_kv(cache):
    """First per-layer KV dict carrying a page table, or None (dense cache).

    Every layer shares one logical page assignment (the allocator hands out
    pool indices per request, not per layer), so auditing a single layer's
    table/lens audits them all."""
    found: List[Any] = []

    def rec(node):
        if found:
            return
        if _is_kv(node):
            if "page_table" in node:
                found.append(node)
            return
        if isinstance(node, dict):
            for v in node.values():
                rec(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v)

    rec(cache)
    return found[0] if found else None


def find_recurrent_nodes(cache) -> List[Dict[str, Any]]:
    """Every recurrent-state dict in a cache tree, in traversal order."""
    out: List[Dict[str, Any]] = []

    def rec(node):
        if _is_kv(node) or _is_xkv(node):
            return
        if _is_recurrent(node):
            out.append(node)
            return
        if isinstance(node, dict):
            for v in node.values():
                rec(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v)

    rec(cache)
    return out


def find_cross_nodes(cache) -> List[Dict[str, Any]]:
    """Every cross-attention ``xkv`` dict in a cache tree, traversal order."""
    out: List[Dict[str, Any]] = []

    def rec(node):
        if _is_kv(node):
            return
        if _is_xkv(node):
            out.append(node)
            return
        if isinstance(node, dict):
            for v in node.values():
                rec(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v)

    rec(cache)
    return out


# --------------------------------------------------------------------------
# Whole-cache-tree walkers (per-layer primitives live in nn/attention.py
# and nn/ssm.py; these apply one lifecycle event across every state node)
# --------------------------------------------------------------------------

def _map_slot_op(cache, fn, rec_fn=None, xkv_fn=None):
    """Apply ``fn(kv_dict, layer_axis)`` to every per-layer KV dict in a
    Stack cache tree ({'prelude': [...], 'body': [...]}, scan-stacked leaves
    carry a leading layer dim).  ``rec_fn(state_dict)`` / ``xkv_fn(node)``
    extend the walk to recurrent and cross-attention nodes (None leaves
    them untouched — the pre-adapter behavior)."""
    def rec(node):
        if _is_kv(node):
            return fn(node, jnp.ndim(node["len"]) == 2)
        if _is_xkv(node):
            return xkv_fn(node) if xkv_fn is not None else node
        if _is_recurrent(node):
            return rec_fn(node) if rec_fn is not None else node
        if isinstance(node, dict):
            return {k: rec(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rec(v) for v in node)
        return node
    return rec(cache)


def _map_slot_op2(big, small, fn, rec_fn=None):
    """Same walk over two structurally identical cache trees."""
    def rec(b, s):
        if _is_kv(b):
            return fn(b, s, jnp.ndim(b["len"]) == 2)
        if _is_xkv(b):
            return b
        if _is_recurrent(b):
            return rec_fn(b, s) if rec_fn is not None else b
        if isinstance(b, dict):
            return {k: rec(v, s[k]) for k, v in b.items()}
        if isinstance(b, (list, tuple)):
            return type(b)(rec(bb, ss) for bb, ss in zip(b, s))
        return b
    return rec(big, small)


def _zero_recurrent_slot(state: Dict[str, Any], slot) -> Dict[str, Any]:
    """Zero one slot's row in every leaf of a recurrent-state dict.

    A zeroed row is the adapter's *inert* state: admission starts every
    recurrence from zeros (nn/ssm.py ``init_state``), so an evicted slot is
    indistinguishable from a never-used one — the auditor's dead-slot
    invariant (serve/audit.py ``check_recurrent_rows``).
    """
    out: Dict[str, Any] = {}
    for k, v in state.items():
        if v is None:
            out[k] = v
            continue
        ax = _rec_slot_axis(k, v)
        shape = list(v.shape)
        shape[ax] = 1
        out[k] = jax.lax.dynamic_update_slice_in_dim(
            v, jnp.zeros(shape, v.dtype), slot, axis=ax)
    return out


def _scatter_recurrent_slot(big: Dict[str, Any], small: Dict[str, Any],
                            slot) -> Dict[str, Any]:
    """Write a batch-1 recurrent state into ``slot`` of the per-slot state
    (the one-shot admission copy; chunked admission writes in place via the
    mixers' ``chunk`` path instead)."""
    out: Dict[str, Any] = {}
    for k, v in big.items():
        if v is None:
            out[k] = v
            continue
        ax = _rec_slot_axis(k, v)
        out[k] = jax.lax.dynamic_update_slice_in_dim(
            v, small[k].astype(v.dtype), slot, axis=ax)
    return out


def _reset_xkv_slot(node: Dict[str, Any], slot) -> Dict[str, Any]:
    """Evict one slot of a cross-attention cache: ``xlen[slot] = 0``.

    The projected ``xk``/``xv`` rows are left for overwrite (consumers mask
    on ``xlen``, exactly like KV ``len``) — eviction stays O(1)."""
    xl = node["xlen"]
    if jnp.ndim(xl) == 2:     # scan-stacked (L, slots)
        upd = jnp.zeros((xl.shape[0], 1), jnp.int32)
        xl = jax.lax.dynamic_update_slice(xl, upd, (jnp.int32(0), slot))
    else:
        xl = jax.lax.dynamic_update_slice(
            xl, jnp.zeros((1,), jnp.int32), (slot,))
    return dict(node, xlen=xl)


def admit_cache_slot(big_cache, small_cache, slot, length):
    """Write a batch-1 prefilled cache into ``slot`` of the per-slot cache.

    KV nodes block-copy ``length`` rows (``write_kv_slot``); recurrent nodes
    scatter the batch-1 state row (the whole recurrence fits one row, so
    ``length`` does not apply); cross-attention nodes pass through (EncDec
    one-shot admission is rejected at Scheduler construction).
    """
    return _map_slot_op2(
        big_cache, small_cache,
        lambda b, s, la: write_kv_slot(b, s, slot, length, layer_axis=la),
        rec_fn=lambda b, s: _scatter_recurrent_slot(b, s, slot))


def evict_cache_slot(cache, slot):
    """O(1) per-slot eviction across every state kind.

    KV: live length to zero, rows left for overwrite (paged caches
    additionally unmap the slot's page-table row; the host-side allocator
    reclaims the pages — Scheduler.run's ``finish``).  Recurrent: the slot's
    state rows are zeroed (a fresh admission must start its recurrence from
    zeros — there is no ``len`` mask to hide stale rows behind).
    Cross-attention: ``xlen`` to zero.
    """
    return _map_slot_op(
        cache, lambda kv, la: reset_kv_slot(kv, slot, layer_axis=la),
        rec_fn=lambda st: _zero_recurrent_slot(st, slot),
        xkv_fn=lambda node: _reset_xkv_slot(node, slot))


def merge_inactive(old_cache, new_cache, active):
    """Keep inactive slots' recurrent rows at their pre-step values.

    KV state tolerates batched steps running *every* row (junk appends land
    at rows >= ``len`` and are overwritten on admission), but a recurrence
    has no position axis to hide behind: one masked decode step through a
    dead or mid-prefill slot advances its state with a pad token and
    corrupts it.  This merge — ``where(active, stepped, previous)`` per slot
    row — restores every inactive row after the batched step, making the
    recurrent adapter's lifecycle identical to KV's.  KV and cross nodes
    pass through unchanged (structure preservation under donation).
    """
    act = jnp.asarray(active)

    def merge_rec(o: Dict[str, Any], n: Dict[str, Any]) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for k, v in n.items():
            if v is None:
                out[k] = v
                continue
            ax = _rec_slot_axis(k, v)
            shape = [1] * v.ndim
            shape[ax] = v.shape[ax]
            out[k] = jnp.where(act.reshape(shape), v, o[k])
        return out

    def rec(o, n):
        if _is_kv(n) or _is_xkv(n):
            return n
        if _is_recurrent(n):
            return merge_rec(o, n)
        if isinstance(n, dict):
            return {k: rec(o[k], v) for k, v in n.items()}
        if isinstance(n, (list, tuple)):
            return type(n)(rec(oo, nn) for oo, nn in zip(o, n))
        return n
    return rec(old_cache, new_cache)


def set_cache_page_row(cache, slot, row):
    """Install a page-table row for ``slot`` in every layer of a paged cache
    tree (all layers share one logical page assignment — the allocator hands
    out pool indices once per request, not per layer)."""
    return _map_slot_op(
        cache, lambda kv, la: set_page_row(kv, slot, row, layer_axis=la))


def copy_cache_page(cache, src, dst):
    """Copy pool page ``src`` onto ``dst`` in every layer of a paged cache
    tree — the device half of copy-on-write (the host half is the refcount
    bookkeeping in serve/paging.py)."""
    return _map_slot_op(
        cache, lambda kv, la: copy_kv_page(kv, src, dst, layer_axis=la))


def set_cache_page_entry(cache, slot, idx, page):
    """``page_table[slot, idx] = page`` in every layer of a paged cache tree
    — the lazy decode-growth append (oversubscription)."""
    return _map_slot_op(
        cache, lambda kv, la: set_page_entry(kv, slot, idx, page,
                                             layer_axis=la))


def gather_cache_pages(cache, pages):
    """Swap-out gather: read pool pages ``pages`` out of every layer's K/V
    pools.  Returns a list of ``{"k", "v"}`` page stacks in the cache tree's
    deterministic traversal order (``scatter_cache_pages`` consumes the same
    order) — the cache itself is not modified."""
    out = []

    def op(kv, la):
        out.append(gather_pool_pages(kv, pages, layer_axis=la))
        return kv

    _map_slot_op(cache, op)
    return out


def scatter_cache_pages(cache, pages, data):
    """Swap-in restore: write ``gather_cache_pages`` data back into pool
    pages ``pages`` of every layer (same traversal order)."""
    it = iter(data)
    return _map_slot_op(
        cache, lambda kv, la: scatter_pool_pages(kv, pages, next(it),
                                                 layer_axis=la))


def set_cache_slot_len(cache, slot, length):
    """Set ``len[slot] = length`` in every layer of a per-slot cache tree.

    Prefix-sharing admission starts a slot at its shared-prefix length so
    the decode half's per-tick junk append for the still-prefilling slot
    lands in the slot's private divergence region — at len 0 it would write
    through the shared prefix mapping (see Scheduler admission).
    """
    def op(kv, la):
        ln = kv["len"]
        if la:
            upd = jnp.full((ln.shape[0], 1), length, jnp.int32)
            ln = jax.lax.dynamic_update_slice_in_dim(ln, upd, slot, axis=1)
        else:
            ln = set_kv_slot_len(ln, slot, length)
        return dict(kv, len=ln)

    return _map_slot_op(cache, op)


# --------------------------------------------------------------------------
# State-kind discovery and per-kind byte accounting
# --------------------------------------------------------------------------

def _model_blocks(model) -> List[Any]:
    """Every decode-path Block of a model (CausalLM stack / EncDec decoder)."""
    stacks = []
    if hasattr(model, "stack"):
        stacks.append(model.stack)
    if hasattr(model, "decoder"):
        stacks.append(model.decoder)
    blocks: List[Any] = []
    for st in stacks:
        blocks.extend(st.prelude)
        blocks.extend(st.body)
    return blocks


def state_kinds(model) -> Tuple[str, ...]:
    """The per-slot state kinds a model serves with, in canonical order.

    ``"kv"`` — attention mixers (dense or paged self-attention K/V);
    ``"recurrent"`` — Mamba/RWKV mixers (fixed-size recurrence rows);
    ``"cross"`` — an EncDec decoder with a sized cross-attention cache
    (``enc_len`` set).  A hybrid (jamba) reports ``("kv", "recurrent")``.
    """
    blocks = _model_blocks(model)
    kinds: List[str] = []
    if any(b.mixer == "attn" for b in blocks):
        kinds.append("kv")
    if any(b.mixer in ("mamba", "rwkv") for b in blocks):
        kinds.append("recurrent")
    if hasattr(model, "encode") and getattr(model, "enc_len", None) \
            and any(getattr(b, "cross", False) for b in blocks):
        kinds.append("cross")
    return tuple(kinds)


def _bytes_where(cache, pred) -> int:
    """Total leaf bytes of the cache-tree nodes matching ``pred`` (runs on
    concrete arrays or ``jax.eval_shape`` structs alike)."""
    total = 0

    def rec(node):
        nonlocal total
        if pred(node):
            total += sum(l.size * l.dtype.itemsize
                         for l in jax.tree_util.tree_leaves(node))
            return
        if _is_kv(node) or _is_xkv(node) or _is_recurrent(node):
            return
        if isinstance(node, dict):
            for v in node.values():
                rec(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                rec(v)

    rec(cache)
    return total


def state_bytes_per_slot(cache, slots: int) -> Dict[str, int]:
    """Per-slot device bytes of each state kind present in ``cache``.

    The serving-memory comparison serve_bench's hetero bench reports:
    recurrent rows are constant in sequence length while KV slabs grow with
    ``max_len`` (paged pools amortize across slots — the pool's share is
    reported per slot).  ``cache`` may be a ``jax.eval_shape`` tree.
    """
    n = max(slots, 1)
    return {"kv": _bytes_where(cache, _is_kv) // n,
            "recurrent": _bytes_where(cache, _is_recurrent) // n,
            "cross": _bytes_where(cache, _is_xkv) // n}


# --------------------------------------------------------------------------
# Adapters: the documented per-kind lifecycle contract
# --------------------------------------------------------------------------

class SlotState:
    """Abstract per-slot state adapter: one state shape, full lifecycle.

    Concrete adapters bundle the walker operations above per state kind.
    The scheduler itself calls the *composite* walkers (one tree walk per
    lifecycle event handles every kind at once); the adapters are the
    contract surface — what tests pin down, what the auditor checks, and
    what docs/serving.md documents per architecture.
    """

    kind: str = "abstract"

    def evict(self, cache, slot):
        """Make ``slot`` inert without touching other slots (O(1))."""
        return evict_cache_slot(cache, slot)

    def admit_write(self, big_cache, small_cache, slot, length):
        """Install a batch-1 prefilled state into ``slot``."""
        return admit_cache_slot(big_cache, small_cache, slot, length)

    def preempt_pack(self, cache, pages):
        """Read the parkable device state out (swap preemption), or raise."""
        raise NotImplementedError(
            f"{self.kind} state does not support swap parking — use "
            f"recompute preemption (the continuation re-prefills)")

    def resume_unpack(self, cache, pages, data):
        """Restore ``preempt_pack`` data into the cache."""
        raise NotImplementedError(
            f"{self.kind} state does not support swap parking — use "
            f"recompute preemption (the continuation re-prefills)")

    def audit_check(self, cache, live: Dict[int, int]) -> None:
        """Assert this kind's device invariants (serve/audit.py checkers)."""

    def bytes_per_slot(self, cache, slots: int) -> int:
        """Per-slot device bytes of this kind's state in ``cache``."""
        return state_bytes_per_slot(cache, slots).get(
            self.kind.split("-")[0], 0)


class DenseKVState(SlotState):
    """Dense per-slot K/V slabs with a per-slot ``len`` vector."""

    kind = "kv"


class PagedKVState(DenseKVState):
    """Paged K/V: shared pool + per-slot page tables (serve/paging.py).

    The only adapter with a swap path: private page contents gather/scatter
    host-side while shared prefix pages stay resident under refcount.
    """

    kind = "kv-paged"

    def preempt_pack(self, cache, pages):
        """Gather pool pages ``pages`` (swap-out; cache unmodified)."""
        return gather_cache_pages(cache, pages)

    def resume_unpack(self, cache, pages, data):
        """Scatter swapped page data back into pool pages ``pages``."""
        return scatter_cache_pages(cache, pages, data)

    def audit_check(self, cache, live: Dict[int, int]) -> None:
        """Page-table invariants run via serve/audit.py check_page_tables
        (the scheduler wires allocator state in; nothing extra here)."""


class RecurrentState(SlotState):
    """Fixed-size recurrence rows (Mamba/RWKV): constant bytes per slot.

    Admission writes the whole row (one-shot scatter or in-place chunk
    scatter via the mixers' ``chunk`` path); eviction zeroes it; batched
    steps must run under ``merge_inactive`` so masked slots never advance.
    Preemption is recompute-only — the row is tiny but *sufficient*, so
    re-prefilling the continuation is cheaper than a swap protocol.
    """

    kind = "recurrent"

    def audit_check(self, cache, live: Dict[int, int]) -> None:
        """Dead slots' rows must be exactly zero (inert)."""
        from repro.serve.audit import check_recurrent_rows

        check_recurrent_rows(cache, set(live))


class CrossAttnState(SlotState):
    """Per-slot projected cross-attention K/V (EncDec serving).

    Written once per admission (``EncDecLM.write_cross_kv``) and read every
    decode step — the FLOPs trade that replaces re-projecting ``enc`` each
    tick.  Eviction zeroes ``xlen``; rows are overwritten on readmission.
    """

    kind = "cross"

    def audit_check(self, cache, live: Dict[int, int]) -> None:
        """Live slots' ``xlen`` must equal their encoder length; dead 0."""
        from repro.serve.audit import check_cross_lens

        check_cross_lens(cache, live)


def adapters_for(model, *, paged: bool = False,
                 cross_attn_cache: bool = True) -> Tuple[SlotState, ...]:
    """The adapter set a scheduler composes for ``model``.

    ``paged`` picks :class:`PagedKVState` over :class:`DenseKVState` for the
    ``"kv"`` kind; ``cross_attn_cache=False`` drops :class:`CrossAttnState`
    (the engine recomputes cross-attention from ``enc`` every step — the
    bench baseline).
    """
    out: List[SlotState] = []
    for kind in state_kinds(model):
        if kind == "kv":
            out.append(PagedKVState() if paged else DenseKVState())
        elif kind == "recurrent":
            out.append(RecurrentState())
        elif kind == "cross" and cross_attn_cache:
            out.append(CrossAttnState())
    return tuple(out)
