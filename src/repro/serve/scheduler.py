"""Continuous-batching scheduler: per-slot decode-state lifecycle over
jitted steps.

The lockstep ``ServeEngine.generate()`` runs every slot for a fixed horizon —
fine for tests, hopeless under traffic: a slot that finishes early idles until
the whole batch restarts.  This module adds the real serving policy on top of
the same jitted prefill/decode steps:

* a **request queue** (prompt, max_new, arrival order);
* **per-slot state** (live length, active flag, EOS hit) — the cache carries
  an int32 ``len`` *vector* (``per_slot_len=True``), so every slot advances
  and masks independently (nn/attention.py, kernels/qdecode_attn.py);
* **admission**, two policies:

  - *one-shot* (``chunk_size=None``): a freed slot is refilled by a
    slot-targeted prefill — the prompt runs through a fresh batch-1 cache,
    then ``write_kv_slot`` copies that cache into the slot's KV slice.  The
    prefill is a stop-the-world dispatch: every live decode slot stalls for
    the full prompt length, and each distinct (bucketed) prompt length costs
    a jit compile.
  - *chunked* (``chunk_size=C``): each tick runs ONE fused jitted mixed step
    (``engine.make_mixed_step``) = all live decode slots plus one C-token
    chunk of the oldest queued prompt, written **in place** into the target
    slot's KV slice (``append_kv_chunk`` / the fused ``qchunk_attn`` Pallas
    kernel for int8 caches).  No batch-1 scratch cache, no copy, one compile
    shape for every prompt length, and decode slots never stall more than
    one chunk — the admission-tail-latency fix.  ``token_budget`` caps the
    per-tick token count (live slots + C): when live decode alone exceeds
    it, the chunk waits (decode tokens are never dropped);

* **paged KV** (``ServeEngine(paged_kv=True)``): the per-slot cache becomes
  a shared page pool + per-slot page tables (nn/attention.py), and the
  scheduler runs a host-side block allocator (serve/paging.py): admission
  allocates ``ceil(extent / page_size)`` pages and installs the slot's
  page-table row; page exhaustion *defers* the admission in the queue
  (composing with the ``token_budget`` stall, decode never waits); eviction
  returns the pages.  Requires chunked admission — docs/serving.md has the
  full geometry;
* **prefix sharing** (paged, default on): an admission whose prompt prefix
  matches resident pages (serve/paging.py ``PrefixIndex``) maps them into
  its own table (refcounted), prefills only from the divergence point, and
  privatizes a shared divergence page by copy-on-write before any write —
  N same-system-prompt requests hold one copy of the prefix, the
  per-pool-byte capacity win serve_bench gates;
* **oversubscription** (``oversubscribe=True``, paged only): admission
  reserves only the prompt-covering pages instead of the full
  ``prompt+max_new`` extent; decode *grows* each slot's page-table row one
  page at a time as its live length crosses page boundaries (the
  ``set_page_entry`` jitted update).  When growth finds the pool empty the
  scheduler **preempts** a victim — least decode progress first, most
  recent admission breaking ties, with an aging bound so no request is
  starved by repeated eviction.  ``preempt_policy="recompute"`` harvests
  the victim's generated tokens and re-queues it as a continuation prompt
  (prompt + generated so far) re-prefilled through the chunked path;
  ``"swap"`` copies its *private* pages to a host-side ``SwapArea``
  (shared prefix pages stay resident under their refcount) and restores
  them as soon as a slot and pages free up.  Both policies keep greedy
  decode token-identical to the unpreempted run;
* **EncDec serving** (chunked only): each request carries its encoder
  output (``Request.enc``); the scheduler keeps a per-slot encoder buffer
  and threads it through the jitted decode/mixed steps, so every slot
  cross-attends its own context.  With ``engine.cross_attn_cache`` (the
  default) admission additionally projects the request's cross-attention
  K/V once into the slot's ``xkv`` rows (``EncDecLM.write_cross_kv``), so
  decode steps skip the per-tick re-projection entirely;
* **recurrent-state serving** (SSM/RWKV, chunked or one-shot): models whose
  layers carry fixed-size recurrence rows instead of (or alongside) KV
  serve through the same loop — the slot lifecycle is dispatched per state
  *kind* by the slot-state walkers (serve/slot_state.py), and batched steps
  run under an inactive-merge barrier so masked slots never advance their
  recurrence (a junk token through a dead KV row is masked by ``len``; a
  junk token through a recurrence corrupts it);
* **termination**: per-slot EOS/length checks; finished slots are evicted
  with an O(1) ``reset_kv_slot`` and emit pad tokens under a sampling mask
  until readmission;
* a **stats tracker**: steady tok/s (compile excluded via ``warmup()``),
  p50/p99 per-request latency in decode steps (and in wall milliseconds
  under ``run(time_ticks=True)``), mean slot occupancy, jit-compile,
  admission-stall and page-allocator counters.

The jitted steps donate their cache (and, outside async-harvest mode, their
token) arguments, so per-tick cache updates are true in-place buffer reuse
at the XLA level rather than a whole-cache copy per tick.

Works for float *and* int8-quantized KV caches — the paper's memory win
(cache bytes ÷2 vs bf16, ÷4 vs f32) exercised under realistic traffic.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import QuantPolicy
from repro.nn.module import Context
from repro.serve.admission import (AdmissionPlanner, Preempted, PrefillLane,
                                   pick_preemption_victim)
from repro.serve.audit import (check_allocator, check_cross_lens,
                               check_page_tables, check_recurrent_rows,
                               check_swap)
from repro.serve.engine import (make_decode_step, make_mixed_step,
                                make_prefill_step, make_ragged_step,
                                sample_tokens)
from repro.serve.faults import FaultPlan
from repro.serve.lanes import assemble_ragged_tick
from repro.serve.paging import (PageAllocator, PrefixIndex, SwapArea,
                                _tree_bytes)
from repro.serve.slot_state import (  # noqa: F401  (re-exported compat names)
    _find_paged_kv, _is_kv, _map_slot_op, _map_slot_op2, admit_cache_slot,
    copy_cache_page, evict_cache_slot, gather_cache_pages, merge_inactive,
    scatter_cache_pages, set_cache_page_entry, set_cache_page_row,
    set_cache_slot_len, state_kinds)

# Back-compat aliases: these used to be defined in this module.
_Prefill = PrefillLane
_Preempted = Preempted


# --------------------------------------------------------------------------
# Requests and results
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is the decode-step tick at which
    the request becomes visible to the scheduler (0 = available at start).
    ``deadline_steps`` (optional) is a per-request latency bound in the
    same virtual clock: a request still unfinished ``deadline_steps`` ticks
    after arrival is evicted (or dropped from the queue/parked set) and
    returned with ``status="timeout"`` — tokens emitted so far included."""

    rid: int
    prompt: Any                 # (P,) int32 token ids (list / np / jnp)
    max_new: int
    arrival: int = 0
    enc: Any = None             # EncDec serving: this request's encoder
    #                             output (S_enc, D) or (1, S_enc, D); None
    #                             for decoder-only models
    deadline_steps: Optional[int] = None


#: Terminal request statuses: ``ok`` (ran to EOS/length), ``timeout``
#: (deadline_steps expired), ``cancelled`` (host-side cancel), ``rejected``
#: (bounded-queue backpressure), ``failed`` (unservable deadlock or a
#: NaN/Inf-poisoned slot evicted by the audit sentinel).
STATUSES = ("ok", "timeout", "cancelled", "rejected", "failed")


@dataclasses.dataclass
class RequestResult:
    """Everything the scheduler knows about one *terminal* request: the
    generated ids, the (arrival, admitted, finished) tick timeline the
    latency percentiles are computed from, and how it ended (``status``).

    Every request passed to ``run()`` gets exactly one result — degraded
    outcomes (timeout/cancelled/rejected/failed) carry whatever tokens were
    emitted before termination instead of vanishing into an exception.
    ``admitted_at`` is -1 for requests that never reached a slot.
    """

    rid: int
    tokens: List[int]           # generated ids (includes EOS if hit)
    prompt_len: int
    arrival: int
    admitted_at: int            # tick the slot-targeted prefill ran
    finished_at: int            # tick the last token was emitted
    eos: bool                   # True: stopped on EOS, False: length limit
    status: str = "ok"          # one of STATUSES

    @property
    def latency_steps(self) -> int:
        """Queueing + service time in decode-step ticks."""
        return self.finished_at - self.arrival


@dataclasses.dataclass
class ServeStats:
    """Aggregates the run; ``summary()`` is what serve_bench.py persists."""

    compile_s: float = 0.0      # warmup (jit compile) wall time, reported apart
    steady_s: float = 0.0       # post-warmup serving loop wall time
    decode_steps: int = 0
    tokens_out: int = 0
    occupancy_sum: float = 0.0
    latencies_steps: List[int] = dataclasses.field(default_factory=list)
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    peak_cache_bytes: int = 0
    num_jit_compiles: int = 0   # compiled entries across the run's jitted steps
    prefill_chunks: int = 0     # chunked admission: mixed steps that carried a chunk
    stalled_chunks: int = 0     # chunked admission: ticks the pending chunk sat
    #                             out under token_budget (stall *duration*, not
    #                             a count of distinct deferred chunks)
    admission_stalls: int = 0   # one-shot admission: stop-the-world prefills
    #                             dispatched while >= 1 other slot was live
    page_stalls: int = 0        # paged KV: ticks the head-of-queue request
    #                             sat deferred because the allocator could not
    #                             serve its full page extent
    prefix_hits: int = 0        # prefix sharing: admissions that mapped >= 1
    #                             resident page instead of allocating it
    shared_pages_mapped: int = 0  # prefix sharing: total page mappings served
    #                             from the index (pool pages NOT allocated)
    cow_copies: int = 0         # prefix sharing: divergence pages privatized
    #                             by copy-on-write before their first write
    peak_pages_in_use: int = 0  # paged KV: allocator high-water mark
    peak_live_slots: int = 0    # max concurrent requests resident (live
    #                             decode slots + a mid-prefill reservation) —
    #                             the effective-capacity metric serve_bench
    #                             compares paged vs dense on
    page_util_sum: float = 0.0  # paged KV: per-tick live tokens / resident
    page_util_ticks: int = 0    # pool tokens (internal-fragmentation gauge)
    grown_pages: int = 0        # oversubscription: decode pages allocated
    #                             lazily, one per page-boundary crossing
    preemptions: int = 0        # oversubscription: slots evicted mid-decode
    #                             because growth/admission found the pool dry
    resumes: int = 0            # swap policy: preempted requests restored
    swapped_pages: int = 0      # swap policy: private pages copied to host
    swap_peak_bytes: int = 0    # swap policy: SwapArea high-water mark
    resume_stalls: int = 0      # swap policy: ticks the oldest preempted
    #                             request waited for a free slot + pages
    truncations: int = 0        # oversize="truncate": requests whose max_new
    #                             was clamped to the page-table width
    preempted_rids: Dict[int, int] = dataclasses.field(default_factory=dict)
    #                             rid -> times preempted (aging-bound audit)
    truncated_rids: Dict[int, int] = dataclasses.field(default_factory=dict)
    #                             rid -> granted max_new (per-request warning
    #                             record for oversize="truncate")
    ttft_steps: List[int] = dataclasses.field(default_factory=list)
    #                             per request: first-admission tick - arrival
    #                             (first leg only — a preempted request's
    #                             first token was already served)
    completed: int = 0          # requests that ended status="ok"
    rejections: int = 0         # bounded-queue backpressure: requests shed
    #                             (reject_policy) with status="rejected"
    timeouts: int = 0           # deadline_steps expiries (status="timeout")
    cancellations: int = 0      # host-side cancels (status="cancelled")
    failed: int = 0             # status="failed": deadlock conversions +
    #                             NaN-sentinel evictions
    deadlock_failures: int = 0  # failed subset: idle-branch unservable
    #                             requests (previously a RuntimeError)
    nan_evictions: int = 0      # failed subset: slots evicted by the
    #                             NaN/Inf logit sentinel (audit mode)
    swap_refusals: int = 0      # swap parks refused (SwapArea capacity or
    #                             an injected fault) -> recompute fallback
    fault_events: int = 0       # injected FaultPlan denials/poisons fired
    audited_ticks: int = 0      # ticks the invariant auditor ran clean
    state_kinds: str = ""       # the served model's slot-state kinds, "+"-
    #                             joined ("kv", "recurrent", "kv+recurrent",
    #                             "kv+cross", ...) — serve/slot_state.py

    @property
    def completion_rate(self) -> float:
        """ok results / all terminal results (1.0 when nothing terminated —
        vacuously complete); the chaos gate's headline number."""
        total = (self.completed + self.rejections + self.timeouts
                 + self.cancellations + self.failed)
        return self.completed / total if total else 1.0

    @property
    def steady_tok_s(self) -> float:
        """Post-warmup tokens per wall second."""
        return self.tokens_out / self.steady_s if self.steady_s > 0 else 0.0

    @property
    def occupancy(self) -> float:
        """Mean fraction of batch slots live per decode step."""
        return self.occupancy_sum / max(self.decode_steps, 1)

    @property
    def page_occupancy(self) -> float:
        """Paged KV: mean live-token fill of the pages held by requests.

        1.0 = every resident pool token is a live K/V row; the gap is
        internal fragmentation (last-page waste + decode headroom reserved
        but not yet generated — oversubscription exists to close the
        latter).  0.0 when the run was not paged.  Sharing-aware: a pool
        page mapped by several slots counts once, filled to the *deepest*
        live row over its mappers, so the gauge stays a meaningful 0..1
        signal under prefix sharing (it used to double-count shared rows
        and read past 1.0).
        """
        return self.page_util_sum / max(self.page_util_ticks, 1)

    def summary(self) -> Dict[str, Any]:
        """The dict serve_bench.py persists (rates, percentiles, counters)."""
        lat = np.asarray(self.latencies_steps or [0])
        lat_ms = np.asarray(self.latencies_s or [0.0]) * 1e3
        return {
            "steady_tok_s": round(self.steady_tok_s, 2),
            "compile_s": round(self.compile_s, 3),
            "steady_s": round(self.steady_s, 4),
            "decode_steps": self.decode_steps,
            "tokens_out": self.tokens_out,
            "occupancy": round(self.occupancy, 4),
            "p50_latency_steps": float(np.percentile(lat, 50)),
            "p99_latency_steps": float(np.percentile(lat, 99)),
            "p50_latency_ms": round(float(np.percentile(lat_ms, 50)), 3),
            "p99_latency_ms": round(float(np.percentile(lat_ms, 99)), 3),
            "peak_cache_bytes": self.peak_cache_bytes,
            "num_jit_compiles": self.num_jit_compiles,
            "prefill_chunks": self.prefill_chunks,
            "stalled_chunks": self.stalled_chunks,
            "admission_stalls": self.admission_stalls,
            "page_stalls": self.page_stalls,
            "peak_pages_in_use": self.peak_pages_in_use,
            "peak_live_slots": self.peak_live_slots,
            "page_occupancy": round(self.page_occupancy, 4),
            "prefix_hits": self.prefix_hits,
            "shared_pages_mapped": self.shared_pages_mapped,
            "cow_copies": self.cow_copies,
            "grown_pages": self.grown_pages,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "swapped_pages": self.swapped_pages,
            "swap_peak_bytes": self.swap_peak_bytes,
            "resume_stalls": self.resume_stalls,
            "truncations": self.truncations,
            "p50_ttft_steps": float(np.percentile(
                np.asarray(self.ttft_steps or [0]), 50)),
            "p99_ttft_steps": float(np.percentile(
                np.asarray(self.ttft_steps or [0]), 99)),
            "rejections": self.rejections,
            "timeouts": self.timeouts,
            "cancellations": self.cancellations,
            "failed": self.failed,
            "completion_rate": round(self.completion_rate, 4),
            "deadlock_failures": self.deadlock_failures,
            "nan_evictions": self.nan_evictions,
            "swap_refusals": self.swap_refusals,
            "fault_events": self.fault_events,
            "audited_ticks": self.audited_ticks,
            "state_kinds": self.state_kinds,
        }


@dataclasses.dataclass
class _Slot:
    req: Request
    admitted_at: int
    plen: int = 0                # this leg's prompt length (a recompute
    #                              continuation's includes carried tokens)
    emitted: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)  # sync mode
    first: Any = None            # async mode: (1,1) device first token
    cols: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    # async mode: per emitted decode token, its (slot row, column) in the
    # step matrix — the row is recorded per token because a swap-resumed
    # request may land in a different slot index


# --------------------------------------------------------------------------
# The scheduler.  Slot-state walkers live in serve/slot_state.py; admission
# planning and the preemption policy in serve/admission.py; ragged lane
# assembly in serve/lanes.py.
# --------------------------------------------------------------------------

class Scheduler:
    """Continuous batching over a ``ServeEngine``'s model/params/steps.

    ``eos_id``: generation stops when this id is sampled (None = length-only).
    ``pad_id``: emitted by masked (inactive) slots and used to pad prompts.
    ``prompt_bucket``: round prompt lengths up to a multiple, so distinct
    prompt lengths share jit compilations; the true last-token logits are
    gathered at the unpadded position and the slot's live length is set to
    the true prompt length, so bucket padding never changes semantics.
    ``chunk_size``: switch admission to chunked prefill (the mixed step);
    the chunk grid subsumes prompt bucketing, so ``prompt_bucket`` is
    ignored.  ``token_budget``: per-tick token cap for chunked admission
    (must fit at least one chunk; live decode slots always run).

    Paged engines (``engine.paged_kv``) require chunked admission: the
    one-shot path prefills into a dense batch-1 scratch cache and block-copies
    it, which has no paged analog (and no reason for one — the mixed step
    writes through the page table directly).

    ``prefix_sharing`` (paged only, default on): requests whose prompt
    prefix matches pages already resident map those pages into their own
    table (refcounted in serve/paging.py) and prefill only from the
    divergence point; a shared divergence page is privatized by
    copy-on-write before its first write.  Disable to measure the unshared
    baseline (serve_bench's shared-prefix gate does exactly that).

    ``oversubscribe`` (paged only): admission reserves only the
    prompt-covering (chunk-padded) pages; decode pages are allocated lazily,
    one page per boundary crossing, and pool exhaustion mid-decode preempts
    a victim under ``preempt_policy`` — ``"recompute"`` (re-queue the
    victim as a continuation prompt, re-prefilled through the chunked path)
    or ``"swap"`` (park its private pages host-side in a ``SwapArea`` and
    restore them when pages free up; shared prefix pages stay resident).
    ``preempt_aging`` bounds how often one request may be re-preempted
    before it becomes ineligible (starvation freedom).  Token streams stay
    identical to the unpreempted run under greedy decoding (temperature 0,
    the default); with sampling, preemption re-randomizes the tail of the
    victim's stream (documented, not asserted).

    ``oversize`` controls requests whose ``prompt+max_new`` extent exceeds
    the page-table width (``kv_max_pages * page_size``) or dense
    ``max_len``: ``"reject"`` (default) raises at ``run()``; ``"truncate"``
    clamps ``max_new`` to what the table can hold and records the clamp in
    ``ServeStats.truncated_rids``.  Either way the failure is *loud* — the
    silent page-plan clamp that used to drop KV rows past the table edge
    (decoding garbage attention) is gone.

    EncDec models (anything with an ``encode`` method) serve through the
    chunked path only, with every request carrying its own encoder output
    (``Request.enc``); the scheduler keeps a per-slot ``(slots, S_enc, D)``
    encoder buffer and threads it through the jitted steps — decoding
    without it silently drops the encoder context and emits garbage.

    All jitted steps donate their cache argument — and their token argument
    outside async-harvest mode (no ``eos_id``), where per-step token columns
    must stay alive until the end-of-run harvest — so on backends with
    donation support each tick updates the KV buffers in place instead of
    copying the whole cache through HBM.
    """

    def __init__(self, engine, *, eos_id: Optional[int] = None,
                 pad_id: int = 0, prompt_bucket: Optional[int] = None,
                 chunk_size: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 prefix_sharing: bool = True,
                 oversubscribe: bool = False,
                 preempt_policy: str = "recompute",
                 preempt_aging: int = 2,
                 oversize: str = "reject",
                 ragged: bool = False,
                 prefill_lanes: int = 1,
                 max_queue: Optional[int] = None,
                 reject_policy: str = "reject",
                 swap_bytes: Optional[int] = None,
                 audit: bool = False):
        """Bind the scheduler's jitted steps to ``engine`` (see class doc).

        ``max_queue`` bounds the *arrived-and-waiting* queue (backpressure):
        an arrival past the bound is terminated with ``status="rejected"``
        under ``reject_policy="reject"``, or, under ``"shed_oldest"``, the
        oldest waiting request is shed in its favor (preemption
        continuations are never shed — they hold served tokens).
        ``swap_bytes`` caps the swap policy's host SwapArea; a victim whose
        pages do not fit falls back to recompute preemption
        (``ServeStats.swap_refusals``).  ``audit=True`` runs the invariant
        auditor (serve/audit.py) every tick and arms the NaN/Inf logit
        sentinel: a poisoned slot is evicted as ``failed`` instead of
        streaming garbage — the per-tick health readback costs pipeline
        overlap, so it is opt-in (CI keeps it always-on in the chaos lane).
        """
        self.engine = engine
        self.eos_id = eos_id
        self.pad_id = int(pad_id)
        self.prompt_bucket = prompt_bucket
        self.chunk_size = chunk_size
        self.token_budget = token_budget
        self.paged = bool(getattr(engine, "paged_kv", False))
        self.prefix_sharing = bool(prefix_sharing) and self.paged
        self.oversubscribe = bool(oversubscribe)
        self.preempt_policy = preempt_policy
        self.preempt_aging = int(preempt_aging)
        self.oversize = oversize
        self.ragged = bool(ragged)
        self.prefill_lanes = int(prefill_lanes)
        self.max_queue = max_queue
        self.reject_policy = reject_policy
        self.swap_bytes = swap_bytes
        self.audit = bool(audit)
        self._cancel_box: set = set()
        self.encdec = hasattr(engine.model, "encode")
        # Which per-slot state kinds this model serves with — the slot-state
        # walkers dispatch per cache node, so the loop below never branches
        # on architecture; these flags only gate policy validation, the
        # inactive-merge barrier, and the per-kind audit hooks.
        kinds = list(state_kinds(engine.model))
        if "cross" in kinds and not getattr(engine, "cross_attn_cache", True):
            kinds.remove("cross")   # engine recomputes from enc every step
        self.state_kinds: Tuple[str, ...] = tuple(kinds)
        self._has_recurrent = "recurrent" in kinds
        self._cross_cached = "cross" in kinds
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if reject_policy not in ("reject", "shed_oldest"):
            raise ValueError(
                f"reject_policy must be 'reject' or 'shed_oldest', "
                f"got {reject_policy!r}")
        if swap_bytes is not None and swap_bytes < 0:
            raise ValueError(f"swap_bytes must be >= 0, got {swap_bytes}")
        if self.oversubscribe and not self.paged:
            raise ValueError(
                "oversubscribe=True requires a paged engine "
                "(ServeEngine(paged_kv=True)): lazy decode pages grow a "
                "page table, dense slabs have nothing to grow")
        if preempt_policy not in ("recompute", "swap"):
            raise ValueError(
                f"preempt_policy must be 'recompute' or 'swap', "
                f"got {preempt_policy!r}")
        if self.preempt_aging < 1:
            raise ValueError(
                f"preempt_aging must be >= 1, got {preempt_aging}")
        if oversize not in ("reject", "truncate"):
            raise ValueError(
                f"oversize must be 'reject' or 'truncate', got {oversize!r}")
        if chunk_size is not None and chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        if self.paged and chunk_size is None:
            raise ValueError(
                "paged KV (engine.paged_kv) requires chunked admission: "
                "pass chunk_size=... (one-shot admission block-copies a "
                "dense scratch cache, which has no paged analog)")
        if self.encdec and chunk_size is None:
            raise ValueError(
                "EncDec serving requires chunked admission: pass "
                "chunk_size=... (e.g. Scheduler(engine, chunk_size=32)) — "
                "the one-shot slot prefill block-copies a scratch cache "
                "without the request's encoder output or its cross-attention "
                "K/V, so the slot would decode without encoder context")
        if self._has_recurrent:
            if self.ragged:
                raise ValueError(
                    "ragged=True cannot serve recurrent-state (SSM/RWKV) "
                    "layers: the ragged forward interleaves many slots' "
                    "tokens in one flattened batch, and a recurrence must "
                    "consume its slot's tokens in order — use the mixed "
                    "step (chunk_size=... without ragged)")
            if self.paged and "kv" not in kinds:
                raise ValueError(
                    "paged KV (engine.paged_kv) on a pure recurrent-state "
                    "model: there is no KV cache to page — recurrent state "
                    "is a fixed-size per-slot row (drop paged_kv; its bytes "
                    "do not grow with sequence length)")
            if self.oversubscribe and preempt_policy == "swap":
                raise ValueError(
                    "preempt_policy='swap' cannot serve recurrent-state "
                    "layers: swap parks only KV pool pages, the victim's "
                    "recurrence rows would be zeroed by eviction and "
                    "resume would continue from corrupt state — use "
                    "preempt_policy='recompute' (re-prefill rebuilds the "
                    "recurrence exactly)")
            if prompt_bucket is not None and chunk_size is None:
                raise ValueError(
                    "prompt_bucket cannot serve recurrent-state layers "
                    "under one-shot admission: bucket padding would run "
                    "pad tokens through the recurrence and corrupt the "
                    "admitted state (KV slots mask on len; a recurrence "
                    "cannot) — drop prompt_bucket or use chunk_size=...")
        if token_budget is not None:
            if chunk_size is None:
                raise ValueError("token_budget requires chunked admission "
                                 "(chunk_size=...)")
            if token_budget < chunk_size:
                raise ValueError(
                    f"token_budget {token_budget} < chunk_size {chunk_size}: "
                    f"an idle batch could never admit a chunk")
        if self.ragged and chunk_size is None:
            raise ValueError(
                "ragged=True requires chunked admission (chunk_size=...): "
                "the ragged step's prefill lanes carry fixed-size chunks")
        if self.prefill_lanes < 1:
            raise ValueError(
                f"prefill_lanes must be >= 1, got {prefill_lanes}")
        if self.prefill_lanes > 1 and not self.ragged:
            raise ValueError(
                f"prefill_lanes={prefill_lanes} requires ragged=True: the "
                f"mixed step carries exactly one chunk per tick — only the "
                f"ragged forward flattens several lanes into one batch")

        model = engine.model
        vocab = engine.vocab
        temperature = engine.temperature
        health = self.audit     # audit mode threads per-row logit health
        decode = make_decode_step(
            model, mesh=engine.mesh, axis_rules=engine.axis_rules,
            temperature=temperature, with_health=health)
        pad = jnp.int32(self.pad_id)

        # Recurrent-state models: restore every inactive slot's recurrence
        # rows after the batched step (serve/slot_state.py merge_inactive) —
        # reading the donated input after the step is trace-safe (donation
        # is an aliasing hint, XLA copies where the value is still needed).
        merge = merge_inactive if self._has_recurrent else None

        def masked_decode(params, tok, cache, rng, active, enc=None,
                          poison=None):
            old = cache
            if health:
                nxt, ok, cache = decode(params, tok, cache, rng, enc,
                                        poison)
                if merge is not None:
                    cache = merge(old, cache, active)
                return jnp.where(active[:, None], nxt, pad), ok, cache
            nxt, cache = decode(params, tok, cache, rng, enc)
            if merge is not None:
                cache = merge(old, cache, active)
            return jnp.where(active[:, None], nxt, pad), cache

        def set_tok(tok, first, slot):
            # traced slot index: one compile serves every slot
            return jax.lax.dynamic_update_slice(tok, first, (slot, 0))

        # Donation: cache always; tok only in sync (EOS) mode — async mode
        # retains every step's token column until the end-of-run harvest, so
        # donating tok there would invalidate retained buffers.
        sync = eos_id is not None

        # The module-level tree ops get a per-instance closure before jit:
        # jax keys its compile cache on the underlying callable, so jitting
        # the shared function directly would make num_jit_compiles count
        # every OTHER engine's cache shapes too (the bucket-explosion
        # telltale must be per-scheduler to mean anything).
        def evict(cache, slot):
            return evict_cache_slot(cache, slot)

        self._masked_decode = jax.jit(masked_decode,
                                      donate_argnums=(1, 2) if sync else (2,))
        self._evict = jax.jit(evict, donate_argnums=(0,))
        self._set_tok = jax.jit(set_tok,
                                donate_argnums=(0,) if sync else ())
        self._jits = [self._masked_decode, self._evict, self._set_tok]
        if self.paged:
            def set_pages(cache, slot, row):
                return set_cache_page_row(cache, slot, row)

            def set_len(cache, slot, length):
                return set_cache_slot_len(cache, slot, length)

            def append_page(cache, slot, idx, page):
                return set_cache_page_entry(cache, slot, idx, page)

            self._set_pages = jax.jit(set_pages, donate_argnums=(0,))
            self._set_len = jax.jit(set_len, donate_argnums=(0,))
            self._append_page = jax.jit(append_page, donate_argnums=(0,))
            self._jits += [self._set_pages, self._set_len, self._append_page]
        if self.prefix_sharing:
            def copy_page(cache, src, dst):
                return copy_cache_page(cache, src, dst)

            self._copy_page = jax.jit(copy_page, donate_argnums=(0,))
            self._jits.append(self._copy_page)
        if self.oversubscribe and self.preempt_policy == "swap":
            def gather_pages(cache, pages):
                return gather_cache_pages(cache, pages)

            def scatter_pages(cache, pages, data):
                return scatter_cache_pages(cache, pages, data)

            # gather must NOT donate: the cache stays live (only page
            # contents are read out); scatter donates like every other
            # cache update
            self._gather_pages = jax.jit(gather_pages)
            self._scatter_pages = jax.jit(scatter_pages, donate_argnums=(0,))
            self._jits += [self._gather_pages, self._scatter_pages]
        if self.encdec:
            def set_enc(buf, row, slot):
                return jax.lax.dynamic_update_slice(
                    buf, row.astype(buf.dtype), (slot, jnp.int32(0),
                                                 jnp.int32(0)))

            self._set_enc = jax.jit(set_enc, donate_argnums=(0,))
            self._jits.append(self._set_enc)
        if self._cross_cached:
            # project + install one request's cross-attention K/V rows into
            # its slot, once, at admission/resume (EncDecLM.write_cross_kv)
            def write_xkv(params, cache, row, slot):
                ctx = Context(policy=QuantPolicy.float32(), train=False,
                              mesh=engine.mesh, axis_rules=engine.axis_rules)
                return model.write_cross_kv(params, cache, row, slot, ctx)

            self._write_xkv = jax.jit(write_xkv, donate_argnums=(1,))
            self._jits.append(self._write_xkv)
        # Host-side admission planning (paged sizing, prefix plans, COW) —
        # serve/admission.py; only chunked admission pages/plans anything.
        self._admission = AdmissionPlanner(
            page_size=engine.page_size, max_pages=engine.kv_max_pages,
            chunk_size=chunk_size, oversubscribe=self.oversubscribe) \
            if chunk_size is not None else None

        if chunk_size is None:
            # one-shot admission: batch-1 prefill + write_kv_slot copy
            prefill_full = make_prefill_step(
                model, mesh=engine.mesh, axis_rules=engine.axis_rules)

            def slot_prefill(params, tokens, plen, rng):
                """(1, P) prompt -> (first token (1,1), batch-1 cache).
                The LM head runs over the single true-last position only
                (logit_pos), not the whole padded bucket."""
                cache = model.init_cache(
                    1, engine.max_len, quantized_kv=engine.quantized_kv,
                    kv_dtype=getattr(model, "dtype", jnp.float32))
                logits, cache = prefill_full(params, tokens, cache,
                                             logit_pos=plen - 1)
                return sample_tokens(logits[:, 0], rng, vocab,
                                     temperature), cache

            def admit(big, small, slot, length):
                return admit_cache_slot(big, small, slot, length)

            self._slot_prefill = jax.jit(slot_prefill)
            self._admit = jax.jit(admit, donate_argnums=(0,))
            self._jits += [self._slot_prefill, self._admit]
        elif self.ragged:
            # ragged admission: ONE forward per tick — decode rows for every
            # slot plus up to prefill_lanes C-token chunks, flattened into a
            # single (1, B + L*C) token batch (engine.make_ragged_step).
            # Pure-decode ticks run the same step with all-inert lane rows:
            # one compile shape for the entire run.
            rag = make_ragged_step(
                model, mesh=engine.mesh, axis_rules=engine.axis_rules,
                temperature=temperature, with_health=health)
            nslots = engine.batch_slots

            def masked_ragged(params, tok, cache, rng, active, chunk_tok,
                              slot_ids, positions, logit_rows, enc=None,
                              poison=None):
                if health:
                    nxt, ok, cache = rag(params, tok, cache, rng, chunk_tok,
                                         slot_ids, positions, logit_rows,
                                         enc, poison)
                    dec = jnp.where(active[:, None], nxt[:nslots], pad)
                    return dec, nxt[nslots:], ok, cache
                nxt, cache = rag(params, tok, cache, rng, chunk_tok,
                                 slot_ids, positions, logit_rows, enc)
                dec = jnp.where(active[:, None], nxt[:nslots], pad)
                return dec, nxt[nslots:], cache

            self._masked_ragged = jax.jit(masked_ragged,
                                          donate_argnums=(1, 2) if sync
                                          else (2,))
            self._jits.append(self._masked_ragged)
        else:
            # chunked admission: one fused mixed step, one compile shape.
            # merge runs between the decode and chunk halves so the lane
            # slot's recurrence enters its chunk un-corrupted.
            mixed = make_mixed_step(
                model, mesh=engine.mesh, axis_rules=engine.axis_rules,
                temperature=temperature, with_health=health, merge=merge)

            def masked_mixed(params, tok, cache, rng, active, chunk_tok,
                             slot, start, length, enc=None, poison=None):
                if health:
                    nxt, first, dec_ok, first_ok, cache = mixed(
                        params, tok, cache, rng, chunk_tok, slot, start,
                        length, enc, poison, active)
                    return (jnp.where(active[:, None], nxt, pad), first,
                            dec_ok, first_ok, cache)
                nxt, first, cache = mixed(params, tok, cache, rng, chunk_tok,
                                          slot, start, length, enc, None,
                                          active)
                return jnp.where(active[:, None], nxt, pad), first, cache

            self._masked_mixed = jax.jit(masked_mixed,
                                         donate_argnums=(1, 2) if sync
                                         else (2,))
            self._jits.append(self._masked_mixed)

    def _count_jit_compiles(self) -> int:
        """Compiled-entry count across this scheduler's jitted steps — the
        bucket-explosion telltale: chunked admission stays O(1) no matter how
        many distinct prompt lengths a run serves."""
        return sum(f._cache_size() for f in self._jits
                   if hasattr(f, "_cache_size"))

    def cancel(self, rid: int) -> None:
        """Request host-side cancellation of ``rid`` (thread/callback-safe
        in the sense that it only mutates a host set): the running ``run()``
        drains the box at its next tick and terminates the request —
        wherever it is (queued, mid-prefill, parked, or live) — with
        ``status="cancelled"`` and its tokens emitted so far.  Cancelling an
        unknown or already-finished rid is a no-op."""
        self._cancel_box.add(int(rid))

    # ---- paged admission sizing (delegates to serve/admission.py) ---------
    def _pages_needed(self, plen: int, max_new: int) -> int:
        """A request's worst-case page footprint (AdmissionPlanner)."""
        return self._admission.pages_needed(plen, max_new)

    def _page_row(self, pages: List[int]) -> jax.Array:
        """A (max_pages,) device row: allocated pool indices then -1s."""
        return self._admission.page_row(pages)

    def _plan_admission(self, r: Request, plen: int, alloc: PageAllocator,
                        index: Optional[PrefixIndex],
                        keys: Optional[List[bytes]] = None):
        """Page plan for admitting ``r`` (AdmissionPlanner.plan), or None
        on a page stall."""
        return self._admission.plan(r, plen, alloc, index, keys=keys)

    def _assert_private_write(self, pages: List[int], lo: int, hi: int,
                              alloc: PageAllocator) -> None:
        """Shared-mapping write invariant (AdmissionPlanner)."""
        self._admission.assert_private_write(pages, lo, hi, alloc)

    # ---- prompt bucketing --------------------------------------------------
    def _bucket(self, plen: int) -> int:
        if self.prompt_bucket is None:
            return plen
        b = self.prompt_bucket
        return ((plen + b - 1) // b) * b

    def _pad_prompt(self, prompt) -> Tuple[jax.Array, int]:
        arr = np.asarray(prompt, np.int32).reshape(-1)
        plen = int(arr.shape[0])
        padded = np.full((1, self._bucket(plen)), self.pad_id, np.int32)
        padded[0, :plen] = arr
        return jnp.asarray(padded), plen

    # ---- warmup ------------------------------------------------------------
    def warmup(self, prompt_lens: Sequence[int], *, seed: int = 0,
               enc: Any = None) -> float:
        """Compile every step the run will need against throwaway state, so
        the measured loop is pure steady state. Returns compile seconds.

        One-shot admission compiles one slot-prefill per distinct (bucketed)
        prompt length; chunked admission compiles the mixed step once — its
        chunk shape is static, so ``prompt_lens`` is irrelevant.  ``enc`` is
        the run's per-slot encoder buffer shape-alike (EncDec serving).
        """
        eng = self.engine
        t0 = time.perf_counter()
        rng = jax.random.PRNGKey(seed)
        cache = eng.new_cache(per_slot=True)
        tok = jnp.full((eng.batch_slots, 1), self.pad_id, jnp.int32)
        active = jnp.ones((eng.batch_slots,), bool)
        slot0 = jnp.int32(0)
        # audit mode: the health-threading steps take a poison vector — an
        # all-zeros one is an exact no-op (see engine.make_decode_step)
        pz = jnp.zeros((eng.batch_slots,), jnp.float32) \
            if self.audit else None
        if enc is not None:
            enc = self._set_enc(jnp.zeros_like(enc), enc[:1], slot0)
            if self._cross_cached:
                cache = self._write_xkv(eng.params, cache, enc[:1], slot0)
        if self.chunk_size is not None:
            if self.paged:
                # throwaway page assignment for slot 0 (no allocator: warmup
                # state is discarded, only the compiles matter)
                n = min(self._pages_needed(self.chunk_size, 1),
                        eng.kv_num_pages)
                cache = self._set_pages(cache, slot0,
                                        self._page_row(list(range(n))))
                cache = self._append_page(cache, slot0, jnp.int32(n - 1),
                                          jnp.int32(n - 1))
                cache = self._set_len(cache, slot0, jnp.int32(0))
                if self.prefix_sharing:
                    cache = self._copy_page(cache, jnp.int32(0),
                                            jnp.int32(n - 1))
            if self.ragged:
                # one compile serves every tick: shapes depend only on
                # (slots, lanes, chunk) — values here are throwaway
                L, C = self.prefill_lanes, self.chunk_size
                T = eng.batch_slots + L * C
                ctok = jnp.full((L, C), self.pad_id, jnp.int32)
                sids = jnp.zeros((T,), jnp.int32)
                poss = jnp.full((T,), -1, jnp.int32)
                lrows = jnp.zeros((eng.batch_slots + L,), jnp.int32)
                if self.audit:
                    rp = jnp.zeros((eng.batch_slots + L,), jnp.float32)
                    tok, firsts, _ok, cache = self._masked_ragged(
                        eng.params, tok, cache, rng, active, ctok, sids,
                        poss, lrows, enc, rp)
                else:
                    tok, firsts, cache = self._masked_ragged(
                        eng.params, tok, cache, rng, active, ctok, sids,
                        poss, lrows, enc)
                tok = self._set_tok(tok, firsts[:1], slot0)
                cache = self._evict(cache, slot0)
                jax.block_until_ready((tok, cache))
                return time.perf_counter() - t0
            ctok = jnp.full((1, self.chunk_size), self.pad_id, jnp.int32)
            if self.audit:
                tok, first, _dok, _fok, cache = self._masked_mixed(
                    eng.params, tok, cache, rng, active, ctok, slot0,
                    jnp.int32(0), jnp.int32(self.chunk_size), enc, pz)
            else:
                tok, first, cache = self._masked_mixed(
                    eng.params, tok, cache, rng, active, ctok, slot0,
                    jnp.int32(0), jnp.int32(self.chunk_size), enc)
            tok = self._set_tok(tok, first, slot0)
        else:
            for p in sorted({self._bucket(int(p)) for p in prompt_lens}):
                toks = jnp.full((1, p), self.pad_id, jnp.int32)
                first, small = self._slot_prefill(eng.params, toks,
                                                  jnp.int32(p), rng)
                cache = self._admit(cache, small, slot0, jnp.int32(p))
                tok = self._set_tok(tok, first, slot0)
        if self.audit:
            tok, _ok, cache = self._masked_decode(eng.params, tok, cache,
                                                  rng, active, enc, pz)
        else:
            tok, cache = self._masked_decode(eng.params, tok, cache, rng,
                                             active, enc)
        cache = self._evict(cache, slot0)
        jax.block_until_ready((tok, cache))
        return time.perf_counter() - t0

    # ---- the serving loop --------------------------------------------------
    def run(self, requests: Sequence[Request], *, seed: int = 0,
            warmup: bool = True, time_ticks: bool = False,
            cancels: Optional[Dict[int, int]] = None,
            preempts: Optional[Dict[int, int]] = None,
            fault_plan: Optional[FaultPlan] = None,
            on_tick=None,
            ) -> Tuple[Dict[int, RequestResult], ServeStats]:
        """Serve all requests to a *terminal* status; ({rid: result}, stats).

        Time is discrete: one tick per batched step.  Queued requests become
        visible at their ``arrival`` tick and are admitted into the
        lowest-numbered free slot in (arrival, rid) order — one-shot (a
        stop-the-world batch-1 prefill between ticks) or, with
        ``chunk_size`` set, chunked (each tick's fused mixed step carries one
        prompt chunk alongside every live decode slot).

        Every request gets exactly one ``RequestResult`` — ``status="ok"``
        or a degraded terminal (``STATUSES``) carrying the tokens emitted so
        far: a ``deadline_steps`` expiry is a ``timeout`` wherever the
        request currently lives (queued, prefilling, parked, or decoding); a
        host cancel (``cancels={rid: tick}`` or :meth:`cancel` from a
        callback) is a ``cancelled``; a bounded-queue shed is a
        ``rejected``; an unservable request under a dry pool (previously a
        RuntimeError mid-run) is a ``failed``, as is a slot evicted by the
        audit-mode NaN/Inf logit sentinel.  ``run()`` itself only raises for
        invalid *inputs* (and :class:`~repro.serve.audit.AuditError` for
        genuine state corruption) — operational overload degrades per
        request instead of burning the whole batch.

        ``fault_plan`` (serve/faults.py) injects deterministic failures at
        the scheduler's seams for testing; ``on_tick(t)`` is a host hook
        called at the top of every tick (the cancellation tests drive
        :meth:`cancel` from it).

        ``preempts={rid: tick}`` forces a preemption of ``rid`` at the
        first tick >= ``tick`` where it holds a live slot (the entry stays
        pending until then, and is dropped if the request reaches a
        terminal status first).  The configured ``preempt_policy`` applies;
        on non-paged engines (dense KV, recurrent state) the preemption is
        always recompute — tokens so far are banked and the request
        re-queues as a continuation, so greedy token streams are unchanged.
        This is the preemption drill's deterministic trigger: it exercises
        the evict → carry → re-prefill lifecycle without needing a pool to
        exhaust.

        Without an ``eos_id`` termination is length-only, so scheduling never
        needs token *values* mid-flight: the loop runs fully async (device
        tokens harvested once at the end), keeping the dispatch pipeline as
        full as lockstep ``generate()``.  With EOS enabled each step syncs
        one (B, 1) readback — the price of data-dependent eviction.

        ``time_ticks=True`` blocks on each tick's tokens and records
        per-request wall-clock latency (summary p50/p99_latency_ms): the
        *step*-latency percentiles cannot see a stop-the-world prefill
        (virtual time does not advance during it), wall time can.
        """
        eng = self.engine
        nslots = eng.batch_slots
        C = self.chunk_size
        stats = ServeStats()
        stats.state_kinds = "+".join(self.state_kinds)
        preempts = {int(k): int(v) for k, v in (preempts or {}).items()}
        if fault_plan is not None:
            if fault_plan.nan and not self.audit:
                raise ValueError(
                    "FaultPlan.nan requires Scheduler(audit=True): the "
                    "NaN/Inf sentinel is audit mode's per-tick health "
                    "readback — without it the poison would stream garbage "
                    "tokens undetected")
            for tk, sj in fault_plan.nan.items():
                if not 0 <= sj < nslots:
                    raise ValueError(
                        f"FaultPlan.nan[{tk}] targets slot {sj} outside "
                        f"[0, {nslots})")
        plen_of: Dict[int, int] = {}
        checked: List[Request] = []
        for r in requests:
            plen = int(np.asarray(r.prompt).reshape(-1).shape[0])
            if r.max_new < 1:
                raise ValueError(f"request {r.rid}: max_new must be >= 1")
            if plen < 1:
                raise ValueError(f"request {r.rid}: empty prompt")
            if r.deadline_steps is not None and r.deadline_steps < 1:
                raise ValueError(
                    f"request {r.rid}: deadline_steps must be >= 1, got "
                    f"{r.deadline_steps}")
            if self.encdec and r.enc is None:
                raise ValueError(
                    f"request {r.rid}: EncDec serving needs the request's "
                    f"encoder output (Request.enc) — decoding without it "
                    f"drops the encoder context entirely")
            if not self.encdec and r.enc is not None:
                raise ValueError(
                    f"request {r.rid}: Request.enc given but the model has "
                    f"no encoder")
            if C is not None:
                rows = -(-plen // C) * C   # last (padded) chunk's extent
                # paged slots are bounded by their page-table capacity
                # (max_len rounded up to whole pages), not max_len itself —
                # chunk padding only has to fit allocatable pages
                cap = eng.kv_max_pages * eng.page_size if self.paged \
                    else eng.max_len
                if plen + r.max_new > cap and self.oversize == "truncate" \
                        and max(rows, plen + 1) <= cap:
                    granted = cap - plen
                    print(f"serve: request {r.rid}: truncating max_new "
                          f"{r.max_new} -> {granted} (prompt {plen} + "
                          f"horizon exceeds table capacity {cap})")
                    stats.truncations += 1
                    stats.truncated_rids[r.rid] = granted
                    r = dataclasses.replace(r, max_new=granted)
                if max(rows, plen + r.max_new) > cap:
                    # the loud half of the page-table-edge fix: rows past
                    # the table width would be sentinel-dropped on device
                    # and the request would silently decode garbage
                    raise ValueError(
                        f"request {r.rid}: prompt {plen} (chunk-padded to "
                        f"{rows}) + max_new {r.max_new} exceeds cache "
                        f"capacity {cap} (max_len {eng.max_len}); its KV "
                        f"rows past the table edge would be dropped and it "
                        f"would decode garbage — shrink the request, raise "
                        f"max_len, or use oversize='truncate'")
            elif self._bucket(plen) + r.max_new > eng.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {plen} (+bucket) + max_new "
                    f"{r.max_new} exceeds cache max_len {eng.max_len}")
            if self.paged:
                need = self._pages_needed(plen, r.max_new)
                if need > eng.kv_num_pages:
                    raise ValueError(
                        f"request {r.rid}: needs {need} pages but the pool "
                        f"holds {eng.kv_num_pages} — it could never be "
                        f"admitted (raise kv_pool_pages or shrink the "
                        f"request)")
            plen_of[r.rid] = plen
            checked.append(r)
        requests = checked
        orig_plen = dict(plen_of)   # recompute preemption moves plen_of

        enc_buf = None
        enc_of: Dict[int, jax.Array] = {}
        if self.encdec:
            for r in requests:
                row = jnp.asarray(r.enc)
                if row.ndim == 2:
                    row = row[None]
                if row.ndim != 3 or row.shape[0] != 1:
                    raise ValueError(
                        f"request {r.rid}: enc must be (S_enc, D) or "
                        f"(1, S_enc, D), got {row.shape}")
                enc_of[r.rid] = row
            shapes = {v.shape for v in enc_of.values()}
            if len(shapes) != 1:
                raise ValueError(
                    f"all requests must share one encoder shape per run "
                    f"(one jitted step signature), got {sorted(shapes)}")
            (one,) = shapes
            if self._cross_cached:
                el = int(getattr(eng.model, "enc_len"))
                if one[1] > el:
                    raise ValueError(
                        f"encoder output length {one[1]} exceeds the "
                        f"model's cross-attention cache capacity "
                        f"enc_len={el}: the cached xk/xv rows would "
                        f"truncate the encoder context — raise enc_len or "
                        f"shorten the encoder output")
            # keep the encoder's own dtype: an f32 buffer would silently
            # promote a bf16 model's cross-attention (and its residual
            # stream) and diverge from the generate() baseline
            enc_buf = jnp.zeros((nslots,) + one[1:],
                                next(iter(enc_of.values())).dtype)

        if warmup:
            stats.compile_s = self.warmup(
                [np.asarray(r.prompt).reshape(-1).shape[0]
                 for r in requests], seed=seed, enc=enc_buf)

        use_eos = self.eos_id is not None
        # pending: not yet arrived; queue: arrived and waiting.  The split
        # is what bounded-queue backpressure measures — max_queue bounds the
        # *waiting* set, not the future schedule.
        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.rid)))
        queue: deque = deque()
        cont_rids: set = set()     # recompute continuations: never shed —
        #                            they hold already-served tokens
        cancels = {int(k): int(v) for k, v in (cancels or {}).items()}
        cancel_pending: set = set()
        has_deadlines = any(r.deadline_steps is not None for r in requests)
        fault = fault_plan
        poison_plan = deque(sorted(fault.nan.items())) \
            if fault is not None else deque()
        fault_hold = False         # this tick idled because of an injected
        #                            fault denial (not a genuine deadlock)
        zero_poison = None
        if self.audit:
            R = nslots + (self.prefill_lanes if self.ragged else 0)
            zero_poison = jnp.zeros((R,), jnp.float32)
        slots: List[Optional[_Slot]] = [None] * nslots
        results: Dict[int, RequestResult] = {}
        # (slot, j, finish tick, eos, status) per terminal leg
        finished: List[Tuple[_Slot, int, int, bool, str]] = []
        step_cols: List[jax.Array] = []    # async mode: one (B, 1) per step
        arrival_wall: Dict[int, float] = {}
        cache = eng.new_cache(per_slot=True)
        stats.peak_cache_bytes = sum(
            l.size * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(cache))
        tok = jnp.full((nslots, 1), self.pad_id, jnp.int32)
        rng = jax.random.PRNGKey(seed)
        active_host, active_dev = None, None
        # chunked admission state: the requests currently being prefilled
        # chunk-by-chunk into reserved slots.  The mixed step drives exactly
        # one lane; the ragged step drives up to prefill_lanes concurrently.
        lanes: List[_Prefill] = []
        max_lanes = self.prefill_lanes if self.ragged else 1
        alloc = PageAllocator(eng.kv_num_pages) if self.paged else None
        index = PrefixIndex(eng.page_size) if self.prefix_sharing else None
        slot_pages: Dict[int, List[int]] = {}
        prompt_keys: Dict[int, List[bytes]] = {}   # rid -> cached digests
        carry: Dict[int, List[int]] = {}     # recompute: earlier legs' tokens
        first_admit: Dict[int, int] = {}     # rid -> first admission tick
        preempted: List[_Preempted] = []     # swap policy: parked requests
        swap = SwapArea(capacity_bytes=self.swap_bytes) \
            if (self.oversubscribe
                and self.preempt_policy == "swap") else None
        t = 0

        def digests_of(r: Request) -> Optional[List[bytes]]:
            """Prompt page digests, hashed once per request (satellite #2)."""
            if index is None:
                return None
            keys = prompt_keys.get(r.rid)
            if keys is None:
                keys = index.digests(r.prompt)
                prompt_keys[r.rid] = keys
            return keys

        def bump(status: str) -> None:
            """Route a terminal status into its ServeStats counter."""
            if status == "ok":
                stats.completed += 1
            elif status == "timeout":
                stats.timeouts += 1
            elif status == "cancelled":
                stats.cancellations += 1
            elif status == "rejected":
                stats.rejections += 1
            else:
                stats.failed += 1

        def finish(j: int, slot: _Slot, eos: bool, status: str = "ok"):
            nonlocal cache
            finished.append((slot, j, t, eos, status))
            if status == "ok":
                # degraded terminals are excluded from the latency
                # percentiles: a timeout's latency is its deadline by
                # construction, and mixing it in would poison the p99
                stats.latencies_steps.append(t - slot.req.arrival)
                if time_ticks and slot.req.rid in arrival_wall:
                    stats.latencies_s.append(
                        time.perf_counter() - arrival_wall[slot.req.rid])
            bump(status)
            # ORDER MATTERS: enqueue the device-side page-table unmap
            # (evict_cache_slot) BEFORE returning the pages to the host
            # allocator.  The very next admission may be handed these pages
            # (LIFO free list) and install them in another slot's row; its
            # writes are sequenced after this unmap through the cache
            # value's data dependency — freeing first would let a reused
            # page be mapped by two rows at once (aliasing/double-free).
            cache = self._evict(cache, jnp.int32(j))
            if alloc is not None and j in slot_pages:
                released = alloc.free(slot_pages.pop(j))
                if index is not None:
                    # shared prefixes outlive their owner: only pages whose
                    # refcount hit zero leave the index
                    index.drop_pages(released)
            slots[j] = None

        def admit_live(j: int, r: Request, first):
            """Slot j goes live holding its freshly sampled first token."""
            slot = _Slot(req=r, admitted_at=t, plen=plen_of[r.rid],
                         emitted=1, first=first)
            slots[j] = slot
            stats.tokens_out += 1
            if r.rid not in first_admit:
                first_admit[r.rid] = t
                stats.ttft_steps.append(t - r.arrival)
            if index is not None and j in slot_pages:
                # prefill complete: this slot's full prompt pages become
                # donor candidates for later same-prefix admissions (the
                # digests were cached at admission — no re-hash here)
                index.insert_keys(digests_of(r),
                                  slot_pages[j][:plen_of[r.rid]
                                                // eng.page_size])
            if use_eos:
                first_id = int(np.asarray(first)[0, 0])
                slot.tokens.append(first_id)
                if first_id == self.eos_id or r.max_new == 1:
                    finish(j, slot, first_id == self.eos_id)
            elif r.max_new == 1:
                finish(j, slot, False)

        def requeue(r: Request) -> None:
            """Put a request back into the queue in (arrival, rid) order.

            Only preemption continuations come through here; they bypass the
            ``max_queue`` bound (they hold served tokens — shedding one
            would throw away completed work) and are marked shed-immune.
            """
            cont_rids.add(r.rid)
            items = list(queue)
            items.append(r)
            items.sort(key=lambda q: (q.arrival, q.rid))
            queue.clear()
            queue.extend(items)

        def terminal_queued(r: Request, status: str) -> None:
            """Emit the result for a request terminated outside a live slot
            (still queued / mid-prefill / parked): tokens are whatever
            earlier legs banked in ``carry`` (empty for a fresh request)."""
            results[r.rid] = RequestResult(
                rid=r.rid, tokens=carry.pop(r.rid, []),
                prompt_len=orig_plen[r.rid], arrival=r.arrival,
                admitted_at=first_admit.get(r.rid, -1), finished_at=t,
                eos=False, status=status)
            bump(status)

        def fail_slot_state(slot_j: int, r: Request, status: str) -> None:
            """Tear down a reserved/mid-prefill slot's device + pool state
            (same evict-before-free ordering as ``finish``) and emit the
            request's terminal result."""
            nonlocal cache
            cache = self._evict(cache, jnp.int32(slot_j))
            if alloc is not None and slot_j in slot_pages:
                released = alloc.free(slot_pages.pop(slot_j))
                if index is not None:
                    index.drop_pages(released)
            terminal_queued(r, status)

        def abort_lane(p: _Prefill, status: str) -> None:
            """Terminate a mid-prefill admission lane."""
            lanes.remove(p)
            fail_slot_state(p.slot, p.req, status)

        def terminal_parked(p: _Preempted, status: str) -> None:
            """Terminate a parked (swapped-out) request: free its kept
            prefix refs, drop its swapped bytes, harvest its tokens."""
            preempted.remove(p)
            finished.append((p.slot, -1, t, False, status))
            bump(status)
            released = alloc.free(p.kept)
            if index is not None:
                index.drop_pages(released)
            rid = p.slot.req.rid
            if rid in swap:
                swap.pop(rid)

        def reap_status(r: Request) -> Optional[str]:
            """Terminal status a live/waiting request must take this tick
            (cancellation beats timeout), or None to keep serving."""
            if r.rid in cancel_pending:
                return "cancelled"
            if r.deadline_steps is not None \
                    and t >= r.arrival + r.deadline_steps:
                return "timeout"
            return None

        def pool_alloc(n: int) -> Optional[List[int]]:
            """``alloc.alloc`` through the fault seam: a ``deny_alloc``
            tick answers None (pool exhausted) regardless of free pages."""
            nonlocal fault_hold
            if fault is not None and fault.deny_alloc(t):
                stats.fault_events += 1
                fault_hold = True
                return None
            return alloc.alloc(n)

        def harvest_slot_tokens(slot: _Slot) -> List[int]:
            """Tokens this leg emitted so far (device sync in async mode)."""
            if use_eos:
                return list(slot.tokens)
            out = [int(np.asarray(slot.first)[0, 0])]
            for row, c in slot.cols:
                out.append(int(np.asarray(step_cols[c])[row, 0]))
            return out

        def preempt(j: int) -> None:
            """Evict live slot j mid-decode to hand its pages to someone else.

            ``recompute``: the victim's generated tokens so far are banked in
            ``carry`` and the request re-queues as a continuation whose prompt
            is original-prompt + generated-tokens — the existing chunked
            prefill rebuilds its KV (and, under greedy decoding, continues
            the exact token stream).  ``swap``: its private pages are copied
            to the host SwapArea and restored verbatim on resume; shared
            prefix pages stay resident (refcount held) and are never moved.
            """
            nonlocal cache
            slot = slots[j]
            rid = slot.req.rid
            stats.preemptions += 1
            stats.preempted_rids[rid] = stats.preempted_rids.get(rid, 0) + 1
            # non-paged engines (dense KV, recurrent state) have no pages to
            # park or free — eviction + recompute covers every state kind
            pages = slot_pages.pop(j) if alloc is not None else None
            park = swap is not None
            if park and fault is not None and fault.deny_swap(t):
                # injected host-memory refusal: degrade to recompute
                stats.fault_events += 1
                stats.swap_refusals += 1
                park = False
            if park:
                # COW admission keeps shared mappings a contiguous row
                # prefix; split it from the private tail
                m = 0
                while m < len(pages) and alloc.refcount(pages[m]) > 1:
                    m += 1
                kept, priv = pages[:m], pages[m:]
                assert all(alloc.refcount(p) == 1 for p in priv), \
                    "shared page past the private tail — refcount layout bug"
                data, pad = None, 0
                if priv:
                    # pow2-pad the gather so swap traffic reuses a handful
                    # of compiled shapes instead of one per page count
                    pad = 1
                    while pad < len(priv):
                        pad *= 2
                    idx = jnp.asarray(priv + [priv[0]] * (pad - len(priv)),
                                      jnp.int32)
                    # device_get blocks: the host copy is complete before
                    # the pages re-enter the free list below
                    data = jax.device_get(self._gather_pages(cache, idx))
                if not swap.fits(_tree_bytes(data)):
                    # SwapArea capacity (swap_bytes) refusal: recompute
                    stats.swap_refusals += 1
                    park = False
            if park:
                stats.swapped_pages += len(priv)
                swap.put(rid, data)
                stats.swap_peak_bytes = swap.peak_bytes
                preempted.append(_Preempted(
                    slot=slot, kept=kept, n_priv=len(priv), data=data,
                    pad=pad, live_len=slot.plen + slot.emitted - 1,
                    last_tok=tok[j:j + 1]))
                cache = self._evict(cache, jnp.int32(j))
                released = alloc.free(priv)    # kept pages: refs retained
                if index is not None:
                    index.drop_pages(released)
            else:
                toks = harvest_slot_tokens(slot)
                carry[rid] = carry.get(rid, []) + toks
                remaining = slot.req.max_new - slot.emitted   # >= 1 here
                cont_prompt = np.concatenate(
                    [np.asarray(slot.req.prompt, np.int32).reshape(-1),
                     np.asarray(toks, np.int32)])
                plen_of[rid] = int(cont_prompt.shape[0])
                prompt_keys.pop(rid, None)     # digests are stale now
                cache = self._evict(cache, jnp.int32(j))
                if alloc is not None:
                    released = alloc.free(pages)
                    if index is not None:
                        index.drop_pages(released)
                requeue(dataclasses.replace(slot.req, prompt=cont_prompt,
                                            max_new=remaining))
            slots[j] = None

        def try_resume() -> None:
            """Restore parked (swap-policy) requests, FIFO, while room lasts."""
            nonlocal cache, tok, enc_buf
            while preempted:
                p = preempted[0]
                free = [j for j in range(nslots) if slots[j] is None
                        and all(p.slot != j for p in lanes)]
                if not free:
                    stats.resume_stalls += 1
                    return
                got = pool_alloc(p.n_priv)
                if got is None:
                    stats.resume_stalls += 1
                    return
                j = free[0]
                rid = p.slot.req.rid
                data = swap.pop(rid)
                if p.n_priv:
                    # dup-pad the scatter to the gather's pow2 shape; the
                    # duplicate indices rewrite the same page with the same
                    # contents, which is idempotent
                    idx = jnp.asarray(got + [got[0]] * (p.pad - p.n_priv),
                                      jnp.int32)
                    cache = self._scatter_pages(cache, idx, data)
                row = p.kept + got
                slot_pages[j] = row
                cache = self._set_pages(cache, jnp.int32(j),
                                        self._page_row(row))
                cache = self._set_len(cache, jnp.int32(j),
                                      jnp.int32(p.live_len))
                tok = self._set_tok(tok, p.last_tok, jnp.int32(j))
                if enc_buf is not None:
                    enc_buf = self._set_enc(enc_buf, enc_of[rid],
                                            jnp.int32(j))
                    if self._cross_cached:
                        cache = self._write_xkv(eng.params, cache,
                                                enc_of[rid], jnp.int32(j))
                if index is not None and rid in prompt_keys:
                    index.insert_keys(prompt_keys[rid],
                                      row[:p.slot.plen // eng.page_size])
                slots[j] = p.slot    # cols hold (row, col) pairs, so the
                preempted.pop(0)     # slot index change is harvest-safe
                stats.resumes += 1
                stats.peak_pages_in_use = alloc.peak_in_use

        def ensure_growth() -> None:
            """Lazy decode growth: extend any slot about to cross a page
            boundary; preempt a victim when the pool is dry."""
            nonlocal cache
            for j in range(nslots):
                slot = slots[j]
                if slot is None:
                    continue
                need_rows = slot.plen + slot.emitted   # next write position+1
                while slots[j] is not None \
                        and need_rows > len(slot_pages[j]) * eng.page_size:
                    if len(slot_pages[j]) >= eng.kv_max_pages:
                        raise RuntimeError(
                            f"slot {j} (rid {slot.req.rid}) needs row "
                            f"{need_rows} past its page table "
                            f"({eng.kv_max_pages} pages) — run() validation "
                            f"should have rejected this request")
                    got = pool_alloc(1)
                    if got is not None:
                        pos = len(slot_pages[j])
                        slot_pages[j].append(got[0])
                        cache = self._append_page(cache, jnp.int32(j),
                                                  jnp.int32(pos),
                                                  jnp.int32(got[0]))
                        stats.grown_pages += 1
                        stats.peak_pages_in_use = alloc.peak_in_use
                        continue
                    # pool dry mid-decode: preempt. Victims are picked
                    # starvation-free (aged slots become untouchable); each
                    # preemption removes a candidate, so this terminates.
                    cands = [(i, s.req.rid, s.emitted, s.admitted_at)
                             for i, s in enumerate(slots) if s is not None]
                    victim = pick_preemption_victim(
                        cands, stats.preempted_rids, self.preempt_aging)
                    preempt(victim)

        t0 = time.perf_counter()
        while pending or queue or lanes or preempted \
                or any(s is not None for s in slots):
            if on_tick is not None:
                on_tick(t)
            fault_hold = False

            # -- arrivals + bounded-queue backpressure ----------------------
            while pending and pending[0].arrival <= t:
                r = pending.popleft()
                if time_ticks:
                    arrival_wall.setdefault(r.rid, time.perf_counter())
                if self.max_queue is not None \
                        and len(queue) >= self.max_queue:
                    if self.reject_policy == "shed_oldest":
                        victim = next(
                            (q for q in queue if q.rid not in cont_rids),
                            None)
                        if victim is not None:
                            queue.remove(victim)
                            print(f"serve: queue full ({self.max_queue}) — "
                                  f"shedding oldest waiting request "
                                  f"{victim.rid} for arrival {r.rid}")
                            terminal_queued(victim, "rejected")
                            queue.append(r)
                            continue
                    print(f"serve: queue full ({self.max_queue}) — "
                          f"rejecting request {r.rid}")
                    terminal_queued(r, "rejected")
                    continue
                queue.append(r)

            # -- cancellation + deadline sweep, every residence state -------
            if cancels:
                for rid_, tk_ in cancels.items():
                    if tk_ <= t:
                        cancel_pending.add(rid_)
            if self._cancel_box:
                cancel_pending |= self._cancel_box
                self._cancel_box = set()
            if cancel_pending or has_deadlines:
                for r in list(queue):
                    st = reap_status(r)
                    if st:
                        queue.remove(r)
                        cancel_pending.discard(r.rid)
                        terminal_queued(r, st)
                for p in list(lanes):
                    st = reap_status(p.req)
                    if st:
                        cancel_pending.discard(p.req.rid)
                        abort_lane(p, st)
                for p in list(preempted):
                    st = reap_status(p.slot.req)
                    if st:
                        cancel_pending.discard(p.slot.req.rid)
                        terminal_parked(p, st)
                for j in range(nslots):
                    if slots[j] is not None:
                        st = reap_status(slots[j].req)
                        if st:
                            cancel_pending.discard(slots[j].req.rid)
                            finish(j, slots[j], False, status=st)

            # -- forced preemption drills (``preempts={rid: tick}``) --------
            # fire on the first tick >= the requested tick where the rid is
            # live; entries for already-finished rids are dropped
            if preempts:
                for rid_, tk_ in list(preempts.items()):
                    if tk_ > t:
                        continue
                    if rid_ in results:
                        preempts.pop(rid_)
                        continue
                    for j in range(nslots):
                        if slots[j] is not None \
                                and slots[j].req.rid == rid_:
                            preempt(j)
                            preempts.pop(rid_)
                            break

            # Oversubscription housekeeping runs before admission: parked
            # requests get first claim on freed pages (no starvation behind
            # a stream of fresh admissions), then live slots grow into
            # whatever remains before a new reservation can take it.
            if self.oversubscribe:
                if preempted:
                    try_resume()
                ensure_growth()

            chunk_job: Optional[_Prefill] = None
            if C is None:
                # -- one-shot admission: freed slots pull from the queue ----
                free = [j for j in range(nslots) if slots[j] is None]
                while free and queue:
                    if fault is not None and fault.deny_admission(t):
                        stats.fault_events += 1
                        fault_hold = True
                        break
                    j, r = free.pop(0), queue.popleft()
                    if any(s is not None for s in slots):
                        stats.admission_stalls += 1
                    padded, plen = self._pad_prompt(r.prompt)
                    rng, sub = jax.random.split(rng)
                    first, small = self._slot_prefill(eng.params, padded,
                                                      jnp.int32(plen), sub)
                    cache = self._admit(cache, small, jnp.int32(j),
                                        jnp.int32(plen))
                    tok = self._set_tok(tok, first, jnp.int32(j))
                    admit_live(j, r, first)
            else:
                # -- chunked admission: reserve a slot (and, when paged, the
                # request's full page extent) per open lane for the oldest
                # arrived requests; chunks ride the mixed/ragged step -------
                while len(lanes) < max_lanes and queue:
                    if fault is not None and fault.deny_admission(t):
                        # injected admission stall: nobody enters this tick
                        stats.fault_events += 1
                        fault_hold = True
                        break
                    free = [j for j in range(nslots) if slots[j] is None
                            and all(p.slot != j for p in lanes)]
                    if not free:
                        break
                    r = queue[0]
                    plan = None
                    if alloc is not None:
                        if fault is not None and fault.deny_alloc(t):
                            # injected pool exhaustion at the admission seam
                            stats.fault_events += 1
                            stats.page_stalls += 1
                            fault_hold = True
                            break
                        plan = self._plan_admission(r, plen_of[r.rid],
                                                    alloc, index,
                                                    keys=digests_of(r))
                        if plan is None:
                            # page exhaustion defers the admission in
                            # the queue; eviction frees pages, so the
                            # retry eventually lands (decode never waits).
                            # Head-of-queue blocking on purpose: skipping
                            # ahead would starve the big request behind an
                            # endless stream of small ones.
                            stats.page_stalls += 1
                            break
                    queue.popleft()
                    j = free[0]
                    start0 = 0
                    if plan is not None:
                        row_pages, copies, n_share, start0 = plan
                        slot_pages[j] = list(row_pages)
                        if n_share or copies:
                            stats.prefix_hits += 1
                            stats.shared_pages_mapped += n_share
                            stats.cow_copies += len(copies)
                        # device order: privatize divergence pages
                        # (COW copy) BEFORE installing the row that
                        # points at the copies, then park the slot's
                        # live length at the shared-prefix boundary
                        # so the decode half's junk append for this
                        # still-prefilling slot lands in the private
                        # region, never through a shared mapping
                        for src, dst in copies:
                            cache = self._copy_page(
                                cache, jnp.int32(src), jnp.int32(dst))
                        cache = self._set_pages(
                            cache, jnp.int32(j),
                            self._page_row(row_pages))
                        if start0:
                            cache = self._set_len(
                                cache, jnp.int32(j),
                                jnp.int32(start0))
                        stats.peak_pages_in_use = alloc.peak_in_use
                    if enc_buf is not None:
                        enc_buf = self._set_enc(
                            enc_buf, enc_of[r.rid], jnp.int32(j))
                        if self._cross_cached:
                            # project + cache the encoder K/V once, at
                            # admission — decode steps read the cached rows
                            # instead of re-projecting ``enc`` every tick
                            cache = self._write_xkv(
                                eng.params, cache, enc_of[r.rid],
                                jnp.int32(j))
                    lanes.append(_Prefill(
                        req=r, slot=j,
                        prompt=np.asarray(r.prompt, np.int32).reshape(-1),
                        next_start=start0))
                if lanes and not self.ragged:
                    n_live = sum(s is not None for s in slots)
                    if self.token_budget is not None \
                            and n_live + C > self.token_budget:
                        stats.stalled_chunks += 1   # decode never waits
                    else:
                        chunk_job = lanes[0]

            if not any(s is not None for s in slots) and chunk_job is None \
                    and not (self.ragged and lanes):
                if not lanes:
                    if fault_hold:
                        # this tick idled because an injected fault denial
                        # blocked admission/alloc — a transient stall, not a
                        # deadlock.  Fault windows are finite by contract
                        # (serve/faults.py), so just let time pass.
                        t += 1
                        continue
                    # With nothing live, no pages will ever be freed again —
                    # a blocked resume or a page-stalled head request is a
                    # genuine deadlock, not a transient stall.  Convert ONE
                    # victim to status="failed" (freeing whatever it pins)
                    # and retry: the remaining requests usually survive.
                    # This used to raise mid-run and burn the whole batch.
                    if preempted:
                        p = preempted[0]
                        stats.deadlock_failures += 1
                        print(f"serve: unservable deadlock — parked request "
                              f"{p.slot.req.rid} cannot resume (pool pages "
                              f"pinned by parked shared prefixes, nothing "
                              f"live to free any); failing it to unblock "
                              f"(raise kv_pool_pages to avoid this)")
                        terminal_parked(p, "failed")
                        continue
                    if queue:
                        r = queue.popleft()
                        stats.deadlock_failures += 1
                        print(f"serve: request {r.rid} can never be "
                              f"admitted — nothing is live yet its "
                              f"admission plan still cannot be served from "
                              f"the pool ({eng.kv_num_pages} pages); "
                              f"failing it (raise kv_pool_pages or shrink "
                              f"the request)")
                        terminal_queued(r, "failed")
                        continue
                    if pending:   # idle gap: jump to the next arrival
                        t = max(t + 1, pending[0].arrival)
                continue

            # -- one batched step; finished slots emit masked pads -----------
            active = [s is not None for s in slots]
            stats.peak_live_slots = max(
                stats.peak_live_slots, sum(active) + len(lanes))
            if active != active_host:       # rebuild device mask only on change
                active_host, active_dev = active, jnp.asarray(active)
            rng, sub = jax.random.split(rng)
            poison_dev, ok_host = None, None
            if self.audit:
                # all-zeros poison is an exact logits no-op; a scheduled
                # FaultPlan.nan event poisons its target slot's row the
                # first tick >= its tick where that slot is live
                poison_dev = zero_poison
                if poison_plan and t >= poison_plan[0][0] \
                        and slots[poison_plan[0][1]] is not None:
                    _, sj_ = poison_plan.popleft()
                    stats.fault_events += 1
                    vec = np.zeros(zero_poison.shape, np.float32)
                    vec[sj_] = np.nan
                    poison_dev = jnp.asarray(vec)
            admitted = []               # (slot, request, first) on last chunks
            if self.ragged:
                # -- ONE ragged forward: B decode rows + L lanes x C chunk
                # rows flatten into a single token batch; idle slots and
                # lane tails are inert pad rows (position -1), so every
                # tick — pure decode included — is the same compiled step.
                rt = assemble_ragged_tick(
                    slots, lanes, nslots=nslots, n_lanes=self.prefill_lanes,
                    chunk=C, pad_id=self.pad_id,
                    token_budget=self.token_budget, n_active=sum(active),
                    assert_private=(
                        (lambda sj, lo, hi: self._assert_private_write(
                            slot_pages[sj], lo, hi, alloc))
                        if alloc is not None else None))
                stats.stalled_chunks += rt.stalled  # decode never waits
                ctok, sids, poss, lrows = rt.ctok, rt.sids, rt.poss, rt.lrows
                ran = rt.ran
                if self.audit:
                    tok, firsts, ok, cache = self._masked_ragged(
                        eng.params, tok, cache, sub, active_dev,
                        jnp.asarray(ctok), jnp.asarray(sids),
                        jnp.asarray(poss), jnp.asarray(lrows), enc_buf,
                        poison_dev)
                    ok_host = np.asarray(ok).reshape(-1)
                else:
                    tok, firsts, cache = self._masked_ragged(
                        eng.params, tok, cache, sub, active_dev,
                        jnp.asarray(ctok), jnp.asarray(sids),
                        jnp.asarray(poss), jnp.asarray(lrows), enc_buf)
                done = []
                for li, clen in ran:
                    p = lanes[li]
                    stats.prefill_chunks += 1
                    p.next_start += clen
                    if p.next_start >= int(p.prompt.shape[0]):
                        if ok_host is not None \
                                and not bool(ok_host[nslots + li]):
                            # NaN/Inf first-token logits: evict the lane's
                            # poisoned slot state instead of admitting it
                            stats.nan_evictions += 1
                            fail_slot_state(p.slot, p.req, "failed")
                            done.append(li)
                            continue
                        first = firsts[li:li + 1]
                        tok = self._set_tok(tok, first, jnp.int32(p.slot))
                        admitted.append((p.slot, p.req, first))
                        done.append(li)
                for li in reversed(done):
                    lanes.pop(li)
            elif chunk_job is not None:
                start = chunk_job.next_start
                plen = int(chunk_job.prompt.shape[0])
                clen = min(C, plen - start)
                ctok = np.full((1, C), self.pad_id, np.int32)
                ctok[0, :clen] = chunk_job.prompt[start:start + clen]
                if alloc is not None:
                    # the fused chunk write covers C (padded) rows: none may
                    # go through a shared mapping (COW ran at admission)
                    self._assert_private_write(
                        slot_pages[chunk_job.slot], start, start + C, alloc)
                first_ok = None
                if self.audit:
                    tok, first, dec_ok, first_ok, cache = self._masked_mixed(
                        eng.params, tok, cache, sub, active_dev,
                        jnp.asarray(ctok), jnp.int32(chunk_job.slot),
                        jnp.int32(start), jnp.int32(clen), enc_buf,
                        poison_dev)
                    ok_host = np.asarray(dec_ok).reshape(-1)
                else:
                    tok, first, cache = self._masked_mixed(
                        eng.params, tok, cache, sub, active_dev,
                        jnp.asarray(ctok), jnp.int32(chunk_job.slot),
                        jnp.int32(start), jnp.int32(clen), enc_buf)
                stats.prefill_chunks += 1
                chunk_job.next_start = start + clen
                if chunk_job.next_start >= plen:
                    if first_ok is not None \
                            and not bool(np.asarray(first_ok).reshape(-1)[0]):
                        # NaN/Inf first-token logits: evict, don't admit
                        stats.nan_evictions += 1
                        fail_slot_state(chunk_job.slot, chunk_job.req,
                                        "failed")
                    else:
                        tok = self._set_tok(tok, first,
                                            jnp.int32(chunk_job.slot))
                        admitted.append((chunk_job.slot, chunk_job.req,
                                         first))
                    lanes.pop(0)
            else:
                if self.audit:
                    tok, ok, cache = self._masked_decode(
                        eng.params, tok, cache, sub, active_dev, enc_buf,
                        poison_dev)
                    ok_host = np.asarray(ok).reshape(-1)
                else:
                    tok, cache = self._masked_decode(eng.params, tok, cache,
                                                     sub, active_dev,
                                                     enc_buf)
            if time_ticks:
                jax.block_until_ready(tok)
            t += 1
            stats.decode_steps += 1
            stats.occupancy_sum += sum(active) / nslots
            if alloc is not None and alloc.pages_in_use:
                # internal-fragmentation gauge: live K/V rows per resident
                # pool token.  Sharing-aware: a pool page mapped by several
                # slots counts ONCE, at the deepest live row any mapper
                # reaches — summing per-slot lengths would double-count
                # shared prefixes and report occupancy > 1.0.
                fill: Dict[int, int] = {}

                def _acc(pages: List[int], live: int) -> None:
                    for i, pg in enumerate(pages):
                        rows = min(max(live - i * eng.page_size, 0),
                                   eng.page_size)
                        if rows > fill.get(pg, 0):
                            fill[pg] = rows

                for s_j, s_ in enumerate(slots):
                    if s_ is not None:
                        _acc(slot_pages[s_j], s_.plen + s_.emitted)
                for p_ in lanes:
                    _acc(slot_pages.get(p_.slot, []), p_.next_start)
                for p_ in preempted:   # parked shared prefixes stay live
                    _acc(p_.kept, len(p_.kept) * eng.page_size)
                stats.page_util_sum += sum(fill.values()) / (
                    alloc.pages_in_use * eng.page_size)
                stats.page_util_ticks += 1
            tok_host = np.asarray(tok) if use_eos else None
            if not use_eos:
                step_cols.append(tok)
            for j in range(nslots):
                slot = slots[j]
                if slot is None:
                    continue
                if ok_host is not None and not bool(ok_host[j]):
                    # NaN/Inf logits in row j: evict the poisoned slot as
                    # failed — its garbage token is never recorded (emitted
                    # is not bumped, so the harvest stops at the last
                    # healthy token)
                    stats.nan_evictions += 1
                    finish(j, slot, False, status="failed")
                    continue
                slot.emitted += 1
                stats.tokens_out += 1
                hit_eos = False
                if use_eos:
                    tid = int(tok_host[j, 0])
                    slot.tokens.append(tid)
                    hit_eos = tid == self.eos_id
                else:
                    # (row, col): a swap-resumed slot may land in a new row
                    slot.cols.append((j, len(step_cols) - 1))
                if hit_eos or slot.emitted >= slot.req.max_new:
                    finish(j, slot, hit_eos)
            for a in admitted:
                admit_live(*a)

            # -- invariant audit: allocator/table/swap agreement every tick -
            if self.audit:
                holders: Dict[Any, List[int]] = {
                    ("slot", j_): pgs for j_, pgs in slot_pages.items()}
                for p_ in preempted:
                    holders[("parked", p_.slot.req.rid)] = p_.kept
                if alloc is not None:
                    check_allocator(alloc, holders)
                    kv = _find_paged_kv(cache)
                    if kv is not None:
                        table = np.asarray(kv["page_table"])
                        lens = np.asarray(kv["len"])
                        if table.ndim == 3:    # scan-stacked layer axis
                            table = table[0]
                        if lens.ndim == 2:
                            lens = lens[0]
                        # live decode slots pin their device len exactly
                        # (plen + emitted - 1 rows written); mid-prefill
                        # lanes only lower-bound it — the fused mixed
                        # step's masked junk appends may run a lane's len
                        # ahead of its chunk cursor (see nn/attention.py
                        # append_kv_decode)
                        exact = {j_: s_.plen + s_.emitted - 1
                                 for j_, s_ in enumerate(slots)
                                 if s_ is not None}
                        mins = {p_.slot: p_.next_start for p_ in lanes}
                        check_page_tables(
                            table, lens, slot_pages, alloc.refcount,
                            exact_lens=exact, min_lens=mins,
                            page_size=eng.page_size)
                check_swap(swap, [(p_.slot.req.rid, p_.data)
                                  for p_ in preempted])
                if self._has_recurrent:
                    # dead slots must hold exactly-zero recurrent rows —
                    # any leak through merge_inactive decodes garbage for
                    # the NEXT occupant, so catch it the tick it happens
                    live_rec = {j_ for j_, s_ in enumerate(slots)
                                if s_ is not None}
                    live_rec |= {p_.slot for p_ in lanes}
                    check_recurrent_rows(cache, live_rec)
                if self._cross_cached:
                    want_xl = {j_: int(enc_of[s_.req.rid].shape[1])
                               for j_, s_ in enumerate(slots)
                               if s_ is not None}
                    for p_ in lanes:
                        want_xl[p_.slot] = int(
                            enc_of[p_.req.rid].shape[1])
                    check_cross_lens(cache, want_xl)
                stats.audited_ticks += 1
        stats.steady_s = time.perf_counter() - t0
        stats.num_jit_compiles = self._count_jit_compiles()

        # -- harvest: one device->host sync for the whole run (async mode) --
        if step_cols:
            mat = np.asarray(jnp.concatenate(step_cols, axis=1))
        for slot, j, t_fin, eos, status in finished:
            r = slot.req
            if not use_eos:
                slot.tokens = [int(np.asarray(slot.first)[0, 0])] \
                    + [int(mat[row, c]) for row, c in slot.cols]
            # recompute preemption: tokens banked by earlier legs come
            # first; the result is keyed to the ORIGINAL prompt length and
            # first admission tick, so preemption is invisible downstream
            results[r.rid] = RequestResult(
                rid=r.rid, tokens=carry.pop(r.rid, []) + slot.tokens,
                prompt_len=orig_plen[r.rid],
                arrival=r.arrival,
                admitted_at=first_admit.get(r.rid, slot.admitted_at),
                finished_at=t_fin, eos=eos, status=status)
        return results, stats


# --------------------------------------------------------------------------
# Restart-the-batch baseline (what continuous batching replaces)
# --------------------------------------------------------------------------

def run_restart_batching(engine, requests: Sequence[Request], *, seed: int = 0,
                         warmup: bool = True, eos_id: Optional[int] = None,
                         ) -> Tuple[Dict[int, RequestResult], ServeStats]:
    """Serve via lockstep ``generate()`` restarts: gather whatever has
    arrived (≤ batch_slots), run the whole batch for the *longest* request's
    horizon, restart.  Late arrivals wait for the restart; short requests pad
    out the batch.  The bench's comparison point for the scheduler's
    steady-state throughput (benchmarks/serve_bench.py).
    """
    reqs = sorted(requests, key=lambda r: (r.arrival, r.rid))
    plens = {int(np.asarray(r.prompt).reshape(-1).shape[0]) for r in reqs}
    if len(plens) != 1:
        raise ValueError(f"restart baseline needs equal prompt lengths: {plens}")
    plen = plens.pop()
    nslots = engine.batch_slots
    stats = ServeStats()
    stats.peak_cache_bytes = engine.cache_bytes()
    max_horizon = max(r.max_new for r in reqs)

    if warmup:
        t0 = time.perf_counter()
        dummy = jnp.zeros((nslots, plen), jnp.int32)
        jax.block_until_ready(engine.generate(dummy, max_horizon, seed=seed))
        stats.compile_s = time.perf_counter() - t0

    queue = deque(reqs)
    results: Dict[int, RequestResult] = {}
    t = 0
    t0 = time.perf_counter()
    while queue:
        if queue[0].arrival > t:
            t = queue[0].arrival
        batch: List[Request] = []
        while queue and queue[0].arrival <= t and len(batch) < nslots:
            batch.append(queue.popleft())
        horizon = max(r.max_new for r in batch)
        prompts = np.zeros((nslots, plen), np.int32)
        for i, r in enumerate(batch):
            prompts[i] = np.asarray(r.prompt, np.int32).reshape(-1)
        out = np.asarray(engine.generate(jnp.asarray(prompts), horizon,
                                         seed=seed))
        for i, r in enumerate(batch):
            toks = [int(x) for x in out[i, :r.max_new]]
            eos = False
            if eos_id is not None and eos_id in toks:
                toks, eos = toks[:toks.index(eos_id) + 1], True
            results[r.rid] = RequestResult(
                rid=r.rid, tokens=toks, prompt_len=plen, arrival=r.arrival,
                admitted_at=t, finished_at=t + horizon, eos=eos)
            stats.tokens_out += len(toks)
            stats.latencies_steps.append(t + horizon - r.arrival)
        for step in range(horizon):
            stats.occupancy_sum += sum(
                1 for r in batch if r.max_new > step) / nslots
        stats.decode_steps += horizon
        t += horizon
    stats.steady_s = time.perf_counter() - t0
    stats.completed = len(results)    # the baseline serves everything "ok"
    return results, stats
