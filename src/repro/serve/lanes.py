"""Ragged-tick lane assembly: flatten decode slots + prefill lanes to host
metadata for the one-forward-per-tick ragged step.

The ragged step (serve/engine.py ``make_ragged_step``) takes per-token
addressing — slot ids, logical positions, per-lane chunk tokens, and the
logit rows to sample — instead of the mixed step's scalar chunk metadata.
Building those vectors from the scheduler's live slots and admission lanes
is pure host bookkeeping with a token-budget split; this module owns it so
the serving loop stays policy-only.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.admission import PrefillLane


@dataclasses.dataclass
class RaggedTick:
    """One tick's assembled ragged-step metadata (host numpy, pre-device).

    ``sids``/``poss`` address every flattened token: token ``t`` is logical
    row ``poss[t]`` of slot ``sids[t]``; position -1 marks inert padding
    (idle decode slots, lane tails).  ``ctok`` is the (L, C) per-lane chunk
    token block; ``lrows`` the (B + L,) logit rows the step samples.
    ``ran`` lists (lane index, chunk length) for the lanes that carried
    tokens this tick; ``stalled`` counts lanes deferred by the token budget.
    """

    sids: np.ndarray             # (B + L*C,) int32 slot id per token
    poss: np.ndarray             # (B + L*C,) int32 position per token (-1 inert)
    ctok: np.ndarray             # (L, C) int32 chunk tokens (pad-filled)
    lrows: np.ndarray            # (B + L,) int32 logit rows to sample
    ran: List[Tuple[int, int]]   # (lane index, clen) lanes that ran
    stalled: int                 # lanes deferred under token_budget


def assemble_ragged_tick(slots: Sequence, lanes: Sequence[PrefillLane], *,
                         nslots: int, n_lanes: int, chunk: int, pad_id: int,
                         token_budget: Optional[int], n_active: int,
                         assert_private: Optional[Callable[[int, int, int],
                                                           None]] = None,
                         ) -> RaggedTick:
    """Build one tick's :class:`RaggedTick` from live slots and lanes.

    Decode rows: every live slot consumes its last sampled token and writes
    K/V at its next free row (``plen + emitted - 1``); idle slots are inert.
    Lane rows: the token budget (minus live decode tokens) splits over the
    lanes in admission order — older lanes drain first, younger lanes take
    the remainder; a lane granted no room this tick counts as ``stalled``
    (decode tokens are never dropped).  ``assert_private(slot, lo, hi)``,
    when given, runs per lane over its valid write rows — the paged
    shared-mapping invariant (serve/admission.py ``assert_private_write``).
    """
    L, C = n_lanes, chunk
    sids = np.zeros((nslots + L * C,), np.int32)
    poss = np.full((nslots + L * C,), -1, np.int32)
    ctok = np.full((L, C), pad_id, np.int32)
    lrows = np.full((nslots + L,), 0, np.int32)
    lrows[:nslots] = np.arange(nslots)
    for j, s in enumerate(slots):
        if s is not None:
            sids[j] = j
            # this tick consumes tok[j] (the slot's last sampled token) and
            # writes its K/V at the next free row
            poss[j] = s.plen + s.emitted - 1
    # split the token budget over the lanes in admission order: older lanes
    # drain first, younger lanes take the remainder
    avail = None if token_budget is None \
        else max(0, token_budget - n_active)
    ran: List[Tuple[int, int]] = []
    stalled = 0
    for li, p in enumerate(lanes):
        base = nslots + li * C
        lrows[nslots + li] = base
        room = int(p.prompt.shape[0]) - p.next_start
        clen = min(C, room) if avail is None else min(C, room, avail)
        if clen <= 0:
            stalled += 1                        # decode never waits
            continue
        if avail is not None:
            avail -= clen
        start = p.next_start
        ctok[li, :clen] = p.prompt[start:start + clen]
        sids[base:base + clen] = p.slot
        poss[base:base + clen] = np.arange(start, start + clen)
        lrows[nslots + li] = base + clen - 1
        if assert_private is not None:
            # ragged lanes write exactly their clen valid rows (pads are
            # inert): none may go through a shared mapping (COW ran at
            # admission)
            assert_private(p.slot, start, start + clen)
        ran.append((li, clen))
    return RaggedTick(sids=sids, poss=poss, ctok=ctok, lrows=lrows,
                      ran=ran, stalled=stalled)
