"""Invariant auditor for the paged serving state (host + device halves).

The scheduler's paging machinery maintains a three-way agreement: the host
allocator's refcounts, the scheduler's per-slot page lists (plus parked
swap state), and the device page tables the kernels actually read through.
A bug in any one of them — a double-mapped private page, a leaked page, a
stale refcount, a table row pointing at a freed page — decodes *plausible
garbage*, the worst failure mode an inference stack has.  This module
makes the agreement checkable: ``Scheduler(audit=True)`` runs
:func:`check_allocator` / :func:`check_page_tables` / :func:`check_swap`
every tick and raises :class:`AuditError` at the first breach, and the
hypothesis property tests (tests/test_paging_properties.py) drive the same
checks against randomly churned and deliberately corrupted states.

Invariants enforced:

* **refcount conservation** — every pool page's refcount equals the number
  of holders mapping it (live slot rows, mid-prefill reservations, parked
  requests' kept prefixes); the free list holds exactly the refcount-zero
  pages, without duplicates;
* **page tables map only live pages** — a resident slot's device table row
  is exactly its host-side page list (then ``-1``), and a slot holding no
  request has an all ``-1`` row;
* **no page mapped twice as private** — a page appearing in several rows
  must carry a refcount > 1 (a shared prefix), never 1 (aliased writes);
* **slot lens vs page extents** — a live slot's device ``len`` equals its
  ``prompt + emitted - 1`` write frontier and fits its mapped extent; a
  mid-prefill slot's ``len`` never falls behind its chunk cursor;
* **SwapArea byte conservation** — the area holds exactly the parked
  requests' page trees, and its byte counter matches their sizes.

Per-adapter state invariants (serve/slot_state.py) ride the same per-tick
hook:

* **recurrent rows inert when dead** — a slot holding no request (and not
  reserved by a prefill lane) must have exactly-zero recurrent state rows
  (:func:`check_recurrent_rows`): admission starts every recurrence from
  zeros, so any nonzero dead row means a masked step leaked state through
  the ``merge_inactive`` barrier or an eviction skipped a row;
* **cross-attention lens match the encoder** — a live/reserved EncDec
  slot's cached ``xlen`` equals its request's encoder length, every other
  slot's is 0 (:func:`check_cross_lens`): a mismatch means the slot decodes
  against another request's (or a stale) encoder projection.

The per-tick NaN/Inf *logit* sentinel is the scheduler's half (the jitted
steps return per-row health flags under ``audit=True``); this module is
the pool/state half.
"""
from __future__ import annotations

from collections import Counter
from typing import Any, Dict, List, Mapping, Optional, Sequence, Set, Tuple

import numpy as np

from repro.serve.paging import PageAllocator, SwapArea, _tree_bytes
from repro.serve.slot_state import (REC_BASE_RANK, find_cross_nodes,
                                    find_recurrent_nodes)


class AuditError(RuntimeError):
    """A serving-state invariant was breached (see module doc)."""


def check_allocator(alloc: PageAllocator,
                    holders: Mapping[Any, Sequence[int]]) -> None:
    """Refcount conservation between ``alloc`` and its ``holders``.

    ``holders`` maps an opaque holder key (a live slot, a parked request —
    anything that owns page references) to the pool pages it maps.  Every
    page's refcount must equal the number of holder entries naming it, the
    free list must hold exactly the unreferenced pages, and no page may
    appear in the free list twice.  Catches double-maps (more holders than
    refs), leaks (refs with no holder), and stale refcounts in either
    direction.
    """
    counts: Counter = Counter()
    for key, pages in holders.items():
        for p in pages:
            if not 0 <= p < alloc.num_pages:
                raise AuditError(
                    f"holder {key!r} maps page {p} outside the pool "
                    f"[0, {alloc.num_pages})")
            counts[p] += 1
    free = list(alloc.free_list)
    if len(free) != len(set(free)):
        dup = [p for p, c in Counter(free).items() if c > 1]
        raise AuditError(f"free list holds duplicate page(s) {sorted(dup)}")
    free_set = set(free)
    for p in range(alloc.num_pages):
        rc = alloc.refcount(p)
        held = counts.get(p, 0)
        if rc != held:
            kind = "leaked (no holder)" if held < rc else "double-mapped"
            raise AuditError(
                f"page {p}: refcount {rc} but {held} holder mapping(s) — "
                f"{kind}")
        if rc > 0 and p in free_set:
            raise AuditError(
                f"page {p} is on the free list with refcount {rc}")
        if rc == 0 and p not in free_set:
            raise AuditError(
                f"page {p} has refcount 0 but is missing from the free "
                f"list — leaked out of the pool")


def check_page_tables(table: np.ndarray, lens: np.ndarray,
                      slot_rows: Mapping[int, Sequence[int]],
                      refcount_of, *,
                      exact_lens: Optional[Mapping[int, int]] = None,
                      min_lens: Optional[Mapping[int, int]] = None,
                      page_size: int = 1) -> None:
    """Device page tables / lens vs the scheduler's host-side slot state.

    ``table`` is the (slots, max_pages) int32 device table (one layer — all
    layers share the logical assignment), ``lens`` the (slots,) device live
    lengths.  ``slot_rows`` maps *resident* slot index -> its host page
    list; every other slot must have an all ``-1`` row.  ``exact_lens``
    (live decode slots) pins ``len`` exactly; ``min_lens`` (mid-prefill
    slots, whose ``len`` may run ahead over masked junk rows on the fused
    mixed step) only lower-bounds it.  ``refcount_of`` is called for pages
    mapped by more than one row — any such page must be shared
    (refcount > 1), never private.
    """
    nslots = table.shape[0]
    mapped_by: Dict[int, List[int]] = {}
    for j in range(nslots):
        row = table[j]
        pages = slot_rows.get(j)
        if pages is None:
            if (row != -1).any():
                raise AuditError(
                    f"slot {j} holds no request but its table row still "
                    f"maps pages {row[row != -1].tolist()}")
            continue
        n = len(pages)
        if not np.array_equal(row[:n], np.asarray(pages, row.dtype)):
            raise AuditError(
                f"slot {j}: device table row {row[:n].tolist()} != host "
                f"page list {list(pages)}")
        if (row[n:] != -1).any():
            raise AuditError(
                f"slot {j}: table row maps {row[row != -1].size} pages "
                f"past its host page list ({n})")
        for p in pages:
            mapped_by.setdefault(int(p), []).append(j)
        if exact_lens is not None and j in exact_lens:
            if int(lens[j]) != exact_lens[j]:
                raise AuditError(
                    f"slot {j}: device len {int(lens[j])} != expected "
                    f"write frontier {exact_lens[j]}")
            if exact_lens[j] > n * page_size:
                raise AuditError(
                    f"slot {j}: live frontier {exact_lens[j]} exceeds its "
                    f"mapped extent ({n} pages x {page_size})")
        elif min_lens is not None and j in min_lens:
            if int(lens[j]) < min_lens[j]:
                raise AuditError(
                    f"slot {j}: device len {int(lens[j])} fell behind its "
                    f"prefill cursor {min_lens[j]}")
    for p, rows in mapped_by.items():
        if len(rows) > 1 and refcount_of(p) <= 1:
            raise AuditError(
                f"page {p} is mapped by slots {rows} but its refcount is "
                f"{refcount_of(p)} — a private page aliased across rows")


def check_swap(swap: Optional[SwapArea],
               parked: Sequence[Tuple[int, Any]]) -> None:
    """SwapArea byte conservation vs the scheduler's parked list.

    ``parked``: (rid, data) per parked request (data None when it had no
    private pages).  The area must hold exactly the parked rids and its
    byte counter must equal the sum of their trees' sizes.
    """
    if swap is None:
        if parked:
            raise AuditError(
                f"{len(parked)} parked request(s) but no SwapArea exists")
        return
    expect = 0
    for rid, data in parked:
        if rid not in swap:
            raise AuditError(f"parked request {rid} missing from SwapArea")
        expect += _tree_bytes(data)
    if len(swap) != len(parked):
        raise AuditError(
            f"SwapArea holds {len(swap)} request(s) but the scheduler has "
            f"{len(parked)} parked")
    if swap.bytes_held != expect:
        raise AuditError(
            f"SwapArea bytes_held {swap.bytes_held} != parked page bytes "
            f"{expect} — byte-conservation breach")


def check_recurrent_rows(cache, live: Set[int]) -> None:
    """Dead slots' recurrent-state rows must be exactly zero.

    ``live``: slot indices holding a request or reserved by a prefill lane
    (their rows carry real state, partial for mid-prefill lanes).  Every
    other slot's row in every recurrent leaf (Mamba ``h``/``conv``, RWKV
    ``s``/``shift``) must be all-zeros — the inert state admission assumes.
    A nonzero dead row means a masked batched step advanced it (a hole in
    the ``merge_inactive`` barrier) or an eviction missed a leaf; either
    way the *next* request admitted there would inherit foreign state and
    decode plausible garbage.
    """
    for node in find_recurrent_nodes(cache):
        for key, leaf in node.items():
            if leaf is None:
                continue
            arr = np.asarray(leaf)
            ax = 1 if arr.ndim == REC_BASE_RANK[key] + 1 else 0
            for j in range(arr.shape[ax]):
                if j in live:
                    continue
                row = np.take(arr, j, axis=ax)
                if np.any(row != 0):
                    raise AuditError(
                        f"recurrent leaf {key!r}: dead slot {j} holds "
                        f"nonzero state (max |x| = "
                        f"{float(np.max(np.abs(row)))}) — leaked through "
                        f"the inactive-merge barrier or missed by eviction")


def check_cross_lens(cache, want: Mapping[int, int]) -> None:
    """Cached cross-attention lengths vs the scheduler's live slots.

    ``want``: slot index -> its request's encoder length, for every live
    or lane-reserved slot; all other slots must read 0.  The cached
    ``xk``/``xv`` rows are masked by ``xlen`` exactly like KV ``len``, so a
    wrong value either truncates the encoder context or attends into
    stale rows from a previous occupant.
    """
    for node in find_cross_nodes(cache):
        xl = np.asarray(node["xlen"])
        if xl.ndim == 2:        # scan-stacked (L, slots): layers agree
            if np.any(xl != xl[0]):
                raise AuditError(
                    f"cross-attention xlen disagrees across stacked "
                    f"layers: {xl.tolist()}")
            xl = xl[0]
        for j in range(xl.shape[0]):
            exp = int(want.get(j, 0))
            if int(xl[j]) != exp:
                raise AuditError(
                    f"slot {j}: cached cross-attention xlen {int(xl[j])} "
                    f"!= expected {exp} ({'live' if j in want else 'dead'} "
                    f"slot)")
