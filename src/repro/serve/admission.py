"""Admission planning and preemption policy, behind the slot-state interface.

The scheduler's host-side admission logic — page sizing, prefix-match page
plans, copy-on-write bookkeeping, the shared-write invariant, and the
preemption victim policy — lives here, decoupled from the serving loop.
Everything operates on host integers and the allocator/index objects
(serve/paging.py); the *device* half of each decision (installing a page
row, privatizing a page, evicting a slot) goes through the slot-state
walkers (serve/slot_state.py) from the scheduler's jitted closures.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.paging import PageAllocator, PrefixIndex


@dataclasses.dataclass
class PrefillLane:
    """Chunked-admission state: one request currently being prefilled,
    chunk by chunk, into its reserved (not yet live) slot."""

    req: Any                     # serve.scheduler.Request
    slot: int
    prompt: np.ndarray           # (P,) int32
    next_start: int = 0          # first row of the next chunk


@dataclasses.dataclass
class Preempted:
    """Swap-policy parking state for one preempted request: everything the
    scheduler needs to resume it bit-exactly once a slot and pages free up."""

    slot: Any                    # the live-slot state, carried across
    kept: List[int]              # shared prefix pages still resident (the
    #                              refcount this request keeps holding)
    n_priv: int                  # private pages swapped out (to re-alloc)
    data: Any                    # host tree of the private pages' contents
    #                              (None when n_priv == 0)
    pad: int                     # padded page-vector length of ``data``
    live_len: int                # cache len at preemption (rows written)
    last_tok: Any                # (1, 1) device token feeding the next step


def pick_preemption_victim(candidates: Sequence[Tuple[int, int, int, int]],
                           counts: Dict[int, int], bound: int,
                           ) -> Optional[int]:
    """Choose which live slot to preempt; None when there are no candidates.

    ``candidates``: (slot_index, rid, emitted, admitted_at) per live slot.
    Starvation-free by an aging bound: a request already preempted
    ``bound`` or more times is only chosen when *every* candidate is (so
    re-admission is bounded — the victim eventually runs to completion).
    Among eligible candidates the least decode progress goes first (least
    recomputation/swap traffic wasted), most recent admission breaking ties
    (FIFO fairness: the oldest admissions finish first).
    """
    if not candidates:
        return None

    def key(c):
        j, rid, emitted, admitted_at = c
        return (counts.get(rid, 0) >= bound, emitted, -admitted_at, j)

    return min(candidates, key=key)[0]


@dataclasses.dataclass
class AdmissionPlanner:
    """Host-side paged-admission sizing and page planning.

    One instance per scheduler, parameterized by the engine's cache
    geometry; stateless across calls (the allocator and prefix index carry
    the state).  ``oversubscribe`` switches the reservation policy from
    full-extent (decode can never exhaust the pool) to prompt-only (decode
    pages grow lazily; exhaustion preempts a victim).
    """

    page_size: int
    max_pages: int               # page-table width (per-slot ceiling)
    chunk_size: int
    oversubscribe: bool = False

    def pages_needed(self, plen: int, max_new: int) -> int:
        """Pages covering a request's full extent: the chunk-padded prompt
        rows (the last chunk writes C rows even when partially valid) or
        prompt+decode tokens, whichever is larger — what up-front admission
        reserves so decode can never hit page exhaustion mid-request.
        Under oversubscription this is still the request's *worst-case*
        footprint (the pool-size feasibility floor), just no longer what
        admission takes up front."""
        c = self.chunk_size
        extent = max(-(-plen // c) * c, plen + max_new)
        return -(-extent // self.page_size)

    def page_row(self, pages: List[int]) -> jax.Array:
        """A (max_pages,) device row: allocated pool indices then -1s."""
        row = np.full((self.max_pages,), -1, np.int32)
        row[:len(pages)] = pages
        return jnp.asarray(row)

    def plan(self, r, plen: int, alloc: PageAllocator,
             index: Optional[PrefixIndex],
             keys: Optional[List[bytes]] = None):
        """Page plan for admitting ``r``: match, share, allocate, COW — or
        None when the pool cannot serve the fresh-page balance (page stall).

        With sharing, the request maps the longest resident prefix chain
        (full prompt pages only) and prefills from the divergence point
        ``next_start``.  ``keys`` are the request's precomputed prompt
        digests (``PrefixIndex.digests``) — the scheduler caches them per
        request so a page-stalled admission retried every tick does not
        re-hash its whole prompt every time.  A matched page the request
        must still write — only the final prompt page, when the *whole*
        prompt is resident and the last token is re-run for its first-token
        logits — is privatized up front: a fresh page is allocated, the
        shared page's rows are copied, and the table row points at the copy
        (copy-on-write; eager because the write is certain).

        Up-front mode reserves the full ``max(chunk_end, plen+max_new)``
        extent so decode can never exhaust the pool; oversubscription
        reserves only through ``chunk_end`` (the prompt's padded chunk
        writes) and leaves decode pages to the lazy growth loop.  The page
        count is clamped to the table width only when the overflow rows are
        *droppable chunk padding* (the device scatter's OOB sentinel); a
        plan that cannot cover the request's real rows raises — the silent
        clamp that used to drop live KV here is the bug this replaces.

        Returns ``(row_pages, copies, n_share, next_start)``: the table row
        in logical order, the (src, dst) device copies to enqueue, how many
        row entries are shared mappings, and the first prompt row to prefill.
        """
        ps = self.page_size
        C = self.chunk_size
        if index is None:
            matched = []
        elif keys is not None:
            matched = index.match_keys(keys)
        else:
            matched = index.match(r.prompt)
        s0 = len(matched) * ps
        # always prefill >= 1 token: the last chunk's logits sample the
        # request's first generated token
        next_start = min(s0, plen - 1)
        # pages covering the padded chunk writes (chunks write C rows from
        # next_start, so the write extent shifts with the shared prefix)
        # and, in up-front mode, the decode horizon
        chunk_end = next_start + -(-(plen - next_start) // C) * C
        if self.oversubscribe:
            extent, required = chunk_end, plen
        else:
            extent, required = max(chunk_end, plen + r.max_new), \
                plen + r.max_new
        total = -(-extent // ps)
        if total > self.max_pages:
            # rows past the table edge are sentinel-dropped by the device
            # scatter — benign for padded chunk tails, fatal for real rows
            total = self.max_pages
        if total * ps < required:
            raise ValueError(
                f"request {r.rid}: the page plan covers {total * ps} rows "
                f"(page-table width {self.max_pages} pages x "
                f"{ps}) but the request needs {required} "
                f"(prompt {plen}{'' if self.oversubscribe else f' + max_new {r.max_new}'}) "
                f"— the overflow rows would be silently dropped by the "
                f"out-of-bounds sentinel and the request would decode "
                f"garbage attention; raise max_len or shrink the request")
        first_write_page = next_start // ps
        n_share = min(len(matched), first_write_page)
        copies_src = matched[n_share:]          # divergence page(s) to COW
        fresh_n = total - n_share               # COW targets + fresh tail
        got = alloc.alloc(fresh_n)
        if got is None:
            return None
        alloc.share(matched[:n_share])
        row_pages = matched[:n_share] + got
        copies = list(zip(copies_src, got[:len(copies_src)]))
        return row_pages, copies, n_share, next_start

    def assert_private_write(self, pages: List[int], lo: int, hi: int,
                             alloc: PageAllocator) -> None:
        """The chunk-write invariant: rows [lo, hi) of a slot mapping
        ``pages`` must touch only privately mapped (refcount <= 1) pages —
        a write through a shared mapping would corrupt every other slot
        reading that page.  COW at admission makes this structurally true;
        this is the loud regression net in front of the device scatter."""
        ps = self.page_size
        for pi in range(lo // ps, min(-(-hi // ps), len(pages))):
            rc = alloc.refcount(pages[pi])
            assert rc <= 1, (
                f"chunk write into shared page {pages[pi]} (refcount {rc}) "
                f"— copy-on-write must privatize it first")
