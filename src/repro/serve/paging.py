"""Host-side block allocator + prefix index for the paged KV cache.

The device side of paging is dumb on purpose: pools + page tables
(nn/attention.py ``init_paged_kv_cache``) and kernels that read *through*
the table (kernels/qpaged_attn.py).  All policy — which pool pages belong to
which request, when admission must wait for memory, which pages two requests
may *share* — lives here, in plain Python, because it runs once per
admission/eviction, not per token.

The Scheduler (serve/scheduler.py) drives one :class:`PageAllocator` (and,
with prefix sharing enabled, one :class:`PrefixIndex`) per ``run()``:

* on admission it asks for ``ceil(request_extent / page_size)`` pages; a
  ``None`` answer defers the request in the queue (``page_stalls`` in the
  stats) instead of crashing — the paged analog of the token-budget stall;
* a request whose prompt prefix matches pages already resident (the index)
  maps those pages into its own table and bumps their refcount
  (:meth:`PageAllocator.share`) instead of allocating copies — the
  copy-on-write prefix-sharing path (docs/serving.md "Prefix sharing");
* on eviction it returns the slot's pages; each page goes back to the free
  list only when its refcount hits zero, so a prefix another live request
  still maps survives its original owner.  Reused pages mean external
  fragmentation stays zero by construction; internal fragmentation is
  bounded by one page per request and reported via ``page_occupancy``.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence

import numpy as np


class PageAllocator:
    """Refcounting free-list allocator over ``num_pages`` fixed-size pages.

    Pages are identified by their pool index (0..num_pages-1).  ``alloc``
    is all-or-nothing: a request that cannot get its full extent gets
    nothing (and the caller defers it), so a half-admitted request can never
    strand pages.  ``share`` bumps the refcount of already-held pages (prefix
    sharing maps one pool page into several slots' tables); ``free``
    decrements, and a page re-enters the free list only at refcount zero.
    Freeing a page more times than it was alloc'd/shared raises — better a
    loud ValueError than silent page aliasing between two live requests.
    """

    def __init__(self, num_pages: int):
        """Create an allocator with all ``num_pages`` pages free."""
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = num_pages
        # LIFO free list: freshly freed pages are reused first, which keeps
        # the working set of pool pages small (cache-friendlier on device).
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        """Pages currently available to alloc()."""
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """Pages currently held (refcount > 0) by live requests."""
        return self.num_pages - len(self._free)

    def refcount(self, page: int) -> int:
        """How many slots currently map ``page`` (0 = free)."""
        return self._ref.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages off the free list; None if fewer than n remain.

        All-or-nothing: on None the free list is untouched, so the caller
        can simply retry at the next tick (admission deferral).  Each
        returned page starts at refcount 1.
        """
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return pages

    def share(self, pages: Sequence[int]) -> None:
        """Add one reference to each of ``pages`` (prefix-sharing admission).

        Every page must currently be held — sharing a free page would alias
        whatever the free list hands out next, so that raises instead.
        """
        for p in pages:
            if self._ref.get(p, 0) < 1:
                raise ValueError(f"share of page {p} not currently held")
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns the pages actually released.

        A page re-enters the free list only when its refcount reaches zero
        (a shared prefix outlives its original owner).  The returned
        released-list is what the caller must retire from any side index
        (:meth:`PrefixIndex.drop_pages`).  Over-freeing raises.
        """
        released: List[int] = []
        for p in pages:
            if self._ref.get(p, 0) < 1:
                raise ValueError(f"free of page {p} not currently held")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)
                released.append(p)
        return released


class PrefixIndex:
    """Longest-prefix index over *full* prompt pages, keyed by token hashes.

    Maps the cumulative hash of a prompt's first ``k * page_size`` tokens to
    the pool page holding page ``k-1`` of some live request's prompt.
    Cumulative (not per-page) hashing means a page matches only when the
    *entire prefix* up to and including it matches — identical middle pages
    under different openings can never alias.

    Only pages fully covered by prompt tokens are ever registered: a page
    holding a prompt tail plus decode rows diverges immediately, and decode
    rows must never be shared.  The Scheduler inserts a request's full
    prompt pages once its prefill completes and drops entries when the
    allocator reports their page released (refcount zero) — while *any*
    sharer is live the entry stays valid, because the page still holds
    exactly the hashed tokens' K/V.
    """

    def __init__(self, page_size: int):
        """Index prompts at ``page_size``-token page granularity."""
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self._page_of: Dict[bytes, int] = {}    # cumulative hash -> pool page
        self._key_of: Dict[int, bytes] = {}     # pool page -> its index key

    def _keys(self, prompt) -> List[bytes]:
        """Cumulative sha1 digests, one per *full* prompt page."""
        arr = np.asarray(prompt, np.int32).reshape(-1)
        ps = self.page_size
        h = hashlib.sha1()
        out: List[bytes] = []
        for i in range(arr.shape[0] // ps):
            h.update(arr[i * ps:(i + 1) * ps].tobytes())
            out.append(h.digest())
        return out

    def match(self, prompt) -> List[int]:
        """Longest chain of resident pool pages holding this prompt's prefix.

        Returns pool page indices for full prompt pages 0..m-1 where every
        page up to m matched; the caller maps them (and ``share``s their
        refcounts) into the new slot's table.
        """
        pages: List[int] = []
        for key in self._keys(prompt):
            page = self._page_of.get(key)
            if page is None:
                break
            pages.append(page)
        return pages

    def insert(self, prompt, pages: Sequence[int]) -> None:
        """Register ``prompt``'s full prompt pages (after its prefill).

        ``pages`` is the owning slot's page-table row prefix (one pool page
        per full prompt page).  First writer wins: a prefix already indexed
        keeps its existing page, so concurrent identical prompts converge on
        one shared copy.
        """
        for key, page in zip(self._keys(prompt), pages):
            if key not in self._page_of:
                self._page_of[key] = page
                self._key_of[page] = key

    def drop_pages(self, pages: Sequence[int]) -> None:
        """Retire index entries whose pages the allocator just released."""
        for p in pages:
            key = self._key_of.pop(p, None)
            if key is not None and self._page_of.get(key) == p:
                del self._page_of[key]
