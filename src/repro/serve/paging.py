"""Host-side block allocator for the paged KV cache.

The device side of paging is dumb on purpose: pools + page tables
(nn/attention.py ``init_paged_kv_cache``) and kernels that read *through*
the table (kernels/qpaged_attn.py).  All policy — which pool pages belong to
which request, when admission must wait for memory — lives here, in plain
Python, because it runs once per admission/eviction, not per token.

The Scheduler (serve/scheduler.py) drives one :class:`PageAllocator` per
``run()``:

* on admission it asks for ``ceil(request_extent / page_size)`` pages; a
  ``None`` answer defers the request in the queue (``page_stalls`` in the
  stats) instead of crashing — the paged analog of the token-budget stall;
* on eviction it returns the slot's pages, which the very next admission may
  reuse (no compaction: pages are fixed-size, so external fragmentation is
  zero by construction; internal fragmentation is bounded by one page per
  request and reported via the stats' ``page_occupancy``).
"""
from __future__ import annotations

from typing import List, Optional


class PageAllocator:
    """Free-list allocator over a pool of ``num_pages`` fixed-size pages.

    Pages are identified by their pool index (0..num_pages-1).  ``alloc``
    is all-or-nothing: a request that cannot get its full extent gets
    nothing (and the caller defers it), so a half-admitted request can never
    strand pages.  A held-set guards against double-free in case a caller's
    slot bookkeeping goes wrong — better a loud ValueError than silent page
    aliasing between two live requests.
    """

    def __init__(self, num_pages: int):
        """Create an allocator with all ``num_pages`` pages free."""
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = num_pages
        # LIFO free list: freshly freed pages are reused first, which keeps
        # the working set of pool pages small (cache-friendlier on device).
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._held: set = set()
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        """Pages currently available to alloc()."""
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """Pages currently held by live requests."""
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages off the free list; None if fewer than n remain.

        All-or-nothing: on None the free list is untouched, so the caller
        can simply retry at the next tick (admission deferral).
        """
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._held.update(pages)
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return pages

    def free(self, pages: List[int]) -> None:
        """Return pages to the free list (eviction); double-free raises."""
        for p in pages:
            if p not in self._held:
                raise ValueError(f"free of page {p} not currently held")
            self._held.discard(p)
            self._free.append(p)
