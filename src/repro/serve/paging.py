"""Host-side block allocator + prefix index for the paged KV cache.

The device side of paging is dumb on purpose: pools + page tables
(nn/attention.py ``init_paged_kv_cache``) and kernels that read *through*
the table (kernels/qpaged_attn.py).  All policy — which pool pages belong to
which request, when admission must wait for memory, which pages two requests
may *share* — lives here, in plain Python, because it runs once per
admission/eviction, not per token.

The Scheduler (serve/scheduler.py) drives one :class:`PageAllocator` (and,
with prefix sharing enabled, one :class:`PrefixIndex`) per ``run()``:

* on admission it asks for ``ceil(request_extent / page_size)`` pages; a
  ``None`` answer defers the request in the queue (``page_stalls`` in the
  stats) instead of crashing — the paged analog of the token-budget stall;
* a request whose prompt prefix matches pages already resident (the index)
  maps those pages into its own table and bumps their refcount
  (:meth:`PageAllocator.share`) instead of allocating copies — the
  copy-on-write prefix-sharing path (docs/serving.md "Prefix sharing");
* on eviction it returns the slot's pages; each page goes back to the free
  list only when its refcount hits zero, so a prefix another live request
  still maps survives its original owner.  Reused pages mean external
  fragmentation stays zero by construction; internal fragmentation is
  bounded by one page per request and reported via ``page_occupancy``;
* under **oversubscription** (``Scheduler(oversubscribe=True)``) admission
  reserves only the prompt-covering pages and decode grows the slot one
  page at a time; when growth finds the pool empty the scheduler preempts a
  victim, and with ``preempt_policy="swap"`` the victim's *private* pages
  are copied into a host-side :class:`SwapArea` until they can be restored
  (shared prefix pages are never swapped — their refcount keeps them
  resident for the other sharers).
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


class PageAllocator:
    """Refcounting free-list allocator over ``num_pages`` fixed-size pages.

    Pages are identified by their pool index (0..num_pages-1).  ``alloc``
    is all-or-nothing: a request that cannot get its full extent gets
    nothing (and the caller defers it), so a half-admitted request can never
    strand pages.  ``share`` bumps the refcount of already-held pages (prefix
    sharing maps one pool page into several slots' tables); ``free``
    decrements, and a page re-enters the free list only at refcount zero.
    Freeing a page more times than it was alloc'd/shared raises — better a
    loud ValueError than silent page aliasing between two live requests.
    """

    def __init__(self, num_pages: int):
        """Create an allocator with all ``num_pages`` pages free."""
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = num_pages
        # LIFO free list: freshly freed pages are reused first, which keeps
        # the working set of pool pages small (cache-friendlier on device).
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        """Pages currently available to alloc()."""
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """Pages currently held (refcount > 0) by live requests."""
        return self.num_pages - len(self._free)

    @property
    def free_list(self) -> Sequence[int]:
        """The free list (LIFO order), read-only — the auditor's view."""
        return tuple(self._free)

    def refcount(self, page: int) -> int:
        """How many slots currently map ``page`` (0 = free)."""
        return self._ref.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` pages off the free list; None if fewer than n remain.

        All-or-nothing: on None the free list is untouched, so the caller
        can simply retry at the next tick (admission deferral).  Each
        returned page starts at refcount 1.
        """
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return pages

    def share(self, pages: Sequence[int]) -> None:
        """Add one reference to each of ``pages`` (prefix-sharing admission).

        Every page must currently be held — sharing a free page would alias
        whatever the free list hands out next, so that raises instead.
        """
        for p in pages:
            if self._ref.get(p, 0) < 1:
                raise ValueError(f"share of page {p} not currently held")
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns the pages actually released.

        A page re-enters the free list only when its refcount reaches zero
        (a shared prefix outlives its original owner).  The returned
        released-list is what the caller must retire from any side index
        (:meth:`PrefixIndex.drop_pages`).  Over-freeing raises.
        """
        released: List[int] = []
        for p in pages:
            if self._ref.get(p, 0) < 1:
                raise ValueError(f"free of page {p} not currently held")
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)
                released.append(p)
        return released


class PrefixIndex:
    """Longest-prefix index over *full* prompt pages, keyed by token hashes.

    Maps the cumulative hash of a prompt's first ``k * page_size`` tokens to
    the pool page holding page ``k-1`` of some live request's prompt.
    Cumulative (not per-page) hashing means a page matches only when the
    *entire prefix* up to and including it matches — identical middle pages
    under different openings can never alias.

    Only pages fully covered by prompt tokens are ever registered: a page
    holding a prompt tail plus decode rows diverges immediately, and decode
    rows must never be shared.  The Scheduler inserts a request's full
    prompt pages once its prefill completes and drops entries when the
    allocator reports their page released (refcount zero) — while *any*
    sharer is live the entry stays valid, because the page still holds
    exactly the hashed tokens' K/V.
    """

    def __init__(self, page_size: int):
        """Index prompts at ``page_size``-token page granularity."""
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self._page_of: Dict[bytes, int] = {}    # cumulative hash -> pool page
        self._key_of: Dict[int, bytes] = {}     # pool page -> its index key

    def digests(self, prompt) -> List[bytes]:
        """Cumulative sha1 digests, one per *full* prompt page.

        Hashing is O(prompt) — the scheduler computes this once per request
        and reuses the digests across page-stalled admission retries and the
        post-prefill :meth:`insert_keys` (a deferred request must not
        re-hash its whole prompt every tick).
        """
        arr = np.asarray(prompt, np.int32).reshape(-1)
        ps = self.page_size
        h = hashlib.sha1()
        out: List[bytes] = []
        for i in range(arr.shape[0] // ps):
            h.update(arr[i * ps:(i + 1) * ps].tobytes())
            out.append(h.digest())
        return out

    def match_keys(self, keys: Sequence[bytes]) -> List[int]:
        """Longest resident page chain for precomputed :meth:`digests`."""
        pages: List[int] = []
        for key in keys:
            page = self._page_of.get(key)
            if page is None:
                break
            pages.append(page)
        return pages

    def match(self, prompt) -> List[int]:
        """Longest chain of resident pool pages holding this prompt's prefix.

        Returns pool page indices for full prompt pages 0..m-1 where every
        page up to m matched; the caller maps them (and ``share``s their
        refcounts) into the new slot's table.
        """
        return self.match_keys(self.digests(prompt))

    def insert_keys(self, keys: Sequence[bytes],
                    pages: Sequence[int]) -> None:
        """Register precomputed :meth:`digests` against their pool pages."""
        for key, page in zip(keys, pages):
            if key not in self._page_of:
                self._page_of[key] = page
                self._key_of[page] = key

    def insert(self, prompt, pages: Sequence[int]) -> None:
        """Register ``prompt``'s full prompt pages (after its prefill).

        ``pages`` is the owning slot's page-table row prefix (one pool page
        per full prompt page).  First writer wins: a prefix already indexed
        keeps its existing page, so concurrent identical prompts converge on
        one shared copy.
        """
        self.insert_keys(self.digests(prompt), pages)

    def drop_pages(self, pages: Sequence[int]) -> None:
        """Retire index entries whose pages the allocator just released."""
        for p in pages:
            key = self._key_of.pop(p, None)
            if key is not None and self._page_of.get(key) == p:
                del self._page_of[key]


def _tree_bytes(data: Any) -> int:
    """Host bytes held by a nested list/dict tree of numpy arrays."""
    if data is None:
        return 0
    if isinstance(data, dict):
        return sum(_tree_bytes(v) for v in data.values())
    if isinstance(data, (list, tuple)):
        return sum(_tree_bytes(v) for v in data)
    return int(getattr(data, "nbytes", 0))


class SwapArea:
    """Host-side buffer for preempted requests' swapped-out KV pages.

    The ``preempt_policy="swap"`` half of oversubscription: when the pool
    runs dry mid-decode, the victim's *private* pages (refcount 1) are
    gathered device->host into this area and freed; its shared prefix pages
    stay resident (the refcount the victim keeps holding pins them for the
    other sharers — swapping a shared page would yank it from under live
    requests).  On resume the scheduler allocates fresh pages, scatters the
    saved contents back, and rebuilds the victim's table row.

    Purely host-side bookkeeping (numpy trees keyed by request id); the
    device gather/scatter primitives live in nn/attention.py
    (``gather_pool_pages`` / ``scatter_pool_pages``).  ``peak_bytes`` is the
    reporting hook: swap traffic is the cost knob the serve bench surfaces
    next to the admission win.

    ``capacity_bytes`` bounds the area (None = unbounded): the scheduler
    checks :meth:`fits` before parking and falls back to the recompute
    preemption path when a victim's pages do not fit — host memory refusal
    degrades, it does not crash.  :meth:`put` past capacity still raises
    (the loud net behind the polite check).
    """

    def __init__(self, capacity_bytes: Optional[int] = None):
        """Create an empty swap area (``capacity_bytes=None`` = unbounded)."""
        if capacity_bytes is not None and capacity_bytes < 0:
            raise ValueError(
                f"capacity_bytes must be >= 0, got {capacity_bytes}")
        self.capacity_bytes = capacity_bytes
        self._data: Dict[int, Any] = {}
        self.bytes_held = 0
        self.peak_bytes = 0

    def __contains__(self, rid: int) -> bool:
        return rid in self._data

    def __len__(self) -> int:
        return len(self._data)

    def fits(self, nbytes: int) -> bool:
        """Would ``nbytes`` more fit under ``capacity_bytes``?"""
        return (self.capacity_bytes is None
                or self.bytes_held + nbytes <= self.capacity_bytes)

    def put(self, rid: int, data: Any) -> None:
        """Park ``rid``'s swapped page contents (a numpy tree)."""
        if rid in self._data:
            raise ValueError(f"request {rid} already swapped out")
        nbytes = _tree_bytes(data)
        if not self.fits(nbytes):
            raise ValueError(
                f"request {rid}: {nbytes} swap bytes exceed capacity "
                f"{self.capacity_bytes} (held {self.bytes_held}) — the "
                f"scheduler should have checked fits() and recomputed")
        self._data[rid] = data
        self.bytes_held += nbytes
        self.peak_bytes = max(self.peak_bytes, self.bytes_held)

    def pop(self, rid: int) -> Any:
        """Take ``rid``'s parked page contents back for restore."""
        if rid not in self._data:
            raise KeyError(f"request {rid} has no swapped pages")
        data = self._data.pop(rid)
        self.bytes_held -= _tree_bytes(data)
        return data
