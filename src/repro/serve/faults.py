"""Deterministic fault injection for the serving scheduler.

A :class:`FaultPlan` is a *schedule* of failures, fixed before the run
starts, that the Scheduler consults at its resource seams — so every
degradation path (page-pool exhaustion, swap-area refusal, admission
stalls, NaN/Inf logits) can be exercised on purpose, repeatably, in tests
and in the ``bench_chaos`` CI gate.  Nothing here is probabilistic at run
time: :meth:`FaultPlan.random` derives the schedule from a seed once, and
two runs with the same plan see byte-identical fault timing.

The seams (serve/scheduler.py ``run``):

* ``alloc_fail`` ticks make every ``PageAllocator.alloc`` call behave as if
  the pool were empty — admission defers in the queue and mid-decode growth
  preempts victims, exactly like genuine exhaustion.  A growth crossing on
  such a tick preempts every eligible victim up to the growing slot itself
  (total-exhaustion semantics), so keep fault windows finite;
* ``swap_fail`` ticks make ``preempt_policy="swap"`` parking refuse the
  victim's pages: the preemption falls back to the recompute path (tokens
  banked, continuation re-queued) — the same degradation a full
  ``SwapArea(capacity_bytes=...)`` triggers;
* ``admit_stall`` ticks hold all new admissions for the tick (live decode
  never waits — the same contract as the token-budget stall);
* ``nan`` poisons one live decode slot's logits with NaN at (or at the
  first live tick after) a chosen tick.  Requires ``Scheduler(audit=True)``
  — the health sentinel is what turns the poison into a contained
  ``failed`` result instead of a silent garbage stream.

Fault ticks are *virtual time* (decode-step ticks), matching every other
scheduler clock (arrivals, deadlines), so plans are machine-independent.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, FrozenSet, Iterable, List, Tuple

import numpy as np


def _tickset(ticks: Iterable[int]) -> FrozenSet[int]:
    out = frozenset(int(t) for t in ticks)
    if any(t < 0 for t in out):
        raise ValueError(f"fault ticks must be >= 0, got {sorted(out)}")
    return out


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of injected serving faults (module doc).

    ``alloc_fail`` / ``swap_fail`` / ``admit_stall``: virtual-time ticks at
    which the corresponding seam denies.  ``nan``: {tick: decode slot} —
    each entry poisons that slot's logits at the first tick >= the key
    where the slot holds a live request (a plan written against one
    schedule stays meaningful when admission timing shifts a little).
    """

    alloc_fail: FrozenSet[int] = frozenset()
    swap_fail: FrozenSet[int] = frozenset()
    admit_stall: FrozenSet[int] = frozenset()
    nan: Dict[int, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "alloc_fail", _tickset(self.alloc_fail))
        object.__setattr__(self, "swap_fail", _tickset(self.swap_fail))
        object.__setattr__(self, "admit_stall", _tickset(self.admit_stall))
        nan = {int(t): int(s) for t, s in dict(self.nan).items()}
        if any(t < 0 for t in nan):
            raise ValueError(f"nan ticks must be >= 0, got {sorted(nan)}")
        if any(s < 0 for s in nan.values()):
            raise ValueError(f"nan slots must be >= 0, got {nan}")
        object.__setattr__(self, "nan", nan)

    # ---- the seams the scheduler queries ---------------------------------
    def deny_alloc(self, tick: int) -> bool:
        """True when page allocation must fail at ``tick``."""
        return tick in self.alloc_fail

    def deny_swap(self, tick: int) -> bool:
        """True when swap-out parking must refuse at ``tick``."""
        return tick in self.swap_fail

    def deny_admission(self, tick: int) -> bool:
        """True when new admissions must stall at ``tick``."""
        return tick in self.admit_stall

    def nan_events(self) -> List[Tuple[int, int]]:
        """The (tick, slot) poison schedule, earliest tick first."""
        return sorted(self.nan.items())

    # ---- bookkeeping ------------------------------------------------------
    @property
    def empty(self) -> bool:
        """True when the plan injects nothing."""
        return not (self.alloc_fail or self.swap_fail or self.admit_stall
                    or self.nan)

    @property
    def max_tick(self) -> int:
        """The last tick any fault fires at (-1 for an empty plan)."""
        ticks = (list(self.alloc_fail) + list(self.swap_fail)
                 + list(self.admit_stall) + list(self.nan))
        return max(ticks) if ticks else -1

    # ---- (de)serialization ------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        """A JSON-serializable dict; ``from_json`` round-trips it."""
        return {
            "alloc_fail": sorted(self.alloc_fail),
            "swap_fail": sorted(self.swap_fail),
            "admit_stall": sorted(self.admit_stall),
            "nan": [[t, s] for t, s in self.nan_events()],
        }

    @classmethod
    def from_json(cls, obj: Dict[str, Any]) -> "FaultPlan":
        """Build a plan from a :meth:`to_json`-shaped dict."""
        known = {"alloc_fail", "swap_fail", "admit_stall", "nan"}
        extra = set(obj) - known
        if extra:
            raise ValueError(
                f"unknown FaultPlan keys {sorted(extra)} "
                f"(expected a subset of {sorted(known)})")
        nan = obj.get("nan", {})
        if isinstance(nan, (list, tuple)):
            nan = {int(t): int(s) for t, s in nan}
        return cls(alloc_fail=obj.get("alloc_fail", ()),
                   swap_fail=obj.get("swap_fail", ()),
                   admit_stall=obj.get("admit_stall", ()),
                   nan=nan)

    @classmethod
    def from_spec(cls, spec: str) -> "FaultPlan":
        """CLI entry point: ``spec`` is inline JSON (starts with ``{``) or
        the path of a JSON file holding a :meth:`to_json` dict."""
        text = spec.strip()
        if not text.startswith("{"):
            with open(spec, "r", encoding="utf-8") as fh:
                text = fh.read()
        return cls.from_json(json.loads(text))

    @classmethod
    def random(cls, seed: int, *, ticks: int, slots: int,
               alloc_rate: float = 0.05, swap_rate: float = 0.05,
               stall_rate: float = 0.05, nan_events: int = 1) -> "FaultPlan":
        """A seeded random plan over ``[0, ticks)``: each seam denies a tick
        with its rate, and ``nan_events`` poisons target random slots in
        ``[0, slots)``.  Same seed, same plan — the chaos suite's knob."""
        if ticks < 1:
            raise ValueError(f"ticks must be >= 1, got {ticks}")
        rng = np.random.default_rng(seed)
        draws = rng.random((3, ticks))
        nan: Dict[int, int] = {}
        for _ in range(nan_events):
            nan[int(rng.integers(0, ticks))] = int(rng.integers(0, slots))
        return cls(
            alloc_fail=np.flatnonzero(draws[0] < alloc_rate).tolist(),
            swap_fail=np.flatnonzero(draws[1] < swap_rate).tolist(),
            admit_stall=np.flatnonzero(draws[2] < stall_rate).tolist(),
            nan=nan)
