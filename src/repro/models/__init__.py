from repro.models.lm import CausalLM, EncDecLM  # noqa: F401
from repro.models.registry import build_model, get_config, list_archs  # noqa: F401
