"""--arch registry: id -> ArchConfig -> model."""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List

import jax.numpy as jnp

if TYPE_CHECKING:  # avoid circular import (configs.base imports models.lm)
    from repro.configs.base import ArchConfig

_MODULES = {
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "smollm-135m": "repro.configs.smollm_135m",
    "glm4-9b": "repro.configs.glm4_9b",
    "qwen2.5-14b": "repro.configs.qwen25_14b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe_42b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "mamba-130m": "repro.configs.mamba_130m",
}

_cache: Dict[str, "ArchConfig"] = {}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch_id: str) -> "ArchConfig":
    if arch_id not in _cache:
        smoke = arch_id.endswith("-smoke")
        base_id = arch_id[:-6] if smoke else arch_id
        if base_id not in _MODULES:
            raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
        import importlib

        cfg = importlib.import_module(_MODULES[base_id]).CONFIG
        _cache[arch_id] = cfg.smoke() if smoke else cfg
    return _cache[arch_id]


def build_model(arch_id: str, *, dtype=jnp.bfloat16, remat: str = "full",
                scan_layers: bool = True):
    return get_config(arch_id).build(dtype=dtype, remat=remat,
                                     scan_layers=scan_layers)
