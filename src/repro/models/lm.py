"""Model assemblies: decoder-only CausalLM (dense/MoE/SSM/hybrid/VLM) and
encoder-decoder (whisper).

Frontends for the ``[audio]``/``[vlm]`` archs are STUBS per the assignment:
``batch["embeds"]`` carries precomputed frame/patch embeddings (B, S_enc, D)
— the transformer backbone is the thing being built and sharded.

Vocab dims are padded up to a multiple of the TP degree (``vocab_padded``);
the loss and the serving argmax mask the padding tail, so padding never
changes semantics — only shardability.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import Embedding, LayerNorm, RMSNorm
from repro.nn.module import Context, Params
from repro.nn.transformer import Stack


def _final_norm(norm: str, d_model: int):
    return LayerNorm(d_model, name="final_ln") if norm == "ln" \
        else RMSNorm(d_model, name="final_norm")


def _set_xkv_slot(node, k, v, slot, length, *, layer_axis: bool):
    """Write projected cross K/V rows into one slot of an ``xkv`` cache node.

    ``k``/``v``: (1, S_row, Hkv, D) — or (L, 1, S_row, Hkv, D) when
    ``layer_axis`` (scan-stacked projections from a vmap over layer params).
    Sets ``xlen[slot] = length``; rows past ``S_row`` keep whatever they held
    (consumers mask on ``xlen``).
    """
    z = jnp.int32(0)
    if layer_axis:
        xk = jax.lax.dynamic_update_slice(
            node["xk"], k.astype(node["xk"].dtype), (z, slot, z, z, z))
        xv = jax.lax.dynamic_update_slice(
            node["xv"], v.astype(node["xv"].dtype), (z, slot, z, z, z))
        upd = jnp.full((node["xlen"].shape[0], 1), length, jnp.int32)
        xlen = jax.lax.dynamic_update_slice(node["xlen"], upd, (z, slot))
    else:
        xk = jax.lax.dynamic_update_slice(
            node["xk"], k.astype(node["xk"].dtype), (slot, z, z, z))
        xv = jax.lax.dynamic_update_slice(
            node["xv"], v.astype(node["xv"].dtype), (slot, z, z, z))
        xlen = jax.lax.dynamic_update_slice(
            node["xlen"], jnp.asarray(length, jnp.int32).reshape(1), (slot,))
    return {"xk": xk, "xv": xv, "xlen": xlen}


@dataclasses.dataclass(frozen=True)
class CausalLM:
    vocab: int                    # true vocabulary size
    vocab_padded: int             # padded for TP shardability
    d_model: int
    stack: Stack
    norm: str = "rms"
    tie_embeddings: bool = True
    logit_scale: float = 1.0
    dtype: Any = jnp.float32
    name: str = "lm"

    def _embed(self):
        return Embedding(self.vocab_padded, self.d_model, dtype=self.dtype,
                         name="embed")

    def init(self, key) -> Params:
        ke, ks, kn, kh = jax.random.split(key, 4)
        p: Params = {
            "embed": self._embed().init(ke),
            "stack": self.stack.init(ks),
            "final_norm": _final_norm(self.norm, self.d_model).init(kn),
        }
        if not self.tie_embeddings:
            from repro.nn.layers import Dense

            p["lm_head"] = Dense(self.d_model, self.vocab_padded, use_bias=False,
                                 dtype=self.dtype, name="lm_head").init(kh)
        return p

    def init_cache(self, batch: int, max_len: int, *, quantized_kv: bool = False,
                   kv_dtype=jnp.bfloat16, per_slot_len: bool = False,
                   page_size: Optional[int] = None,
                   num_pages: Optional[int] = None):
        return self.stack.init_cache(batch, max_len, quantized_kv=quantized_kv,
                                     kv_dtype=kv_dtype,
                                     per_slot_len=per_slot_len,
                                     page_size=page_size, num_pages=num_pages)

    # ---- forward -----------------------------------------------------------
    def apply(self, params: Params, tokens: Optional[jax.Array], ctx: Context, *,
              embeds: Optional[jax.Array] = None,
              cache: Optional[Dict[str, Any]] = None,
              positions: Optional[jax.Array] = None,
              decode: bool = False,
              chunk=None,
              ragged=None,
              logit_pos: Optional[jax.Array] = None,
              logit_rows: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
        """Returns (logits (B, S, vocab_padded), new_cache).

        ``chunk``: a KVChunk routing this forward as a chunked prefill into
        one slot of a per-slot cache (serve/engine.make_mixed_step).
        ``logit_pos``: compute logits at this single position only (returns
        (B, 1, V)) — serving prefills sample exactly one token, and the LM
        head over the padded vocab dwarfs the rest of a small-batch forward,
        so slicing *before* the head is the admission-path win for one-shot
        and chunked admission alike.
        ``ragged``: a RaggedBatch routing this forward as one flat (1, T)
        token batch over a per-slot cache (serve/engine.make_ragged_step);
        combine with ``logit_rows`` ((R,) int32 token indices) to compute
        logits only at the rows that sample a token (returns (B, R, V)).
        """
        ctx = ctx.scope(self.name)
        embedder = self._embed()
        if tokens is not None:
            x = embedder.apply(params["embed"], tokens, ctx)
            if embeds is not None:  # VLM: vision prefix + text tokens
                x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
        else:
            x = embeds.astype(self.dtype)
        x = ctx.constrain(x, "batch", "seq", None)

        x, new_cache = self.stack.apply(params["stack"], x, ctx, cache=cache,
                                        positions=positions, decode=decode,
                                        chunk=chunk, ragged=ragged)
        if logit_rows is not None:
            x = jnp.take(x, jnp.asarray(logit_rows, jnp.int32), axis=1)
        if logit_pos is not None:
            x = jax.lax.dynamic_slice_in_dim(
                x, jnp.asarray(logit_pos, jnp.int32), 1, axis=1)
        x = _final_norm(self.norm, self.d_model).apply(params["final_norm"], x, ctx)

        if self.tie_embeddings:
            logits = embedder.attend(params["embed"], x, ctx)
        else:
            from repro.nn.layers import Dense

            logits = Dense(self.d_model, self.vocab_padded, use_bias=False,
                           dtype=self.dtype, name="lm_head").apply(
                params["lm_head"], x, ctx)
        if self.logit_scale != 1.0:
            logits = logits * self.logit_scale
        logits = ctx.constrain(logits, "batch", None, "vocab")
        return logits.astype(jnp.float32), new_cache

    # ---- training loss -------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, jax.Array], ctx: Context,
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Next-token cross-entropy; labels < 0 are masked (padding)."""
        logits, _ = self.apply(params, batch["tokens"], ctx,
                               embeds=batch.get("embeds"))
        labels = batch["labels"]
        if "embeds" in batch and batch["embeds"] is not None \
                and batch.get("tokens") is not None:
            # vision prefix produces logits we don't score
            logits = logits[:, -labels.shape[1]:]
        mask = (labels >= 0).astype(jnp.float32)
        labels_safe = jnp.maximum(labels, 0)

        # padded-vocab tail never wins: mask it out of the normalizer
        v_iota = jax.lax.broadcasted_iota(jnp.int32, (self.vocab_padded,), 0)
        pad_mask = (v_iota >= self.vocab).astype(jnp.float32) * -1e9
        logits = logits + pad_mask

        lse = jax.nn.logsumexp(logits, axis=-1)
        # indicator-sum gather: take_along_axis backward is a scatter that the
        # SPMD partitioner materializes UNsharded over vocab (12.9 GiB/device
        # at smollm train_4k); the boolean-mask contraction is elementwise so
        # both directions stay vocab-sharded (§Perf iteration 0)
        v_pos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, self.vocab_padded), 2)
        indicator = (v_pos == labels_safe[..., None]).astype(logits.dtype)
        gold = jnp.sum(logits * indicator, axis=-1)
        nll = (lse - gold) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll) / denom
        aux = jnp.asarray(0.0, jnp.float32)
        for v in ctx.losses.values():
            aux = aux + v
        acc = jnp.sum((jnp.argmax(logits, -1) == labels_safe) * mask) / denom
        return loss + aux, {"nll": loss, "aux": aux, "accuracy": acc}


@dataclasses.dataclass(frozen=True)
class EncDecLM:
    """Encoder-decoder (whisper-style). Encoder input is stub frame embeddings.

    ``enc_len`` (the config's encoder sequence ceiling) sizes the per-slot
    cross-attention K/V cache for serving; ``None`` disables it and decode
    re-projects ``enc`` every step (the pre-cache behavior).
    """

    vocab: int
    vocab_padded: int
    d_model: int
    encoder: Stack
    decoder: Stack
    max_target_len: int = 448
    norm: str = "ln"
    enc_len: Optional[int] = None
    dtype: Any = jnp.float32
    name: str = "encdec"

    def _embed(self):
        return Embedding(self.vocab_padded, self.d_model, dtype=self.dtype,
                         name="embed")

    def init(self, key) -> Params:
        ks = jax.random.split(key, 6)
        return {
            "embed": self._embed().init(ks[0]),
            "pos_embed": {"table": 0.02 * jax.random.normal(
                ks[1], (self.max_target_len, self.d_model), jnp.float32)},
            "encoder": self.encoder.init(ks[2]),
            "enc_norm": _final_norm(self.norm, self.d_model).init(ks[3]),
            "decoder": self.decoder.init(ks[4]),
            "final_norm": _final_norm(self.norm, self.d_model).init(ks[5]),
        }

    def init_cache(self, batch: int, max_len: int, *, quantized_kv: bool = False,
                   kv_dtype=jnp.bfloat16, per_slot_len: bool = False,
                   page_size: Optional[int] = None,
                   num_pages: Optional[int] = None,
                   cross_attn_cache: bool = True):
        """Decoder caches; per-slot serving caches grow ``xkv`` cross-attn
        nodes (sized by ``enc_len``) unless ``cross_attn_cache=False``.
        """
        enc_len = self.enc_len if (cross_attn_cache and per_slot_len) else None
        return self.decoder.init_cache(batch, max_len, quantized_kv=quantized_kv,
                                       kv_dtype=kv_dtype,
                                       per_slot_len=per_slot_len,
                                       page_size=page_size, num_pages=num_pages,
                                       enc_len=enc_len)

    def encode(self, params: Params, embeds: jax.Array, ctx: Context) -> jax.Array:
        ctx = ctx.scope(self.name)
        s = embeds.shape[1]
        # sinusoidal positions (whisper encoder)
        pos = jnp.arange(s)[:, None]
        dim = jnp.arange(self.d_model // 2)[None, :]
        ang = pos / jnp.power(10000.0, 2 * dim / self.d_model)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = embeds.astype(self.dtype) + pe.astype(self.dtype)
        x, _ = self.encoder.apply(params["encoder"], x, ctx)
        return _final_norm(self.norm, self.d_model).apply(params["enc_norm"], x, ctx)

    def write_cross_kv(self, params: Params, cache, enc_row: jax.Array,
                       slot: jax.Array, ctx: Context):
        """Project one slot's encoder rows into every cross block's xkv cache.

        ``enc_row``: (1, S_row, D) — the slot's (already encoded) encoder
        output, S_row <= ``enc_len``.  Runs each cross-attention layer's K/V
        projection ONCE and scatters the rows into slot ``slot`` of the
        per-layer ``xkv`` nodes (scan-stacked layers project under ``vmap``
        over the stacked params), setting ``xlen[slot] = S_row``.  Decode
        steps then read the cached rows (``Attention.apply(cross_cache=...)``)
        instead of re-projecting ``enc`` — the admission-time half of the
        cached-cross-attention trade.  Jitted by the scheduler with the cache
        donated; layers without an ``xkv`` node pass through untouched.
        """
        ctx = ctx.scope(self.name)
        sctx = ctx.scope(self.decoder.name)
        slot = jnp.asarray(slot, jnp.int32)
        length = jnp.int32(enc_row.shape[1])
        dec = self.decoder
        new_cache = dict(cache)
        if dec.prelude and cache.get("prelude"):
            pres = []
            for i, blk in enumerate(dec.prelude):
                c = cache["prelude"][i]
                if blk.cross and isinstance(c, dict) and "xkv" in c:
                    bctx = sctx.scope(f"pre{i}").scope(blk.name)
                    k, v = blk._xattn().project_kv(
                        params["decoder"]["prelude"][i]["xattn"], enc_row, bctx)
                    c = dict(c, xkv=_set_xkv_slot(c["xkv"], k, v, slot, length,
                                                  layer_axis=False))
                pres.append(c)
            new_cache["prelude"] = pres
        stacked = dec.scan_layers and dec.n_periods > 1
        bodies = []
        for i, c in enumerate(cache["body"]):
            blk = dec.body[i % len(dec.body)]
            if not (blk.cross and isinstance(c, dict) and "xkv" in c):
                bodies.append(c)
                continue
            p_x = params["decoder"]["body"][i]["xattn"]
            bctx = sctx.scope(f"p{i}" if stacked else f"l{i}").scope(blk.name)
            if stacked:
                k, v = jax.vmap(
                    lambda pl: blk._xattn().project_kv(pl, enc_row, bctx))(p_x)
            else:
                k, v = blk._xattn().project_kv(p_x, enc_row, bctx)
            bodies.append(dict(c, xkv=_set_xkv_slot(c["xkv"], k, v, slot,
                                                    length, layer_axis=stacked)))
        new_cache["body"] = bodies
        return new_cache

    def _decoder_len(self, cache):
        """Live length of the decoder's self-attention cache (first KV leaf).

        Scan-stacked decoder caches carry a leading layer axis on ``len``
        whose rows are identical (one logical length per slot), so the first
        layer's row stands for all.  Stackedness is decided by *where* the
        leaf was found: prelude entries are never stacked, body entries are
        iff the Stack scans its layers — a prelude without any KV cache
        (non-attention mixers) must not hide a stacked body leaf.
        Returns None when the tree holds no KV dict (stateless decoders).
        """
        def find(node):
            if isinstance(node, dict):
                if "k" in node and "len" in node:
                    return node["len"]
                for v in node.values():
                    out = find(v)
                    if out is not None:
                        return out
            elif isinstance(node, (list, tuple)):
                for v in node:
                    out = find(v)
                    if out is not None:
                        return out
            return None

        if isinstance(cache, dict):
            ln = find(cache.get("prelude"))
            if ln is not None:
                return ln
        ln = find(cache.get("body") if isinstance(cache, dict) else cache)
        if ln is None:
            return None
        stacked = self.decoder.scan_layers and self.decoder.n_periods > 1
        return ln[0] if stacked else ln

    def decode_step(self, params: Params, tokens: jax.Array, enc: jax.Array,
                    ctx: Context, *, cache=None, positions=None, decode=False,
                    chunk=None, ragged=None, logit_pos=None,
                    logit_rows=None) -> Tuple[jax.Array, Any]:
        ctx = ctx.scope(self.name)
        x = self._embed().apply(params["embed"], tokens, ctx)
        if positions is None:
            if ragged is not None:
                # ragged tick: each token carries its own absolute position
                # into the learned table (pads clamp to 0 — never sampled)
                positions = jnp.maximum(
                    jnp.asarray(ragged.positions, jnp.int32), 0)[None, :]
            elif chunk is not None:
                # chunked prefill: the chunk's tokens sit at absolute
                # positions start..start+C-1 in the learned position table
                positions = jnp.asarray(chunk.start, jnp.int32) \
                    + jnp.arange(tokens.shape[1])
            elif decode and cache is not None:
                # incremental decode: new rows sit at the cache's live
                # length, NOT at 0..S-1 — without this every generated token
                # read the position-0 embedding (per-slot ``len`` vectors
                # give each batch slot its own offset)
                ln = self._decoder_len(cache)
                if ln is None:
                    positions = jnp.arange(tokens.shape[1])
                elif jnp.ndim(ln) == 1:
                    positions = ln[:, None] + jnp.arange(tokens.shape[1])[None, :]
                else:
                    positions = ln + jnp.arange(tokens.shape[1])
            else:
                positions = jnp.arange(tokens.shape[1])
        ptab = params["pos_embed"]["table"]
        x = x + jnp.take(ptab, jnp.clip(positions, 0, ptab.shape[0] - 1),
                         axis=0).astype(x.dtype)
        x, new_cache = self.decoder.apply(params["decoder"], x, ctx, cache=cache,
                                          enc=enc, decode=decode, chunk=chunk,
                                          ragged=ragged)
        if logit_rows is not None:
            x = jnp.take(x, jnp.asarray(logit_rows, jnp.int32), axis=1)
        if logit_pos is not None:
            x = jax.lax.dynamic_slice_in_dim(
                x, jnp.asarray(logit_pos, jnp.int32), 1, axis=1)
        x = _final_norm(self.norm, self.d_model).apply(params["final_norm"], x, ctx)
        logits = self._embed().attend(params["embed"], x, ctx)
        logits = ctx.constrain(logits, "batch", None, "vocab")
        return logits.astype(jnp.float32), new_cache

    def apply(self, params: Params, tokens, ctx: Context, *, embeds=None,
              cache=None, positions=None, decode=False, enc=None, chunk=None,
              ragged=None, logit_pos=None, logit_rows=None):
        """CausalLM-compatible signature; encodes unless `enc` is given."""
        if enc is None:
            enc = self.encode(params, embeds, ctx)
        return self.decode_step(params, tokens, enc, ctx, cache=cache,
                                positions=positions, decode=decode,
                                chunk=chunk, ragged=ragged,
                                logit_pos=logit_pos, logit_rows=logit_rows)

    def loss(self, params: Params, batch: Dict[str, jax.Array], ctx: Context):
        logits, _ = self.apply(params, batch["tokens"], ctx,
                               embeds=batch["embeds"])
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        labels_safe = jnp.maximum(labels, 0)
        v_iota = jax.lax.broadcasted_iota(jnp.int32, (self.vocab_padded,), 0)
        logits = logits + (v_iota >= self.vocab).astype(jnp.float32) * -1e9
        lse = jax.nn.logsumexp(logits, axis=-1)
        v_pos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, self.vocab_padded), 2)
        indicator = (v_pos == labels_safe[..., None]).astype(logits.dtype)
        gold = jnp.sum(logits * indicator, axis=-1)
        nll = (lse - gold) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll) / denom
        aux = jnp.asarray(0.0, jnp.float32)
        for v in ctx.losses.values():
            aux = aux + v
        acc = jnp.sum((jnp.argmax(logits, -1) == labels_safe) * mask) / denom
        return loss + aux, {"nll": loss, "aux": aux, "accuracy": acc}
