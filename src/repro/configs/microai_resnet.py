"""The paper's own evaluation network: ResNetv1-6 (Fig. 4) over the three
dataset shapes (UCI-HAR / SMNIST / GTSRB).  Not part of the 40-cell LM matrix;
used by the paper-claims benchmarks and the engine-compare study."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.nn.resnet import ResNetV1_6


@dataclasses.dataclass(frozen=True)
class MicroAIDataset:
    name: str
    in_shape: Tuple[int, ...]     # per-sample (samples, channels) / (H, W, C)
    classes: int
    ndim: int


DATASETS = {
    "uci-har": MicroAIDataset("uci-har", (128, 9), 6, 1),
    "smnist": MicroAIDataset("smnist", (39, 13), 10, 1),
    "gtsrb": MicroAIDataset("gtsrb", (32, 32, 3), 43, 2),
}


def build_resnet(dataset: str = "uci-har", filters: int = 16,
                 dtype=jnp.float32) -> ResNetV1_6:
    ds = DATASETS[dataset]
    return ResNetV1_6(in_channels=ds.in_shape[-1], filters=filters,
                      classes=ds.classes, ndim=ds.ndim, dtype=dtype)
