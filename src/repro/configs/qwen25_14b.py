"""Qwen2.5-14B — dense GQA kv=8, QKV bias, untied head. [hf:Qwen/Qwen2.5; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2.5-14b",
    family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab=152064,
    layout="a", qkv_bias=True, norm="rms", activation="silu",
    ffn_kind="gated", tie_embeddings=False,
    notes="QKV bias quantized at accumulator width (paper Sec. 5.8); "
          "40 heads not TP16-divisible -> flat-dim sharding fallback",
)
