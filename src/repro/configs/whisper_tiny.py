"""Whisper-tiny — enc-dec, conv frontend stubbed to precomputed frame
embeddings. [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-tiny",
    family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab=51865,
    layout="a", enc_layers=4, enc_seq=1500,
    norm="ln", activation="gelu", ffn_kind="mlp", use_rope=False,
    tie_embeddings=True,
    notes="MHA (kv=heads); learned decoder positions; sinusoidal encoder "
          "positions; frontend = input_specs() frame-embedding stub",
)
