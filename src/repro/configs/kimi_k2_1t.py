"""Kimi K2 — trillion-param MoE: 61L, 384 experts top-8 + 1 shared, first
layer dense. [arXiv:2501.kimi2; unverified, paper-table]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab=163840,
    layout="a", n_experts=384, top_k=8, n_shared_experts=1,
    moe_every=1, moe_offset=0, first_k_dense=1, d_ff_dense=18432,
    norm="rms", activation="silu", ffn_kind="gated", tie_embeddings=False,
    notes="EP: 24 experts/device on TP16; int8 weights are what makes 1T "
          "params servable in 512x16GiB (DESIGN.md flagship memory win)",
)
