"""InternVL2-2B — InternLM2-1.8B backbone + InternViT stub patch embeddings.
[arXiv:2404.16821; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="internvl2-2b",
    family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92553,
    layout="a", vis_seq=256,
    norm="rms", activation="silu", ffn_kind="gated", tie_embeddings=True,
    notes="vision prefix = 256 stub patch embeddings prepended to the text "
          "tokens; only text logits are scored",
)
