"""ArchConfig: declarative architecture description → model instance + specs.

One instance per assigned architecture lives in ``configs/<id>.py`` with the
exact published numbers.  ``smoke()`` derives the reduced same-family config
used by the CPU smoke tests; ``build()`` assembles the Stack/CausalLM/EncDec;
``input_specs()`` yields ShapeDtypeStruct stand-ins for the dry-run.

Layer layout is a period string over {'a': attention, 'm': mamba, 'r': rwkv6}
repeated ``n_layers/len(layout)`` times (jamba: "mmmammmm").  MoE placement:
``moe_every=k, moe_offset=o`` puts MoE at global layer indices i ≡ o (mod k);
``first_k_dense`` peels leading dense layers out of the scan (kimi-k2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.lm import CausalLM, EncDecLM
from repro.nn.transformer import Block, Stack

# --------------------------------------------------------------------------
# Shapes (assigned): every LM arch is paired with these four cells.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Families with sub-quadratic decode state run long_500k; pure full-attention
# archs skip it (DESIGN.md §5 records the skip rationale).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def pad_vocab(v: int, multiple: int = 256) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    layout: str = "a"              # period string over {a, m, r}
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_every: int = 0             # 0 = no MoE
    moe_offset: int = 0
    first_k_dense: int = 0
    d_ff_dense: int = 0            # dense-FFN width where it differs (kimi)
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    # block structure
    norm: str = "rms"
    parallel_block: bool = False
    activation: str = "silu"
    ffn_kind: str = "gated"        # gated | mlp | rwkv
    tie_embeddings: bool = True
    # enc-dec (audio)
    enc_layers: int = 0
    enc_seq: int = 1500            # stub frontend output length (whisper frames)
    # vlm
    vis_seq: int = 0               # stub vision-prefix length
    # bookkeeping
    notes: str = ""

    # ----------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def supports(self, shape_name: str) -> bool:
        if shape_name == "long_500k":
            return self.family in LONG_CONTEXT_FAMILIES
        return shape_name in SHAPES

    # ----------------------------------------------------------------------
    def _block(self, layer_idx: int, mixer_ch: str, dtype, causal=True) -> Block:
        is_moe = (self.moe_every > 0
                  and layer_idx >= self.first_k_dense
                  and (layer_idx % self.moe_every) == self.moe_offset)
        mixer = {"a": "attn", "m": "mamba", "r": "rwkv"}[mixer_ch]
        if is_moe:
            ffn, d_ff = "moe", self.d_ff
        else:
            ffn = self.ffn_kind
            d_ff = (self.d_ff_dense or self.d_ff)
        return Block(
            d_model=self.d_model, mixer=mixer,
            n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim, qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta, use_rope=self.use_rope, causal=causal,
            ffn=ffn, d_ff=d_ff, activation=self.activation,
            n_experts=self.n_experts, top_k=self.top_k,
            n_shared_experts=self.n_shared_experts,
            norm=self.norm, parallel=self.parallel_block, dtype=dtype)

    def _stack(self, dtype, remat: str, scan_layers: bool) -> Stack:
        period = len(self.layout)
        assert (self.n_layers - self.first_k_dense) % period == 0, self.arch_id
        prelude = tuple(self._block(i, self.layout[i % period], dtype)
                        for i in range(self.first_k_dense))
        body = tuple(self._block(self.first_k_dense + p, self.layout[p], dtype)
                     for p in range(period))
        return Stack(body=body,
                     n_periods=(self.n_layers - self.first_k_dense) // period,
                     prelude=prelude, remat=remat, scan_layers=scan_layers)

    def build(self, *, dtype=jnp.bfloat16, remat: str = "full",
              scan_layers: bool = True):
        if self.is_encdec:
            enc_block = Block(
                d_model=self.d_model, mixer="attn", n_heads=self.n_heads,
                n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
                use_rope=False, causal=False, ffn="mlp", d_ff=self.d_ff,
                activation="gelu", norm=self.norm, dtype=dtype)
            dec_block = Block(
                d_model=self.d_model, mixer="attn", n_heads=self.n_heads,
                n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
                use_rope=False, causal=True, cross=True, ffn="mlp",
                d_ff=self.d_ff, activation="gelu", norm=self.norm, dtype=dtype)
            return EncDecLM(
                vocab=self.vocab, vocab_padded=self.vocab_padded,
                d_model=self.d_model,
                encoder=Stack(body=(enc_block,), n_periods=self.enc_layers,
                              remat=remat, scan_layers=scan_layers),
                decoder=Stack(body=(dec_block,), n_periods=self.n_layers,
                              remat=remat, scan_layers=scan_layers),
                max_target_len=SHAPES["decode_32k"].seq_len,
                norm=self.norm, enc_len=self.enc_seq, dtype=dtype)
        return CausalLM(
            vocab=self.vocab, vocab_padded=self.vocab_padded,
            d_model=self.d_model, stack=self._stack(dtype, remat, scan_layers),
            norm=self.norm, tie_embeddings=self.tie_embeddings, dtype=dtype)

    # ----------------------------------------------------------------------
    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        period = len(self.layout)
        d_model = 64
        n_heads = 4
        n_kv = min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else n_heads
        return dataclasses.replace(
            self,
            arch_id=self.arch_id + "-smoke",
            n_layers=self.first_k_dense + period * (2 if period == 1 else 1),
            d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv, head_dim=16,
            d_ff=128, d_ff_dense=128 if self.d_ff_dense else 0, vocab=503,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            vis_seq=min(self.vis_seq, 8) if self.vis_seq else 0,
            enc_seq=16 if self.enc_layers else self.enc_seq,
        )

    # ----------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic total parameter count (embedding included, true vocab)."""
        d, f = self.d_model, self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        period = len(self.layout)
        for i in range(self.n_layers):
            ch = self.layout[(i - self.first_k_dense) % period] \
                if i >= self.first_k_dense else self.layout[i % period]
            if ch == "a":
                qd = self.n_heads * self.head_dim
                kvd = self.n_kv_heads * self.head_dim
                total += d * (qd + 2 * kvd) + qd * d
            elif ch == "m":
                di = 2 * d
                dtr = max(1, math.ceil(d / 16))
                total += d * 2 * di + di * (dtr + 32) + dtr * di + di * d
            elif ch == "r":
                total += 5 * d * d
            is_moe = (self.moe_every > 0 and i >= self.first_k_dense
                      and (i % self.moe_every) == self.moe_offset)
            if is_moe:
                total += self.n_experts * 3 * d * f
                total += self.n_shared_experts * 3 * d * f
            elif ch == "r":
                total += 2 * d * self.d_ff + d * d
            else:
                ff = self.d_ff_dense or f
                n_mats = 3 if self.ffn_kind == "gated" else 2
                total += n_mats * d * ff
        if self.is_encdec:
            total += self.enc_layers * (4 * d * d + 2 * d * f)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared of E experts)."""
        if not self.moe_every:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = sum(
            1 for i in range(self.first_k_dense, self.n_layers)
            if (i % self.moe_every) == self.moe_offset)
        inactive = n_moe_layers * (self.n_experts - self.top_k) * 3 \
            * self.d_model * self.d_ff
        return full - inactive

    # ----------------------------------------------------------------------
    def input_specs(self, shape_name: str, *, dtype=jnp.bfloat16,
                    ) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell.

        train:   tokens/labels (B, S) (+ embeds stub for audio/vlm)
        prefill: tokens (B, S)
        decode:  tokens (B, 1) + KV/state cache sized for S
        """
        sh = SHAPES[shape_name]
        if not self.supports(shape_name):
            raise ValueError(f"{self.arch_id} skips {shape_name}")
        B, S = sh.global_batch, sh.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct

        if sh.kind == "train":
            if self.is_encdec:
                return {"embeds": sds((B, self.enc_seq, self.d_model), dtype),
                        "tokens": sds((B, S), i32),
                        "labels": sds((B, S), i32)}
            if self.vis_seq:
                return {"embeds": sds((B, self.vis_seq, self.d_model), dtype),
                        "tokens": sds((B, S - self.vis_seq), i32),
                        "labels": sds((B, S - self.vis_seq), i32)}
            return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}

        if sh.kind == "prefill":
            out = {"tokens": sds((B, S), i32)}
            if self.is_encdec:
                out["embeds"] = sds((B, self.enc_seq, self.d_model), dtype)
            if self.vis_seq:
                out["embeds"] = sds((B, self.vis_seq, self.d_model), dtype)
                out["tokens"] = sds((B, S - self.vis_seq), i32)
            return out

        # decode: one new token against an S-token cache
        model = self.build(dtype=dtype)
        cache = jax.eval_shape(
            lambda: model.init_cache(B, S, quantized_kv=False, kv_dtype=dtype))
        out = {"tokens": sds((B, 1), i32), "cache": cache}
        if self.is_encdec:
            out["enc"] = sds((B, self.enc_seq, self.d_model), dtype)
        return out
