"""RWKV-6 "Finch" 7B — attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6-7b",
    family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab=65536,
    layout="r", norm="ln", ffn_kind="rwkv", tie_embeddings=True,
    notes="attention-free: KV-cache quantization inapplicable (state matrix "
          "fp32); paper technique covers 100% of GEMM FLOPs; runs long_500k",
)
