"""Jamba v0.1 52B — Mamba+attention 1:7 interleave, MoE 16e top-2 every other
layer. [arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    layout="mmmammmm",             # attention at period position 3 (1:7)
    n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    use_rope=False,                # jamba: no positional encoding in attn
    norm="rms", activation="silu", ffn_kind="gated", tie_embeddings=True,
    notes="SSM state fp32 (ssm_state in skip_kinds); runs long_500k",
)
