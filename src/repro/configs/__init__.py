from repro.configs.base import LONG_CONTEXT_FAMILIES, SHAPES, ArchConfig, ShapeSpec  # noqa: F401
