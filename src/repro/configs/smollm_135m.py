"""SmolLM-135M — llama-arch small dense GQA. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="smollm-135m",
    family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, head_dim=64,
    d_ff=1536, vocab=49152,
    layout="a", norm="rms", activation="silu", ffn_kind="gated",
    tie_embeddings=True,
    notes="9 heads is not TP16-divisible: head-axis constraints fall back to "
          "flat-dim sharding (dist/sharding.py divisibility rule)",
)
