"""Phi-3.5-MoE 42B (6.6B active) — 16 experts top-2 every layer.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab=32064,
    layout="a", n_experts=16, top_k=2, moe_every=1, moe_offset=0,
    norm="ln", activation="silu", ffn_kind="gated", tie_embeddings=False,
    notes="EP: 1 expert/device on the 16-way model axis; router kept fp32",
)
