"""Mamba 130M — pure selective-SSM stack at smollm scale; the smallest
servable recurrent config (constant per-slot state, no KV cache).
[arXiv:2312.00752; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="mamba-130m",
    family="ssm",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=2048, vocab=50280,
    layout="m", norm="rms", ffn_kind="gated", tie_embeddings=True,
    notes="attention-free: per-slot decode state is a fixed (d_inner, "
          "d_state) matrix + conv tail (serve/slot_state.py RecurrentState) "
          "— bytes/slot constant in sequence length; serves through the "
          "chunked continuous-batching loop",
)
