"""Cohere Command R+ 104B — dense GQA, parallel blocks, no biases.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="command-r-plus-104b",
    family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab=256000,
    layout="a", qkv_bias=False, norm="ln", parallel_block=True,
    activation="silu", ffn_kind="gated", tie_embeddings=True,
    rope_theta=75_000_000.0,
    notes="command-r parallel attn+FFN block; LayerNorm; tied embeddings",
)
