"""Core layers with three execution paths, mirroring the paper's engine:

1. **float / fake-quant** — training, QAT (Sec. 4.3) and PTQ evaluation:
   inputs, weights and biases are constrained to the Qm.n grid (in float),
   outputs re-quantized after the computation (paper Fig. 2).
2. **full integer** — the deployed inference engine (Sec. 5.8): int8/int16
   operands, int32 accumulators, exact bit-shift requantization, saturation.
   Activations flow between layers as :class:`QTensor`.
3. **weight-only integer** — TPU serving adaptation for the large archs:
   int8 weights dequantized on the fly (Pallas ``wq_matmul``), bf16/f32
   activations.  (DESIGN.md §2.)

Layer params are nested dicts; layers are frozen dataclasses with
``init(key) -> params`` and ``apply(params, x, ctx) -> y``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qformat
from repro.core.policy import QMode
from repro.core.qformat import PackedQTensor, QTensor
from repro.core.quantizers import quantize_activation, quantize_weight
from repro.nn.module import Context, Params

# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------

def lecun_normal(key, shape, dtype=jnp.float32, fan_in_axes=None):
    """Truncated-normal initializer with variance ``1/fan_in``."""
    if fan_in_axes is None:
        fan_in = shape[0] if len(shape) > 1 else shape[0]
        if len(shape) > 2:  # conv kernels: all but the last axis feed in
            fan_in = math.prod(shape[:-1])
    else:
        fan_in = math.prod(shape[a] for a in fan_in_axes)
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def normal_init(key, shape, std=0.02, dtype=jnp.float32):
    """Gaussian initializer with fixed standard deviation."""
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    """All-zeros initializer."""
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype=jnp.float32):
    """All-ones initializer."""
    return jnp.ones(shape, dtype)


# --------------------------------------------------------------------------
# Quant plumbing shared by compute layers
# --------------------------------------------------------------------------

def _fq_in(x, ctx: Context, site: str):
    """Fake-quantize a layer input per the active policy (paper Fig. 2)."""
    pol = ctx.policy
    if not pol.enabled or pol.mode is QMode.INTEGER:
        return x
    if ctx.collecting:
        ctx.record(site, x)
    if pol.mode is QMode.CALIB:
        return x
    return quantize_activation(x, pol, frozen_n=ctx.frozen(site))


def _fq_out(y, ctx: Context, site: str):
    """Fake-quantize a layer output after computation (paper Fig. 2)."""
    return _fq_in(y, ctx, site)


def _fq_weight(w, ctx: Context, *, channel_axis: int):
    pol = ctx.policy
    if not pol.enabled or pol.mode in (QMode.INTEGER, QMode.CALIB):
        return w
    return quantize_weight(w, pol, channel_axis=channel_axis)


def _fq_bias(b, ctx: Context):
    pol = ctx.policy
    if b is None or not pol.enabled or pol.mode in (QMode.INTEGER, QMode.CALIB):
        return b
    return quantize_weight(b, pol, channel_axis=None)


def _nout_for(params: Params, ctx: Context, site: str) -> jax.Array:
    """Frozen output exponent for the integer engine (from calibration)."""
    if "n_out" in params:
        return params["n_out"]
    n = ctx.frozen(site)
    if n is None:
        raise ValueError(
            f"integer mode needs a calibrated output exponent for site {ctx.key(site)!r}"
        )
    return n


def _broadcast_channel_n(n: jax.Array, ndim: int, axis: int) -> jax.Array:
    if jnp.ndim(n) == 0:
        return n
    shape = [1] * ndim
    shape[axis] = -1
    return n.reshape(shape)


# --------------------------------------------------------------------------
# Dense
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Dense:
    """Affine projection dispatching float / fake-quant / integer GEMMs
    by the context's quantization policy.
    """
    in_features: int
    out_features: int
    use_bias: bool = True
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    name: str = "dense"
    kind: str = "gemm"  # matched against QuantPolicy.skip_kinds

    def init(self, key) -> Params:
        """Create the kernel (and optional bias) parameters."""
        kw, kb = jax.random.split(key)
        p: Params = {"kernel": lecun_normal(kw, (self.in_features, self.out_features),
                                            self.param_dtype)}
        if self.use_bias:
            p["bias"] = zeros_init(kb, (self.out_features,), self.param_dtype)
        return p

    def apply(self, params: Params, x, ctx: Context):
        """Project ``x`` under the context's quantization policy."""
        ctx = ctx.scope(self.name)
        kernel = params["kernel"]
        bias = params.get("bias")
        skip = self.kind in ctx.policy.skip_kinds

        # ---- integer / weight-only paths --------------------------------
        if isinstance(kernel, PackedQTensor):
            return self._packed_apply(kernel, bias, x)
        if isinstance(kernel, QTensor):
            if isinstance(x, QTensor):
                return self._integer_apply(params, x, ctx)
            return self._weight_only_apply(kernel, bias, x)

        # ---- float / fake-quant path -------------------------------------
        if skip or not ctx.policy.enabled:
            w = kernel.astype(self.dtype)
            y = jnp.matmul(x.astype(self.dtype), w)
            if bias is not None:
                y = y + bias.astype(self.dtype)
            return y
        xq = _fq_in(x, ctx, "in")
        w = _fq_weight(kernel, ctx, channel_axis=-1)
        y = jnp.matmul(xq.astype(self.dtype), w.astype(self.dtype))
        b = _fq_bias(bias, ctx)
        if b is not None:
            y = y + b.astype(self.dtype)
        return _fq_out(y, ctx, "out")

    # ---- paper's deployed engine: int operands, int32 acc, shift, saturate
    def _integer_apply(self, params: Params, x: QTensor, ctx: Context) -> QTensor:
        kernel: QTensor = params["kernel"]
        bias = params.get("bias")
        width = ctx.policy.act_bits
        from repro.kernels import ops as kops  # local import; kernels are optional

        acc = kops.qmm(x.q, kernel.q)  # int32 accumulator
        n_w = _broadcast_channel_n(kernel.n, acc.ndim, -1)
        n_acc = x.n + n_w
        if bias is not None and isinstance(bias, QTensor):
            b = qformat.align(bias.q, bias.n, n_acc, jnp.int32)
            acc = acc + b
        n_out = _nout_for(params, ctx, "out")
        yq = qformat.requantize(acc, n_acc, n_out, width)
        return QTensor(yq, n_out, width)

    # ---- TPU serving path: int8 weights, float activations
    def _weight_only_apply(self, kernel: QTensor, bias, x):
        from repro.kernels import ops as kops

        y = kops.wq_matmul(x.astype(self.dtype), kernel)
        if bias is not None:
            b = bias.dequantize() if isinstance(bias, QTensor) else bias
            y = y + b.astype(y.dtype)
        return y

    # ---- sub-int8 serving path: packed int4/int2 weights, float activations
    def _packed_apply(self, kernel: PackedQTensor, bias, x):
        from repro.kernels import ops as kops

        y = kops.wq4_matmul(x.astype(self.dtype), kernel)
        if bias is not None:
            b = bias.dequantize() if isinstance(bias, QTensor) else bias
            y = y + b.astype(y.dtype)
        return y


# --------------------------------------------------------------------------
# Convolutions (paper's primary compute layer, Sec. 5.6: Conv1D; 2D for GTSRB)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvND:
    """N-d convolution, channels-last (NWC / NHWC)."""

    ndim: int
    in_channels: int
    out_channels: int
    kernel_size: Tuple[int, ...]
    strides: Tuple[int, ...]
    padding: str = "SAME"
    use_bias: bool = True
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    name: str = "conv"
    kind: str = "conv"
    feature_group_count: int = 1

    def _dn(self):
        if self.ndim == 1:
            return jax.lax.conv_dimension_numbers(
                (1, 1, self.in_channels), (*self.kernel_size, self.in_channels, self.out_channels),
                ("NWC", "WIO", "NWC"))
        return jax.lax.conv_dimension_numbers(
            (1, 1, 1, self.in_channels), (*self.kernel_size, self.in_channels, self.out_channels),
            ("NHWC", "HWIO", "NHWC"))

    def init(self, key) -> Params:
        """Create the convolution kernel (and optional bias) parameters."""
        kw, kb = jax.random.split(key)
        kshape = (*self.kernel_size, self.in_channels // self.feature_group_count,
                  self.out_channels)
        p: Params = {"kernel": lecun_normal(kw, kshape, self.param_dtype)}
        if self.use_bias:
            p["bias"] = zeros_init(kb, (self.out_channels,), self.param_dtype)
        return p

    def _conv(self, x, w, preferred=None):
        return jax.lax.conv_general_dilated(
            x, w, window_strides=self.strides, padding=self.padding,
            dimension_numbers=self._dn(), feature_group_count=self.feature_group_count,
            preferred_element_type=preferred)

    def apply(self, params: Params, x, ctx: Context):
        """Convolve ``x`` under the context's quantization policy."""
        ctx = ctx.scope(self.name)
        kernel = params["kernel"]
        bias = params.get("bias")

        if isinstance(kernel, (QTensor, PackedQTensor)):
            if isinstance(x, QTensor) and isinstance(kernel, QTensor):
                return self._integer_apply(params, x, ctx)
            # weight-only serving (packed sub-int8 included): conv has no
            # packed kernel, so dequantize the weight and convolve in float.
            w = kernel.dequantize().astype(self.dtype)
            y = self._conv(x.astype(self.dtype), w)
            if bias is not None:
                b = bias.dequantize() if isinstance(bias, QTensor) else bias
                y = y + b.astype(y.dtype)
            return y

        if not ctx.policy.enabled or self.kind in ctx.policy.skip_kinds:
            y = self._conv(x.astype(self.dtype), kernel.astype(self.dtype))
            if bias is not None:
                y = y + bias.astype(self.dtype)
            return y
        xq = _fq_in(x, ctx, "in")
        w = _fq_weight(kernel, ctx, channel_axis=-1)
        y = self._conv(xq.astype(self.dtype), w.astype(self.dtype))
        b = _fq_bias(bias, ctx)
        if b is not None:
            y = y + b.astype(self.dtype)
        return _fq_out(y, ctx, "out")

    def _integer_apply(self, params: Params, x: QTensor, ctx: Context) -> QTensor:
        kernel: QTensor = params["kernel"]
        bias = params.get("bias")
        width = ctx.policy.act_bits
        from repro.kernels import ops as kops

        if self.ndim == 1 and self.feature_group_count == 1:
            acc = kops.qconv1d(x.q, kernel.q, strides=self.strides[0], padding=self.padding)
        else:
            acc = self._conv(x.q.astype(jnp.int32), kernel.q.astype(jnp.int32))
        n_w = _broadcast_channel_n(kernel.n, acc.ndim, -1)
        n_acc = x.n + n_w
        if bias is not None and isinstance(bias, QTensor):
            acc = acc + qformat.align(bias.q, bias.n, n_acc, jnp.int32)
        n_out = _nout_for(params, ctx, "out")
        yq = qformat.requantize(acc, n_acc, n_out, width)
        return QTensor(yq, n_out, width)


def Conv1D(in_channels, out_channels, kernel_size, stride=1, padding="SAME", **kw):
    """``ConvND`` over one spatial dim (paper's sensor time series)."""
    return ConvND(1, in_channels, out_channels, (kernel_size,), (stride,), padding, **kw)


def Conv2D(in_channels, out_channels, kernel_size, stride=1, padding="SAME", **kw):
    """``ConvND`` over two spatial dims."""
    ks = (kernel_size, kernel_size) if isinstance(kernel_size, int) else tuple(kernel_size)
    st = (stride, stride) if isinstance(stride, int) else tuple(stride)
    return ConvND(2, in_channels, out_channels, ks, st, padding, **kw)


# --------------------------------------------------------------------------
# Embedding
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Embedding:
    """Token-id lookup table."""
    vocab_size: int
    features: int
    param_dtype: Any = jnp.float32
    dtype: Any = jnp.float32
    name: str = "embed"
    kind: str = "embed"

    def init(self, key) -> Params:
        """Create the embedding table."""
        return {"table": normal_init(key, (self.vocab_size, self.features),
                                     std=1.0 / math.sqrt(self.features),
                                     dtype=self.param_dtype)}

    def apply(self, params: Params, ids, ctx: Context):
        """Gather the embedding rows for ``ids``."""
        ctx = ctx.scope(self.name)
        table = params["table"]
        if isinstance(table, QTensor):
            # Gather rows as integers, dequantize only the gathered slice
            # (memory win: table stays int8 in HBM).
            rows = jnp.take(table.q, ids, axis=0)
            return qformat.dequantize(rows, table.n).astype(self.dtype)
        t = table
        if ctx.policy.enabled and ctx.policy.mode not in (QMode.CALIB, QMode.INTEGER) \
                and self.kind not in ctx.policy.skip_kinds:
            t = quantize_weight(t, ctx.policy, channel_axis=None)
        return jnp.take(t, ids, axis=0).astype(self.dtype)

    def attend(self, params: Params, x, ctx: Context):
        """Tied-embedding logits: x @ table.T (always float; logits are fp)."""
        table = params["table"]
        if isinstance(table, QTensor):
            from repro.kernels import ops as kops
            return kops.wq_matmul(x, table, transpose=True)
        return jnp.matmul(x, table.T.astype(self.dtype))


# --------------------------------------------------------------------------
# Norms (kept in fp32 — `norm` is in QuantPolicy.skip_kinds by default)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerNorm:
    """Layer normalization with learned scale and optional bias."""
    features: int
    eps: float = 1e-5
    use_bias: bool = True
    use_scale: bool = True
    name: str = "ln"

    def init(self, key) -> Params:
        """Create the scale (and optional bias) parameters."""
        p: Params = {}
        if self.use_scale:
            p["scale"] = jnp.ones((self.features,), jnp.float32)
        if self.use_bias:
            p["bias"] = jnp.zeros((self.features,), jnp.float32)
        return p

    def apply(self, params: Params, x, ctx: Context):
        """Normalize ``x`` over its feature axis."""
        del ctx
        dt = x.dtype
        x = x.astype(jnp.float32)
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + self.eps)
        if "scale" in params:
            y = y * params["scale"]
        if "bias" in params:
            y = y + params["bias"]
        return y.astype(dt)


@dataclasses.dataclass(frozen=True)
class RMSNorm:
    """Root-mean-square normalization with learned scale."""
    features: int
    eps: float = 1e-6
    name: str = "rms"

    def init(self, key) -> Params:
        """Create the scale parameter."""
        return {"scale": jnp.ones((self.features,), jnp.float32)}

    def apply(self, params: Params, x, ctx: Context):
        """Scale ``x`` by the inverse RMS of its feature axis."""
        del ctx
        dt = x.dtype
        x = x.astype(jnp.float32)
        y = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + self.eps)
        return (y * params["scale"]).astype(dt)


# --------------------------------------------------------------------------
# BatchNorm — folded form (paper Eqs. 5-7): y = w*x + b
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchNormFolded:
    """Inference-form batch norm as the paper deploys it (Eqs. 5-7).

    Training maintains (mean, var, gamma, beta); `fold()` produces the
    multiplicand/addend form used by the engine.
    """

    features: int
    eps: float = 1e-5
    momentum: float = 0.9
    name: str = "bn"

    def init(self, key) -> Params:
        """Create the affine parameters and running statistics."""
        del key
        return {
            "gamma": jnp.ones((self.features,), jnp.float32),
            "beta": jnp.zeros((self.features,), jnp.float32),
            "mean": jnp.zeros((self.features,), jnp.float32),
            "var": jnp.ones((self.features,), jnp.float32),
        }

    def fold(self, params: Params) -> Tuple[jax.Array, jax.Array]:
        """Fold running stats + affine into one inference scale/offset pair."""
        sigma = jnp.sqrt(params["var"] + self.eps)      # Eq. 6
        w = params["gamma"] / sigma                      # Eq. 5
        b = params["beta"] - params["gamma"] * params["mean"] / sigma  # Eq. 7
        return w, b

    def apply(self, params: Params, x, ctx: Context):
        """Apply the folded scale/offset (inference-form batch norm)."""
        if ctx.train:
            axes = tuple(range(x.ndim - 1))
            mu = jnp.mean(x, axis=axes)
            var = jnp.var(x, axis=axes)
            y = (x - mu) * jax.lax.rsqrt(var + self.eps)
            return y * params["gamma"] + params["beta"]
        w, b = self.fold(params)
        y = x * w + b
        return _fq_out(y, ctx.scope(self.name), "out") if ctx.policy.enabled else y


# --------------------------------------------------------------------------
# Stateless ops with quant semantics from Sec. 4.3 / 5.8
# --------------------------------------------------------------------------

def relu(x):
    """ReLU: element-wise max — *no* requantization (paper Sec. 4.3)."""
    if isinstance(x, QTensor):
        return QTensor(jnp.maximum(x.q, 0), x.n, x.width, x.channel_axis)
    return jax.nn.relu(x)


def max_pool(x, window: int, stride: Optional[int] = None, ndim: int = 1):
    """Max pooling — element-wise max, no requantization (paper Sec. 4.3)."""
    stride = stride or window
    if isinstance(x, QTensor):
        return QTensor(max_pool(x.q, window, stride, ndim), x.n, x.width, x.channel_axis)
    dims = (1, window, 1) if ndim == 1 else (1, window, window, 1)
    strides = (1, stride, 1) if ndim == 1 else (1, stride, stride, 1)
    # init must be a concrete (numpy) scalar: a traced jnp constant breaks
    # reduce_window linearization under jit+grad
    import numpy as np

    init = np.asarray(np.iinfo(x.dtype).min if jnp.issubdtype(x.dtype, jnp.integer)
                      else -np.inf, x.dtype)
    return jax.lax.reduce_window(x, init, jax.lax.max, dims, strides, "VALID")


def avg_pool(x, window: int, stride: Optional[int] = None, ndim: int = 1):
    """Average pool; integer inputs use int32 sum + shift when the window
    is a power of two (the paper's no-division rule).
    """
    stride = stride or window
    if isinstance(x, QTensor):
        # Integer average: int32 sum + shift when the divisor is a power of
        # two (the paper's no-division rule), integer divide otherwise.
        size = window if ndim == 1 else window * window
        acc = avg_pool_sum(x.q.astype(jnp.int32), window, stride, ndim)
        if size & (size - 1) == 0:
            q = jnp.right_shift(acc, int(math.log2(size)))
        else:
            q = acc // size
        q = jnp.clip(q, qformat.qmin(x.width), qformat.qmax(x.width))
        return QTensor(q.astype(x.q.dtype), x.n, x.width, x.channel_axis)
    dims = (1, window, 1) if ndim == 1 else (1, window, window, 1)
    strides = (1, stride, 1) if ndim == 1 else (1, stride, stride, 1)
    size = window if ndim == 1 else window * window
    import numpy as np

    s = jax.lax.reduce_window(x, np.asarray(0, x.dtype), jax.lax.add, dims,
                              strides, "VALID")
    return s / size


def avg_pool_sum(x, window: int, stride: int, ndim: int = 1):
    """Sum over pooling windows (the integer accumulator of ``avg_pool``)."""
    import numpy as np

    dims = (1, window, 1) if ndim == 1 else (1, window, window, 1)
    strides = (1, stride, 1) if ndim == 1 else (1, stride, stride, 1)
    return jax.lax.reduce_window(x, np.asarray(0, x.dtype), jax.lax.add, dims,
                                 strides, "VALID")


def global_avg_pool(x, ndim: int = 1):
    """Mean over all spatial axes (integer divide for QTensor inputs)."""
    axes = (1,) if ndim == 1 else (1, 2)
    if isinstance(x, QTensor):
        size = math.prod(x.q.shape[a] for a in axes)
        acc = jnp.sum(x.q.astype(jnp.int32), axis=axes)
        q = jnp.clip(acc // size, qformat.qmin(x.width), qformat.qmax(x.width))
        return QTensor(q.astype(x.q.dtype), x.n, x.width, x.channel_axis)
    return jnp.mean(x, axis=axes)


def qadd(a, b, ctx: Context, site: str = "add", n_out: Optional[jax.Array] = None):
    """Element-wise add with the paper's Add-layer semantics (Sec. 4.3):

    no weights, but the output dynamic range can grow, so the output gets its
    own scale factor.  Integer path: align both operands to a common format in
    the int32 accumulator, add, requantize + saturate.
    """
    if isinstance(a, QTensor) and isinstance(b, QTensor):
        width = a.width
        n_common = jnp.minimum(a.n, b.n)
        acc = qformat.align(a.q, a.n, n_common, jnp.int32) + \
            qformat.align(b.q, b.n, n_common, jnp.int32)
        if n_out is None:
            n_out = ctx.frozen(f"{site}/out")
            if n_out is None:
                raise ValueError(f"integer add needs calibrated exponent at {ctx.key(site)}")
        yq = qformat.requantize(acc, n_common, n_out, width)
        return QTensor(yq, n_out, width)
    y = a + b
    if ctx.policy.enabled and ctx.policy.mode is not QMode.INTEGER:
        y = _fq_out(y, ctx.scope(site), "out")
    return y


def dropout(x, rate: float, ctx: Context, name: str = "dropout"):
    """Inverted dropout; identity when not training or no rng in ``ctx``."""
    if not ctx.train or rate <= 0.0 or ctx.rng is None:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(ctx.fold_rng(name), keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)
