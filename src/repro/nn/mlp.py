"""Feed-forward blocks: gated (SwiGLU/GeGLU — llama-family) and classic MLP.

All matmuls route through :class:`~repro.nn.layers.Dense`, so every FFN
automatically supports the paper's three execution paths (float/fake-quant,
full integer, weight-only int8) and the quantization policy hooks.

TP sharding (Megatron-style): w_gate/w_in are column-parallel (output dim on
the `model` mesh axis), w_out is row-parallel (input dim on `model`); the
activation between them is constrained to (batch, None, model) so XLA keeps
the hidden dim sharded and inserts a single reduce-scatter/all-reduce at w_out.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn.layers import Dense
from repro.nn.module import Context, Params

ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


@dataclasses.dataclass(frozen=True)
class GatedMLP:
    """SwiGLU/GeGLU: w_out(act(w_gate(x)) * w_in(x))."""

    d_model: int
    d_ff: int
    activation: str = "silu"
    use_bias: bool = False
    dtype: Any = jnp.float32
    name: str = "mlp"

    def _layers(self):
        return {
            "w_gate": Dense(self.d_model, self.d_ff, self.use_bias, self.dtype, name="w_gate"),
            "w_in": Dense(self.d_model, self.d_ff, self.use_bias, self.dtype, name="w_in"),
            "w_out": Dense(self.d_ff, self.d_model, self.use_bias, self.dtype, name="w_out"),
        }

    def init(self, key) -> Params:
        """Create the gate/up/down projection parameters."""
        ks = jax.random.split(key, 3)
        return {nm: l.init(k) for (nm, l), k in zip(self._layers().items(), ks)}

    def apply(self, params: Params, x, ctx: Context):
        """Gated feed-forward: ``down(act(gate(x)) * up(x))``."""
        ctx = ctx.scope(self.name)
        ls = self._layers()
        g = ls["w_gate"].apply(params["w_gate"], x, ctx)
        h = ls["w_in"].apply(params["w_in"], x, ctx)
        a = ACTIVATIONS[self.activation](g) * h
        a = ctx.constrain(a, "batch", None, "ff")
        return ls["w_out"].apply(params["w_out"], a, ctx)


@dataclasses.dataclass(frozen=True)
class MLP:
    """Classic 2-layer MLP (whisper, ViT, classifier heads)."""

    d_model: int
    d_ff: int
    d_out: int = 0  # 0 => d_model
    activation: str = "gelu"
    use_bias: bool = True
    dtype: Any = jnp.float32
    name: str = "mlp"

    def _layers(self):
        d_out = self.d_out or self.d_model
        return {
            "w_in": Dense(self.d_model, self.d_ff, self.use_bias, self.dtype, name="w_in"),
            "w_out": Dense(self.d_ff, d_out, self.use_bias, self.dtype, name="w_out"),
        }

    def init(self, key) -> Params:
        """Create the two projection layers' parameters."""
        ks = jax.random.split(key, 2)
        return {nm: l.init(k) for (nm, l), k in zip(self._layers().items(), ks)}

    def apply(self, params: Params, x, ctx: Context):
        """Plain feed-forward: ``proj2(act(proj1(x)))``."""
        ctx = ctx.scope(self.name)
        ls = self._layers()
        a = ACTIVATIONS[self.activation](ls["w_in"].apply(params["w_in"], x, ctx))
        a = ctx.constrain(a, "batch", None, "ff")
        return ls["w_out"].apply(params["w_out"], a, ctx)
