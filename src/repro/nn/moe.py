"""Mixture-of-Experts with capacity-based top-k routing (EP-shardable).

Design (DESIGN.md §4):
  * Experts stored stacked: ``w_gate/w_in`` (E, D, F), ``w_out`` (E, F, D).
    Under pjit the expert axis E shards over the `model` mesh axis (expert
    parallelism) — phi3.5: 1 expert/device, kimi-k2: 24 experts/device.
  * Router (``kind="router"``) stays in float: the top-k decision boundary is
    precision-sensitive, so it is in ``QuantPolicy.skip_kinds`` (paper's
    per-layer skip rule applied to a new layer family).
  * Dispatch is **dense and static-shaped** for compile-time determinism:
    tokens are split into `num_groups` routing groups (aligned with the data
    shards so routing never crosses a shard boundary), each expert takes its
    top-`capacity` tokens per group via ``lax.top_k``, gathers, runs a batched
    expert GEMM, and scatter-adds back.  Over-capacity tokens are dropped
    (standard GShard/Switch semantics); capacity_factor controls slack.
  * Load-balance auxiliary loss (Switch-style f·P) accumulated on the Context.

The expert FFN math itself routes through the same fake-quant hooks as Dense
(weights fake-quantized per policy), so the paper's QAT/PTQ applies to expert
weights exactly as to dense FFNs — see ``_fq_weight`` use below.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.qformat import QTensor
from repro.nn.layers import Dense, _fq_in, _fq_out, _fq_weight, lecun_normal
from repro.nn.mlp import ACTIVATIONS
from repro.nn.module import Context, Params


@dataclasses.dataclass(frozen=True)
class MoE:
    """Mixture-of-experts feed-forward: top-k token routing over stacked
    expert MLPs with a load-balancing auxiliary loss.
    """
    d_model: int
    d_ff: int                      # per-expert hidden dim
    n_experts: int
    top_k: int
    n_shared_experts: int = 0      # kimi-k2-style always-on shared expert(s)
    activation: str = "silu"
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    dtype: Any = jnp.float32
    name: str = "moe"

    def _router(self):
        return Dense(self.d_model, self.n_experts, use_bias=False,
                     dtype=jnp.float32, name="router", kind="router")

    def init(self, key) -> Params:
        """Create router and stacked expert parameters."""
        kr, kg, ki, ko, ks = jax.random.split(key, 5)
        E, D, F = self.n_experts, self.d_model, self.d_ff
        p: Params = {
            "router": self._router().init(kr),
            "experts": {
                "w_gate": {"kernel": lecun_normal(kg, (E, D, F))},
                "w_in": {"kernel": lecun_normal(ki, (E, D, F))},
                "w_out": {"kernel": lecun_normal(ko, (E, F, D))},
            },
        }
        if self.n_shared_experts:
            from repro.nn.mlp import GatedMLP

            shared = GatedMLP(D, F * self.n_shared_experts,
                              activation=self.activation, dtype=self.dtype,
                              name="shared")
            p["shared"] = shared.init(ks)
        return p

    # -- expert weight access (handles float / fake-quant / integerized) ----
    def _expert_w(self, params: Params, name: str, ctx: Context):
        leaf = params["experts"][name]["kernel"]
        if isinstance(leaf, QTensor):
            return leaf.dequantize().astype(self.dtype)
        if ctx.policy.enabled and ctx.policy.mode.value not in ("integer", "calib"):
            return _fq_weight(leaf, ctx.scope(name), channel_axis=-1).astype(self.dtype)
        return leaf.astype(self.dtype)

    def apply(self, params: Params, x, ctx: Context, *, num_groups: Optional[int] = None):
        """x: (B, S, D) -> (B, S, D)."""
        ctx = ctx.scope(self.name)
        b, s, d = x.shape
        E, K = self.n_experts, self.top_k
        act = ACTIVATIONS[self.activation]

        # Decode (s==1) uses the weight-stationary dispatch: with tokens
        # sharded over `data` AND expert weights FSDP-sharded over `data`,
        # the expert einsum has a data-axis conflict (batch dim vs
        # contracting dim) that makes XLA all-gather the expert weights —
        # ~4 GiB/layer for 128 tokens (kimi-k2, §Perf).  Instead: replicate
        # the tiny token set over `data`, shard the *contracting* dims over
        # `data`, and let two small activation psums replace the gathers.
        weight_stationary = (s == 1 and ctx.mesh is not None)

        # ---- routing groups: align with the data shards so top-k stays local
        if num_groups is None:
            num_groups = 1 if weight_stationary else ctx.dp_size
        g = max(1, min(num_groups, b))
        while b % g:
            g -= 1
        tokens_per_group = (b // g) * s
        cap = int(math.ceil(tokens_per_group * K / E * self.capacity_factor))
        cap = max(1, min(cap, tokens_per_group))

        xg = x.reshape(g, tokens_per_group, d)
        xg = ctx.constrain(xg, "batch", None, None)

        # ---- router (fp32, not quantized).  The expert axis of the logits
        # must be REPLICATED: top_k along a model-sharded axis makes the
        # partitioner replicate the full (g,t,E) routing tensors (9.6 GiB
        # observed on kimi-k2 — §Perf kimi train iteration 2).
        logits = self._router().apply(params["router"], xg.astype(jnp.float32), ctx)
        logits = ctx.constrain(logits, "batch", None, None)
        probs = jax.nn.softmax(logits, axis=-1)                    # (g, t, E)

        # SPMD replicates sort/top_k operands, so the (g,t,E) routing tensors
        # cross the wire in full; running the *selection* in bf16 halves
        # those bytes (order-based — bf16 flips ties only).  The aux loss
        # keeps the f32 probs.
        probs_sel = probs.astype(jnp.bfloat16)
        top_vals, top_idx = jax.lax.top_k(probs_sel, K)            # (g, t, K)
        mask = jnp.sum(jax.nn.one_hot(top_idx, E, dtype=probs_sel.dtype),
                       axis=2)                                      # (g,t,E)
        gates_te = probs_sel * mask                                 # (g, t, E)

        # ---- load-balance aux loss (Switch: E * sum_e f_e * P_e)
        f_e = jnp.mean(mask, axis=1)                                # (g, E)
        p_e = jnp.mean(probs, axis=1)
        aux = jnp.mean(jnp.sum(f_e * p_e, axis=-1)) * E
        ctx.add_loss("moe_load_balance", self.aux_loss_weight * aux)

        # ---- expert choice of tokens: top-capacity tokens per (group, expert)
        sel_gate, sel_idx = jax.lax.top_k(
            jnp.swapaxes(gates_te, 1, 2), cap)                      # (g, E, C)
        xe = jnp.take_along_axis(
            xg[:, None], sel_idx[..., None], axis=2)                # (g, E, C, D)
        if weight_stationary:
            xe = ctx.constrain(xe, None, "expert", None, "fsdp")
        else:
            xe = ctx.constrain(xe, "batch", "expert", None, None)

        # ---- fake-quant hooks on the expert FFN input/output (paper Fig. 2)
        xe = _fq_in(xe, ctx, "experts/in")
        w_g = self._expert_w(params, "w_gate", ctx)
        w_i = self._expert_w(params, "w_in", ctx)
        w_o = self._expert_w(params, "w_out", ctx)

        xe_c = xe.astype(self.dtype)
        h = act(jnp.einsum("gecd,edf->gecf", xe_c, w_g)) * jnp.einsum(
            "gecd,edf->gecf", xe_c, w_i)
        if weight_stationary:
            h = ctx.constrain(h, None, "expert", None, "fsdp")
        else:
            h = ctx.constrain(h, "batch", "expert", None, None)
        ye = jnp.einsum("gecf,efd->gecd", h, w_o)                   # (g, E, C, D)
        ye = _fq_out(ye, ctx, "experts/out")
        ye = ctx.constrain(ye, "batch", "expert", None, None)

        # ---- combine: scatter-add weighted expert outputs back to tokens
        ye = ye * sel_gate[..., None].astype(ye.dtype)
        flat_idx = sel_idx.reshape(g, E * cap)                      # (g, E*C)
        flat_ye = ye.reshape(g, E * cap, d)

        def combine(idx_1d, ye_2d):
            return jnp.zeros((tokens_per_group, d), ye_2d.dtype).at[idx_1d].add(ye_2d)

        out = jax.vmap(combine)(flat_idx, flat_ye)                  # (g, t, D)
        out = out.reshape(b, s, d)
        out = ctx.constrain(out, "batch", None, None)

        if self.n_shared_experts:
            from repro.nn.mlp import GatedMLP

            shared = GatedMLP(self.d_model, self.d_ff * self.n_shared_experts,
                              activation=self.activation, dtype=self.dtype,
                              name="shared")
            out = out + shared.apply(params["shared"], x, ctx)
        return out
