"""Transformer blocks and the scanned layer Stack.

A :class:`Block` is one residual layer: norm → mixer (attention / Mamba /
RWKV6 time-mix) → residual, norm → FFN (gated / MLP / MoE / RWKV6 channel-mix)
→ residual.  ``parallel=True`` gives the command-r-style parallel block
(mixer and FFN both read the same normed input).

A :class:`Stack` is ``prelude`` (python-applied, e.g. kimi-k2's dense first
layer) + ``body`` (a period of blocks — period 1 for uniform archs, 8 for
jamba's mamba/attn interleave) scanned ``n_periods`` times with stacked
params.  Scanning keeps the HLO size O(period), not O(layers) — 61-layer
kimi-k2 compiles like a 1-layer model — and composes with ``jax.checkpoint``
for activation rematerialization (policy knob, a §Perf lever).

Quant-stat collection under scan uses Context.fork_for_scan/merge_scanned
(stats reduce with max over the layer axis, aux losses with sum).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.attention import Attention
from repro.nn.layers import LayerNorm, RMSNorm
from repro.nn.mlp import MLP, GatedMLP
from repro.nn.moe import MoE
from repro.nn.module import Context, Params
from repro.nn.ssm import Mamba, RWKV6ChannelMix, RWKV6TimeMix

REMAT_POLICIES = {
    "none": None,
    "dots": "dots_with_no_batch_dims_saveable",
    "full": "nothing_saveable",
}


def _remat(fn, policy_name: str):
    if policy_name == "off":
        return fn
    pol = REMAT_POLICIES[policy_name]
    if pol is None:
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=getattr(jax.checkpoint_policies, pol))


@dataclasses.dataclass(frozen=True)
class Block:
    """One residual block: norm + mixer (attention/Mamba/RWKV) + norm +
    feed-forward (MLP/gated/MoE), with optional cross-attention.
    """
    d_model: int
    # mixer
    mixer: str = "attn"            # attn | mamba | rwkv
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True
    mamba_d_state: int = 16
    # ffn
    ffn: str = "gated"             # gated | mlp | moe | rwkv | none
    d_ff: int = 0
    activation: str = "silu"
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    # structure
    norm: str = "rms"              # rms | ln
    parallel: bool = False         # command-r parallel attn+ffn
    cross: bool = False            # whisper decoder cross-attention
    dtype: Any = jnp.float32
    name: str = "block"

    # ---- sub-layer factories ------------------------------------------------
    def _norm(self, name):
        if self.norm == "ln":
            return LayerNorm(self.d_model, name=name)
        return RMSNorm(self.d_model, name=name)

    def _mixer(self):
        if self.mixer == "attn":
            return Attention(self.d_model, self.n_heads, self.n_kv_heads,
                             self.head_dim, use_qkv_bias=self.qkv_bias,
                             rope_theta=self.rope_theta, use_rope=self.use_rope,
                             causal=self.causal, dtype=self.dtype, name="attn")
        if self.mixer == "mamba":
            return Mamba(self.d_model, d_state=self.mamba_d_state,
                         dtype=self.dtype, name="mamba")
        if self.mixer == "rwkv":
            return RWKV6TimeMix(self.d_model, head_dim=self.head_dim or 64,
                                dtype=self.dtype, name="timemix")
        raise ValueError(self.mixer)

    def _ffn(self):
        if self.ffn == "gated":
            return GatedMLP(self.d_model, self.d_ff, activation=self.activation,
                            dtype=self.dtype, name="ffn")
        if self.ffn == "mlp":
            return MLP(self.d_model, self.d_ff, activation=self.activation,
                       dtype=self.dtype, name="ffn")
        if self.ffn == "moe":
            return MoE(self.d_model, self.d_ff, self.n_experts, self.top_k,
                       n_shared_experts=self.n_shared_experts,
                       activation=self.activation, dtype=self.dtype, name="moe")
        if self.ffn == "rwkv":
            return RWKV6ChannelMix(self.d_model, self.d_ff, dtype=self.dtype,
                                   name="chanmix")
        if self.ffn == "none":
            return None
        raise ValueError(self.ffn)

    def _xattn(self):
        return Attention(self.d_model, self.n_heads, self.n_kv_heads,
                         self.head_dim, use_rope=False, causal=False,
                         dtype=self.dtype, name="xattn")

    # ---- params ---------------------------------------------------------------
    def init(self, key) -> Params:
        """Create the block's norm/mixer/FFN (and cross-attn) parameters."""
        ks = jax.random.split(key, 6)
        p: Params = {"norm1": self._norm("norm1").init(ks[0]),
                     "mixer": self._mixer().init(ks[1])}
        ffn = self._ffn()
        if ffn is not None:
            if not self.parallel:
                p["norm2"] = self._norm("norm2").init(ks[2])
            p["ffn"] = ffn.init(ks[3])
        if self.cross:
            p["norm_x"] = self._norm("norm_x").init(ks[4])
            p["xattn"] = self._xattn().init(ks[5])
        return p

    def init_cache(self, batch: int, max_len: int, *, quantized_kv: bool,
                   kv_dtype=jnp.bfloat16, per_slot_len: bool = False,
                   page_size: Optional[int] = None,
                   num_pages: Optional[int] = None,
                   enc_len: Optional[int] = None,
                   ) -> Dict[str, Any]:
        """Per-layer decode cache: KV slab or paged pool, or per-slot
        recurrent state (SSM/RWKV — batch rows ARE slot rows, so the same
        state dict serves lockstep and continuous batching), plus a per-slot
        cross-attention K/V cache when ``cross`` and ``enc_len`` are set.
        """
        c: Dict[str, Any] = {}
        if self.mixer == "attn":
            from repro.nn.attention import init_kv_cache, init_paged_kv_cache

            if page_size is not None:
                if not per_slot_len:
                    raise ValueError(
                        "paged KV caches are per-slot by construction: pass "
                        "per_slot_len=True alongside page_size/num_pages")
                max_pages = -(-max_len // page_size)
                c["kv"] = init_paged_kv_cache(
                    batch, max_pages, page_size,
                    num_pages if num_pages is not None else batch * max_pages,
                    self.n_kv_heads, self.head_dim, quantized=quantized_kv,
                    dtype=kv_dtype)
            else:
                c["kv"] = init_kv_cache(batch, max_len, self.n_kv_heads,
                                        self.head_dim, quantized=quantized_kv,
                                        dtype=kv_dtype,
                                        per_slot_len=per_slot_len)
        elif self.mixer == "mamba":
            c["ssm"] = Mamba(self.d_model, d_state=self.mamba_d_state,
                             dtype=self.dtype).init_state(batch)
        elif self.mixer == "rwkv":
            c["ssm"] = RWKV6TimeMix(self.d_model, head_dim=self.head_dim or 64,
                                    dtype=self.dtype).init_state(batch)
            if self.ffn == "rwkv":
                c["cm"] = {"shift": jnp.zeros((batch, 1, self.d_model),
                                              self.dtype)}
        else:
            raise ValueError(self.mixer)
        if self.cross and per_slot_len and enc_len is not None:
            from repro.nn.attention import init_cross_cache

            c["xkv"] = init_cross_cache(batch, enc_len, self.n_kv_heads,
                                        self.head_dim, dtype=self.dtype)
        return c

    # ---- forward ---------------------------------------------------------------
    def apply(self, params: Params, x, ctx: Context, *,
              cache: Optional[Dict[str, Any]] = None,
              enc: Optional[jax.Array] = None,
              positions: Optional[jax.Array] = None,
              decode: bool = False,
              chunk=None,
              ragged=None) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
        """Run the block; serving paths thread ``cache``/``chunk``/``ragged``
        through the mixer and gather per-token encoder rows for cross-attn.
        """
        ctx = ctx.scope(self.name)
        new_cache: Dict[str, Any] = {}
        h = self._norm("norm1").apply(params["norm1"], x, ctx)

        if self.mixer == "attn":
            mix_out, kv = self._mixer().apply(
                params["mixer"], h, ctx, positions=positions,
                cache=None if cache is None else cache["kv"], decode=decode,
                chunk=chunk, ragged=ragged)
            if kv is not None:
                new_cache["kv"] = kv
        else:
            if ragged is not None:
                raise NotImplementedError(
                    "the ragged step routes tokens by per-row cache "
                    "positions; recurrent state has no position axis — "
                    "serve recurrent mixers through the chunked path")
            mix_out, st = self._mixer().apply(
                params["mixer"], h, ctx,
                state=None if cache is None else cache["ssm"],
                chunk=chunk)
            if st is not None:
                new_cache["ssm"] = st

        ffn = self._ffn()
        if self.parallel and ffn is not None:
            # command-r: y = x + attn(norm(x)) + ffn(norm(x))
            x = x + mix_out + ffn.apply(params["ffn"], h, ctx)
            return x, (new_cache or None)

        x = x + mix_out
        if self.cross:
            hx = self._norm("norm_x").apply(params["norm_x"], x, ctx)
            xkv = None if cache is None else cache.get("xkv")
            if xkv is not None:
                # cached cross-attention: read the per-slot projected rows;
                # the cache itself is written at admission
                # (EncDecLM.write_cross_kv) and passes through untouched —
                # structure preservation under jit donation.
                if ragged is not None:
                    slots = jnp.clip(jnp.asarray(ragged.slots, jnp.int32),
                                     0, None)
                    sub = {"xk": jnp.take(xkv["xk"], slots, axis=0),
                           "xv": jnp.take(xkv["xv"], slots, axis=0),
                           "xlen": jnp.take(xkv["xlen"], slots, axis=0)}
                    hx_t = jnp.swapaxes(hx, 0, 1)           # (T, 1, d)
                    xo, _ = self._xattn().apply(params["xattn"], hx_t, ctx,
                                                cross_cache=sub)
                    xo = jnp.swapaxes(xo, 0, 1)             # (1, T, d)
                else:
                    xo, _ = self._xattn().apply(params["xattn"], hx, ctx,
                                                cross_cache=xkv, chunk=chunk)
                new_cache["xkv"] = xkv
            elif ragged is not None:
                # Ragged tick: hx is one (1, T, d) token batch mixing tokens
                # from several decode slots, but cross-attention must pair
                # each token with *its own* slot's encoder output.  Gather
                # enc rows per token and run tokens-as-batch (T, 1, d) so
                # every row cross-attends only its own context (pads clamp
                # to slot 0 — their output rows are never sampled).
                slots = jnp.clip(jnp.asarray(ragged.slots, jnp.int32), 0, None)
                enc_g = jnp.take(enc, slots, axis=0)        # (T, S_enc, d)
                hx_t = jnp.swapaxes(hx, 0, 1)               # (T, 1, d)
                xo, _ = self._xattn().apply(params["xattn"], hx_t, ctx,
                                            kv_source=enc_g)
                xo = jnp.swapaxes(xo, 0, 1)                 # (1, T, d)
            else:
                xo, _ = self._xattn().apply(params["xattn"], hx, ctx,
                                            kv_source=enc)
            x = x + xo
        if ffn is not None:
            h2 = self._norm("norm2").apply(params["norm2"], x, ctx)
            if self.ffn == "rwkv":
                f_out, cm = ffn.apply(params["ffn"], h2, ctx,
                                      state=None if cache is None else cache.get("cm"),
                                      chunk=chunk)
                if cm is not None:
                    new_cache["cm"] = cm
            else:
                f_out = ffn.apply(params["ffn"], h2, ctx)
            x = x + f_out
        x = ctx.constrain(x, "batch", "seq", None)
        return x, (new_cache or None)


@dataclasses.dataclass(frozen=True)
class Stack:
    """prelude blocks (python loop) + body period scanned n_periods times."""

    body: Tuple[Block, ...]
    n_periods: int
    prelude: Tuple[Block, ...] = ()
    remat: str = "full"            # off | none | dots | full
    scan_layers: bool = True
    name: str = "stack"

    @property
    def n_layers(self) -> int:
        """Total layer count (prelude + scanned periods)."""
        return len(self.prelude) + len(self.body) * self.n_periods

    def init(self, key) -> Params:
        """Create parameters for every layer (stacked for scanned periods)."""
        kp, kb = jax.random.split(key)
        p: Params = {}
        if self.prelude:
            ks = jax.random.split(kp, len(self.prelude))
            p["prelude"] = [blk.init(k) for blk, k in zip(self.prelude, ks)]
        if self.scan_layers and self.n_periods > 1:
            keys = jax.random.split(kb, self.n_periods)
            body_p = []
            for i, blk in enumerate(self.body):
                per_pos = jax.vmap(lambda k: blk.init(
                    jax.random.fold_in(k, i)))(keys)
                body_p.append(per_pos)
            p["body"] = body_p
        else:
            ks = jax.random.split(kb, self.n_periods * max(1, len(self.body)))
            p["body"] = [self.body[i % len(self.body)].init(ks[i])
                         for i in range(self.n_periods * len(self.body))]
        return p

    def init_cache(self, batch: int, max_len: int, *, quantized_kv: bool,
                   kv_dtype=jnp.bfloat16, per_slot_len: bool = False,
                   page_size: Optional[int] = None,
                   num_pages: Optional[int] = None,
                   enc_len: Optional[int] = None,
                   ) -> Dict[str, Any]:
        """Decode caches for all layers, stacked to match the scan layout."""
        kw = dict(quantized_kv=quantized_kv, kv_dtype=kv_dtype,
                  per_slot_len=per_slot_len, page_size=page_size,
                  num_pages=num_pages, enc_len=enc_len)
        c: Dict[str, Any] = {}
        if self.prelude:
            c["prelude"] = [blk.init_cache(batch, max_len, **kw)
                            for blk in self.prelude]
        if self.scan_layers and self.n_periods > 1:
            c["body"] = [
                jax.tree_util.tree_map(
                    lambda l: jnp.broadcast_to(
                        l[None], (self.n_periods,) + l.shape).copy(),
                    blk.init_cache(batch, max_len, **kw))
                for blk in self.body]
        else:
            c["body"] = [self.body[i % len(self.body)].init_cache(
                batch, max_len, **kw)
                for i in range(self.n_periods * len(self.body))]
        return c

    def apply(self, params: Params, x, ctx: Context, *,
              cache: Optional[Dict[str, Any]] = None,
              enc: Optional[jax.Array] = None,
              positions: Optional[jax.Array] = None,
              decode: bool = False,
              chunk=None,
              ragged=None) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
        """Run all layers (prelude loop + scanned periods), threading the
        serving kwargs and per-layer cache slices through each block.
        """
        ctx = ctx.scope(self.name)
        new_cache: Dict[str, Any] = {} if cache is not None else None

        for i, blk in enumerate(self.prelude):
            bctx = ctx.scope(f"pre{i}")
            x, nc = blk.apply(params["prelude"][i], x, bctx,
                              cache=None if cache is None else cache["prelude"][i],
                              enc=enc, positions=positions, decode=decode,
                              chunk=chunk, ragged=ragged)
            if new_cache is not None:
                new_cache.setdefault("prelude", []).append(nc)

        if not (self.scan_layers and self.n_periods > 1):
            ncs = []
            for i in range(self.n_periods * len(self.body)):
                blk = self.body[i % len(self.body)]

                # stats/aux-losses must cross the jax.checkpoint boundary as
                # outputs (mutating the shared dicts inside the rematerialized
                # region would leak tracers — same discipline as the scan path)
                def layer_fn(p, xc, c, blk=blk, i=i):
                    sctx = ctx.fork_for_scan()
                    bctx = sctx.scope(f"l{i}")
                    x2, nc = blk.apply(p, xc, bctx, cache=c, enc=enc,
                                       positions=positions, decode=decode,
                                       chunk=chunk, ragged=ragged)
                    return x2, nc, sctx.stats, sctx.losses

                if self.remat != "off":
                    layer_fn = _remat(layer_fn, self.remat)
                x, nc, stats, losses = layer_fn(
                    params["body"][i], x,
                    None if cache is None else cache["body"][i])
                ctx.merge_scanned(stats, losses)
                ncs.append(nc)
            if new_cache is not None:
                new_cache["body"] = ncs
            return x, new_cache

        # ---- scanned body ----------------------------------------------------
        def period_body(carry, xs):
            xc = carry
            p_list, c_list = xs
            sctx = ctx.fork_for_scan()
            ncs = []
            for pos, blk in enumerate(self.body):
                bctx = sctx.scope(f"p{pos}")
                xc, nc = blk.apply(
                    p_list[pos], xc, bctx,
                    cache=None if c_list is None else c_list[pos],
                    enc=enc, positions=positions, decode=decode, chunk=chunk,
                    ragged=ragged)
                nc = dict(nc) if nc is not None else {}
                # xkv is read-only here (written only by write_cross_kv, at
                # admission): returning it as a scan output would
                # rematerialize the full per-layer encoder K/V every step —
                # the original stacked buffers are reattached after the scan
                nc.pop("xkv", None)
                ncs.append(nc)
            return xc, (tuple(ncs), sctx.stats, sctx.losses)

        body_fn = _remat(period_body, self.remat)
        xs = (params["body"],
              cache["body"] if cache is not None else None)
        x, (ncs, stats, losses) = jax.lax.scan(body_fn, x, xs)
        ctx.merge_scanned(stats, losses)
        if new_cache is not None:
            ncs = list(ncs)
            for pos in range(len(ncs)):
                cb = cache["body"][pos]
                if isinstance(cb, dict) and "xkv" in cb:
                    # identity passthrough outside the scan: under cache
                    # donation this aliases, so the cached cross-attention
                    # read path pays zero copy per step
                    ncs[pos] = dict(ncs[pos], xkv=cb["xkv"])
            new_cache["body"] = ncs
        return x, new_cache
