"""Minimal functional module substrate.

Parameters are plain nested dicts of jnp arrays (or ``QTensor`` leaves once a
model is integerized), so they are trivially shardable with pjit, scannable
with ``jax.lax.scan`` (stacked leaves) and checkpointable.

A ``Context`` threads cross-cutting concerns through ``apply``:

  * the active :class:`~repro.core.policy.QuantPolicy` (QAT fake-quant hooks,
    frozen scales for PTQ/eval, true-integer serving),
  * activation-range statistics collection (paper Sec. 4.3: ranges reassessed
    during training, frozen for inference — collection happens under CALIB),
  * train/eval flag and RNG,
  * a name path for stable quant-site keys.

Stats collection under ``lax.scan`` needs explicit threading (a dict mutated
inside a scan body would leak tracers); ``Context.fork_for_scan`` /
``Context.merge_scanned`` implement that hand-off.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import QMode, QuantPolicy

Params = Dict[str, Any]


@dataclasses.dataclass
class Context:
    """Per-call state threaded through every module: quantization policy,
    train flag, rng, mesh/axis rules, name scoping and stat collection.
    """
    policy: QuantPolicy = dataclasses.field(default_factory=QuantPolicy.float32)
    train: bool = False
    rng: Optional[jax.Array] = None
    # Frozen activation exponents {site_path: int32 n}, produced by calibration.
    qstate: Optional[Dict[str, jax.Array]] = None
    # Mutable range stats collected this call {site_path: max_abs (f32)}.
    stats: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    # Auxiliary losses accumulated additively (MoE load-balance, router-z).
    losses: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    path: str = ""
    # Distribution: active mesh + logical->physical axis rules, e.g.
    # {"batch": ("pod", "data"), "model": "model", "seq": None}.  None mesh =>
    # single-device semantics (no constraints, no collectives in MoE).
    mesh: Any = None
    axis_rules: Optional[Dict[str, Any]] = None

    def pspec(self, *logical_axes) -> Any:
        """PartitionSpec from logical axis names via the active rules."""
        from jax.sharding import PartitionSpec as P

        if self.axis_rules is None:
            return P()
        return P(*(self.axis_rules.get(a) if a is not None else None
                   for a in logical_axes))

    def constrain(self, x, *logical_axes):
        """with_sharding_constraint if a mesh is active, else identity.

        An axis whose dimension does not divide the mesh-axis size is
        dropped (replicated) — JAX would otherwise emit padded uneven
        shardings (e.g. smollm's 9 heads on a 16-way model axis), which
        show up as pathological all-gathers in the collective schedule.
        """
        if self.mesh is None or self.axis_rules is None:
            return x
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        axes = []
        for i, a in enumerate(logical_axes):
            phys = self.axis_rules.get(a) if a is not None else None
            if phys is None or i >= x.ndim:
                axes.append(None)
                continue
            names = tuple(phys) if isinstance(phys, (tuple, list)) else (phys,)
            # longest prefix of the axis tuple that divides the dim
            while names:
                size = 1
                for nm in names:
                    size *= int(self.mesh.shape[nm])
                if size > 1 and x.shape[i] % size == 0:
                    break
                names = names[:-1]
            if names:
                axes.append(names if len(names) > 1 else names[0])
            else:
                axes.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*axes)))

    def _axis_size(self, logical: str) -> int:
        if self.mesh is None or self.axis_rules is None:
            return 1
        ax = self.axis_rules.get(logical)
        if ax is None:
            return 1
        axes = ax if isinstance(ax, (tuple, list)) else (ax,)
        size = 1
        for a in axes:
            size *= int(self.mesh.shape[a])
        return size

    @property
    def dp_size(self) -> int:
        """Data-parallel degree (used e.g. to align MoE routing groups)."""
        return self._axis_size("batch")

    @property
    def tp_size(self) -> int:
        """Tensor-parallel degree (size of the ``model`` mesh axis)."""
        return self._axis_size("model")

    # -- naming ------------------------------------------------------------
    def scope(self, name: str) -> "Context":
        """Child context with ``name`` appended to the naming path."""
        child = dataclasses.replace(self)
        child.stats = self.stats  # shared collectors
        child.losses = self.losses
        child.path = f"{self.path}/{name}" if self.path else name
        return child

    def key(self, name: str) -> str:
        """Fully-scoped name for a quant site under the current path."""
        return f"{self.path}/{name}" if self.path else name

    # -- stats -------------------------------------------------------------
    @property
    def collecting(self) -> bool:
        """Whether range statistics are being gathered (CALIB/QAT modes)."""
        return self.policy.mode in (QMode.CALIB, QMode.QAT)

    def record(self, name: str, value: jax.Array) -> None:
        """Record a max-|x| range statistic for a quant site."""
        k = self.key(name)
        v = jnp.max(jnp.abs(jax.lax.stop_gradient(value))).astype(jnp.float32)
        if k in self.stats:
            self.stats[k] = jnp.maximum(self.stats[k], v)
        else:
            self.stats[k] = v

    def frozen(self, name: str) -> Optional[jax.Array]:
        """Frozen activation exponent for this site, if calibrated."""
        if self.qstate is None:
            return None
        return self.qstate.get(self.key(name))

    def add_loss(self, name: str, value: jax.Array) -> None:
        """Accumulate an auxiliary loss term (summed across sites/layers)."""
        if name in self.losses:
            self.losses[name] = self.losses[name] + value
        else:
            self.losses[name] = value

    # -- rng ---------------------------------------------------------------
    def fold_rng(self, name: str) -> Optional[jax.Array]:
        """Deterministically fold the scoped name into the context rng."""
        if self.rng is None:
            return None
        # crc32 (not hash()) so the fold-in is deterministic across processes.
        digest = zlib.crc32(self.key(name).encode()) & 0x7FFFFFFF
        return jax.random.fold_in(self.rng, digest)

    # -- scan support --------------------------------------------------------
    def fork_for_scan(self) -> "Context":
        """A context whose stats/losses dicts are private to one scan-body trace."""
        child = dataclasses.replace(self)
        child.stats = {}
        child.losses = {}
        return child

    def merge_scanned(self, scanned_stats: Dict[str, jax.Array],
                      scanned_losses: Optional[Dict[str, jax.Array]] = None) -> None:
        """Merge per-layer-stacked stats (max over scan axis) and losses (sum)."""
        for k, v in scanned_stats.items():
            v = jnp.max(v) if v.ndim else v
            if k in self.stats:
                self.stats[k] = jnp.maximum(self.stats[k], v)
            else:
                self.stats[k] = v
        for k, v in (scanned_losses or {}).items():
            v = jnp.sum(v) if v.ndim else v
            self.add_loss(k, v)


def eval_context(policy: Optional[QuantPolicy] = None, **kw) -> Context:
    """A non-training ``Context`` (float32 policy unless given)."""
    return Context(policy=policy or QuantPolicy.float32(), train=False, **kw)


def train_context(policy: Optional[QuantPolicy] = None, rng=None, **kw) -> Context:
    """A training ``Context`` carrying ``rng`` for dropout and QAT noise."""
    return Context(policy=policy or QuantPolicy.float32(), train=True, rng=rng, **kw)


# --------------------------------------------------------------------------
# Param tree helpers
# --------------------------------------------------------------------------

def param_count(params: Params) -> int:
    """Total number of scalar parameters in a param pytree."""
    leaves = jax.tree_util.tree_leaves(params)
    return int(sum(l.size for l in leaves if hasattr(l, "size")))


def param_bytes(params: Params) -> int:
    """Total storage bytes of a param pytree (int8 counts 1)."""
    leaves = jax.tree_util.tree_leaves(params)
    return int(sum(l.size * l.dtype.itemsize for l in leaves if hasattr(l, "size")))


def tree_paths(params: Params, prefix: str = "") -> Dict[str, Any]:
    """Flatten a nested-dict tree to {slash/path: leaf}."""
    out: Dict[str, Any] = {}

    def rec(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, f"{path}/{k}" if path else str(k))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, f"{path}/{i}" if path else str(i))
        else:
            out[path] = node

    rec(params, prefix)
    return out


def map_with_path(fn: Callable[[str, Any], Any], params: Params) -> Params:
    """Map leaf -> leaf with access to the slash path (dict trees only)."""

    def rec(node, path):
        if isinstance(node, dict):
            return {k: rec(v, f"{path}/{k}" if path else str(k)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            out = [rec(v, f"{path}/{i}" if path else str(i))
                   for i, v in enumerate(node)]
            return type(node)(out) if isinstance(node, tuple) else out
        return fn(path, node)

    return rec(params, "")
