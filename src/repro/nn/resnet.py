"""The paper's evaluation network: ResNetv1-6 (Fig. 4), 1D and 2D.

Topology (constant ``filters`` f everywhere, matching Fig. 4 / Appendix E):

    conv1(k) → relu
    [ conv2(k) → relu → conv3(k) ] + shortcut-conv(1x1) → add → relu
    maxpool(pool)
    [ conv4(k) → relu → conv5(k) ] + identity → add → relu
    global-maxpool → fully-connected(classes)

All three execution paths are supported end-to-end:
float / fake-quant (QAT Sec. 4.3, PTQ-eval), and **full integer** (Sec. 5.8 —
input arrives as a QTensor, activations flow as QTensor, ReLU/MaxPool pass
through without requantization, Add re-aligns operands, the classifier output
is dequantized to float logits).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.qformat import QTensor
from repro.nn.layers import (Conv1D, Conv2D, Dense, global_avg_pool, max_pool,
                             qadd, relu)
from repro.nn.module import Context, Params


def _global_max_pool(x, ndim: int):
    axes = (1,) if ndim == 1 else (1, 2)
    if isinstance(x, QTensor):
        return QTensor(jnp.max(x.q, axis=axes), x.n, x.width, x.channel_axis)
    return jnp.max(x, axis=axes)


@dataclasses.dataclass(frozen=True)
class ResNetV1_6:
    """The paper's small ResNetv1-6 (conv stem, two residual stages,
    global pool + classifier) for the MCU-scale image/HAR tasks.
    """
    in_channels: int
    filters: int
    classes: int
    kernel: int = 3
    pool: int = 4
    ndim: int = 1                 # 1 (UCI-HAR/SMNIST) or 2 (GTSRB)
    global_pool: str = "max"      # paper's net ends in a max pool
    dtype: Any = jnp.float32
    name: str = "resnet6"

    def _conv(self, cin, cout, k, name):
        mk = Conv1D if self.ndim == 1 else Conv2D
        return mk(cin, cout, k, padding="SAME", dtype=self.dtype, name=name)

    def _layers(self):
        f, k = self.filters, self.kernel
        return {
            "conv1": self._conv(self.in_channels, f, k, "conv1"),
            "conv2": self._conv(f, f, k, "conv2"),
            "conv3": self._conv(f, f, k, "conv3"),
            "short1": self._conv(f, f, 1, "short1"),
            "conv4": self._conv(f, f, k, "conv4"),
            "conv5": self._conv(f, f, k, "conv5"),
            "fc": Dense(f, self.classes, dtype=self.dtype, name="fc"),
        }

    def init(self, key) -> Params:
        """Create all convolution/BN/classifier parameters."""
        ls = self._layers()
        ks = jax.random.split(key, len(ls))
        return {nm: l.init(k) for (nm, l), k in zip(ls.items(), ks)}

    def apply(self, params: Params, x, ctx: Context):
        """x: (B, S, C) for 1D, (B, H, W, C) for 2D — float or QTensor."""
        ctx = ctx.scope(self.name)
        ls = self._layers()

        h = relu(ls["conv1"].apply(params["conv1"], x, ctx))
        r = relu(ls["conv2"].apply(params["conv2"], h, ctx))
        r = ls["conv3"].apply(params["conv3"], r, ctx)
        sc = ls["short1"].apply(params["short1"], h, ctx)
        h = relu(qadd(r, sc, ctx, site="add1"))
        h = max_pool(h, self.pool, ndim=self.ndim)
        r = relu(ls["conv4"].apply(params["conv4"], h, ctx))
        r = ls["conv5"].apply(params["conv5"], r, ctx)
        h = relu(qadd(r, h, ctx, site="add2"))
        if self.global_pool == "max":
            h = _global_max_pool(h, self.ndim)
        else:
            h = global_avg_pool(h, ndim=self.ndim)
        logits = ls["fc"].apply(params["fc"], h, ctx)
        if isinstance(logits, QTensor):
            return logits.dequantize()
        return logits
