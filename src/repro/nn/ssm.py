"""State-space sequence mixers: Mamba (jamba hybrid) and RWKV6 "Finch".

Both are attention-free recurrences with O(1) decode state — the reason the
``long_500k`` shape runs only on these families (DESIGN.md §5).

Quantization applicability (DESIGN.md §Arch-applicability): the paper's
technique covers every *projection* GEMM (in/out/x/dt for Mamba; r/k/v/g/o and
the FFN for RWKV) via the shared :class:`Dense` layer.  The recurrent **state
itself stays fp32**: a Qm.n-quantized state re-quantizes every step and the
truncation error compounds over thousands of steps (the paper's engine never
re-quantizes inside max-pool for the same reason — precision lost is never
recovered).  ``ssm_state`` is in ``QuantPolicy.skip_kinds``.

Implementation notes:
  * Train/prefill use a **chunked scan**: an outer ``lax.scan`` over chunks
    carries the (B, ...) state; within a chunk the recurrence is unrolled in
    matrix form where possible.  Chunk size bounds the materialized
    (B, chunk, d_inner, d_state) tensor — VMEM/HBM-friendly.
  * Decode is a single recurrence step against carried state (serve path).
  * TP: d_inner / heads shard over `model`; the state shards with them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.layers import Dense, lecun_normal, normal_init
from repro.nn.module import Context, Params

# --------------------------------------------------------------------------
# Mamba (selective SSM, v1 — as interleaved in Jamba)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Mamba:
    """Mamba selective-SSM mixer with a recurrent decode state."""
    d_model: int
    d_inner: int = 0          # default 2*d_model
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0          # default ceil(d_model/16)
    chunk: int = 128
    dtype: Any = jnp.float32
    name: str = "mamba"

    @property
    def _di(self):
        return self.d_inner or 2 * self.d_model

    @property
    def _dtr(self):
        return self.dt_rank or max(1, math.ceil(self.d_model / 16))

    def _projs(self):
        di = self._di
        return {
            "in_proj": Dense(self.d_model, 2 * di, use_bias=False, dtype=self.dtype,
                             name="in_proj"),
            "x_proj": Dense(di, self._dtr + 2 * self.d_state, use_bias=False,
                            dtype=self.dtype, name="x_proj"),
            "dt_proj": Dense(self._dtr, di, use_bias=True, dtype=self.dtype,
                             name="dt_proj"),
            "out_proj": Dense(di, self.d_model, use_bias=False, dtype=self.dtype,
                              name="out_proj"),
        }

    def init(self, key) -> Params:
        """Create projection, conv and SSM parameters."""
        ks = jax.random.split(key, 6)
        di, n = self._di, self.d_state
        p = {nm: l.init(k) for (nm, l), k in zip(self._projs().items(), ks)}
        # depthwise causal conv over time: (d_conv, di)
        p["conv"] = {"kernel": lecun_normal(ks[4], (self.d_conv, 1, di)),
                     "bias": jnp.zeros((di,), jnp.float32)}
        # S4D-real init for A; D skip
        a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
        p["ssm"] = {"a_log": jnp.log(a), "d_skip": jnp.ones((di,), jnp.float32)}
        return p

    def _conv1d(self, params, x, conv_state=None):
        """Causal depthwise conv; returns (y, padded_input).

        ``conv_state`` is the trailing (K-1) inputs of the previous call
        (zeros for a fresh sequence), so prefill-with-state and single-token
        decode share one code path.  The second return value is the full
        left-padded input ``xp``; callers slice their own carry window out of
        it (the trailing K-1 rows for dense decode, the K-1 rows ending at
        the chunk's live length for per-slot chunked prefill).
        """
        w = params["conv"]["kernel"]                      # (K, 1, di)
        if hasattr(w, "dequantize"):
            # weight-only int8 serving stores every >=2-dim kernel as a
            # QTensor; the depthwise conv reads its weight directly (no
            # Dense/wq_matmul path), so dequantize here
            w = w.dequantize()
        w = w.astype(self.dtype)
        b = params["conv"]["bias"].astype(self.dtype)
        k = self.d_conv
        if conv_state is not None:
            xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
        else:
            xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        y = jax.lax.conv_general_dilated(
            xp, w, (1,), "VALID", dimension_numbers=("NWC", "WIO", "NWC"),
            feature_group_count=self._di) + b
        return y, xp

    def _ssm_inputs(self, params, xc, ctx):
        """Data-dependent dt, B, C from the conv output."""
        projs = self._projs()
        dbc = projs["x_proj"].apply(params["x_proj"], xc, ctx)
        dt, bmat, cmat = jnp.split(
            dbc, [self._dtr, self._dtr + self.d_state], axis=-1)
        dt = jax.nn.softplus(projs["dt_proj"].apply(params["dt_proj"], dt, ctx)
                             .astype(jnp.float32))                  # (B,L,di)
        return dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32)

    def _scan(self, a_log, d_skip, xc, dt, bmat, cmat, h0):
        """Chunked selective scan. xc/dt (B,L,di); bmat/cmat (B,L,N); h0 (B,di,N)."""
        bsz, L, di = xc.shape
        n = self.d_state
        A = -jnp.exp(a_log)                                          # (di, N)
        ch = min(self.chunk, L)
        pad = (-L) % ch
        if pad:
            z = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
            xc, dt, bmat, cmat = z(xc), z(dt), z(bmat), z(cmat)
        nc = xc.shape[1] // ch

        xcf = xc.astype(jnp.float32)

        def chunk_step(h, args):
            xk, dtk, bk, ck = args                                   # (B,ch,·)
            da = jnp.exp(dtk[..., None] * A)                         # (B,ch,di,N)
            dbx = (dtk * xk)[..., None] * bk[:, :, None, :]          # (B,ch,di,N)

            def inner(hc, t):
                hc = da[:, t] * hc + dbx[:, t]
                return hc, jnp.einsum("bdn,bn->bd", hc, ck[:, t])

            h, ys = jax.lax.scan(inner, h, jnp.arange(ch))
            return h, jnp.moveaxis(ys, 0, 1)                         # (B,ch,di)

        args = tuple(t.reshape(bsz, nc, ch, *t.shape[2:]).swapaxes(0, 1)
                     for t in (xcf, dt, bmat, cmat))
        h, ys = jax.lax.scan(chunk_step, h0, args)
        y = ys.swapaxes(0, 1).reshape(bsz, nc * ch, di)[:, :L]
        return y + xcf * d_skip, h

    def apply(self, params: Params, x, ctx: Context,
              state: Optional[Dict[str, Any]] = None,
              chunk=None,
              ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
        """x: (B, S, D).  state: {'h': (B,di,N) f32, 'conv': (B,K-1,di)} or None.

        With ``chunk`` (a ``KVChunk(slot, start, length)``), x is one (1, S, D)
        prompt chunk of a single serving slot: the slot's state row is
        gathered, advanced over the chunk's live ``length`` positions (the pad
        tail is masked to dt=0, an identity state update), and scattered back.
        """
        ctx = ctx.scope(self.name)
        projs = self._projs()
        b, s, _ = x.shape
        di, n = self._di, self.d_state
        k = self.d_conv

        xz = projs["in_proj"].apply(params["in_proj"], x, ctx)
        xin, z = jnp.split(xz, 2, axis=-1)
        xin = ctx.constrain(xin, "batch", None, "ff")

        decode = state is not None and chunk is None
        if chunk is not None:
            h0 = jax.lax.dynamic_index_in_dim(state["h"], chunk.slot, 0,
                                              keepdims=True)
            conv_state = jax.lax.dynamic_index_in_dim(state["conv"], chunk.slot,
                                                      0, keepdims=True)
        else:
            h0 = state["h"] if decode else jnp.zeros((b, di, n), jnp.float32)
            conv_state = state["conv"] if decode else None
        xc, xp = self._conv1d(params, xin, conv_state)
        xc = jax.nn.silu(xc)

        dt, bmat, cmat = self._ssm_inputs(params, xc, ctx)
        if chunk is not None:
            # dt=0 on the pad tail: da=exp(0)=1, dbx=0 — identity update, so
            # the scanned state lands exactly at position `length`.
            live = jnp.arange(s)[None, :, None] < chunk.length
            dt = jnp.where(live, dt, 0.0)

        if decode and s == 1:
            A = -jnp.exp(params["ssm"]["a_log"])
            da = jnp.exp(dt[:, 0, :, None] * A)
            h = da * h0 + (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
                * bmat[:, 0, None, :]
            y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None]
            y = y + xc.astype(jnp.float32) * params["ssm"]["d_skip"]
        else:
            y, h = self._scan(params["ssm"]["a_log"], params["ssm"]["d_skip"],
                              xc, dt, bmat, cmat, h0)

        y = (y.astype(self.dtype) * jax.nn.silu(z)).astype(self.dtype)
        out = projs["out_proj"].apply(params["out_proj"], y, ctx)
        if chunk is not None:
            # conv carry: the K-1 inputs ending at the live length (xp is the
            # conv-state-prepended input, so row `length` is the first carry row)
            new_state = {"h": jax.lax.dynamic_update_slice_in_dim(
                state["h"], h, chunk.slot, axis=0)}
            if k > 1:
                carry = jax.lax.dynamic_slice_in_dim(xp, chunk.length, k - 1,
                                                     axis=1)
                new_state["conv"] = jax.lax.dynamic_update_slice_in_dim(
                    state["conv"], carry.astype(state["conv"].dtype),
                    chunk.slot, axis=0)
            else:
                new_state["conv"] = state["conv"]
        elif decode:
            new_state = {"h": h,
                         "conv": xp[:, -(k - 1):] if k > 1 else None}
        else:
            new_state = None
        return out, new_state

    def init_state(self, batch: int) -> Dict[str, Any]:
        """Zeroed per-slot recurrent state (conv window + SSM state)."""
        return {"h": jnp.zeros((batch, self._di, self.d_state), jnp.float32),
                "conv": jnp.zeros((batch, self.d_conv - 1, self._di), self.dtype)}


# --------------------------------------------------------------------------
# RWKV6 "Finch" — data-dependent decay linear attention
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RWKV6TimeMix:
    """RWKV6 time-mixing: S_t = diag(w_t)·S_{t-1} + kᵀv; o = r·(S + diag(u)kᵀv).

    Simplified-faithful Finch: data-dependent per-channel decay w_t through a
    low-rank MLP (the paper's LoRA), token-shift interpolation on the inputs,
    grouped heads with per-head (N×N) fp32 state.
    """

    d_model: int
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 128
    dtype: Any = jnp.float32
    name: str = "timemix"

    @property
    def n_heads(self):
        """Number of time-mix heads (``d_model / head_dim``)."""
        return self.d_model // self.head_dim

    def _projs(self):
        d = self.d_model
        mk = lambda nm: Dense(d, d, use_bias=False, dtype=self.dtype, name=nm)
        return {"wr": mk("wr"), "wk": mk("wk"), "wv": mk("wv"),
                "wg": mk("wg"), "wo": mk("wo")}

    def init(self, key) -> Params:
        """Create time-mix interpolation, decay and projection parameters."""
        ks = jax.random.split(key, 9)
        d, h, n = self.d_model, self.n_heads, self.head_dim
        p = {nm: l.init(k) for (nm, l), k in zip(self._projs().items(), ks)}
        p["decay"] = {  # w0 + tanh(x A) B  (the Finch decay LoRA)
            "w0": jnp.full((d,), -6.0, jnp.float32),
            "a": normal_init(ks[5], (d, self.decay_lora), std=0.01),
            "b": normal_init(ks[6], (self.decay_lora, d), std=0.01),
        }
        p["bonus_u"] = normal_init(ks[7], (h, n), std=0.5)
        p["mix"] = {"x": jnp.full((5, d), 0.5, jnp.float32)}  # token-shift lerp
        p["ln_out"] = {"scale": jnp.ones((d,), jnp.float32)}
        return p

    def _token_shift(self, x, last):
        """x_{t-1} per position; `last` is (B,1,D) carry for decode."""
        prev = jnp.concatenate([last, x[:, :-1]], axis=1)
        return prev

    def _scan(self, r, k, v, w, u, s0):
        """Recurrence over time, chunked.  r/k/v (B,L,H,N); w (B,L,H,N) decay
        in (0,1); u (H,N); s0 (B,H,N,N).  Returns (out (B,L,H,N), sT)."""
        bsz, L, h, n = r.shape
        ch = min(self.chunk, L)
        pad = (-L) % ch
        if pad:
            z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
            r, k, v, w = z(r), z(k), z(v), z(w)
            w = w.at[:, L:].set(1.0)  # identity decay on padding
        nc = r.shape[1] // ch

        def chunk_step(s, args):
            rk, kk, vk, wk = args                                     # (B,ch,H,N)

            def inner(sc, t):
                kv = kk[:, t, :, :, None] * vk[:, t, :, None, :]      # (B,H,N,N)
                o = jnp.einsum("bhn,bhnm->bhm", rk[:, t],
                               sc + u[None, :, :, None] * kv)
                sc = wk[:, t, :, :, None] * sc + kv
                return sc, o

            s, os = jax.lax.scan(inner, s, jnp.arange(ch))
            return s, jnp.moveaxis(os, 0, 1)                          # (B,ch,H,N)

        args = tuple(t.reshape(bsz, nc, ch, h, n).swapaxes(0, 1)
                     for t in (r, k, v, w))
        s, outs = jax.lax.scan(chunk_step, s0, args)
        out = outs.swapaxes(0, 1).reshape(bsz, nc * ch, h, n)[:, :L]
        return out, s

    def apply(self, params: Params, x, ctx: Context,
              state: Optional[Dict[str, Any]] = None,
              chunk=None,
              ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
        """Run the WKV recurrence over ``x``; returns output and new state.

        With ``chunk``, x is one (1, S, D) prompt chunk of a single serving
        slot: the slot's (s, shift) rows are gathered, the pad tail is masked
        to an identity update (decay 1, k 0), and the final state is scattered
        back into the slot row.
        """
        ctx = ctx.scope(self.name)
        projs = self._projs()
        b, s, d = x.shape
        h, n = self.n_heads, self.head_dim

        if chunk is not None:
            last = jax.lax.dynamic_index_in_dim(state["shift"], chunk.slot, 0,
                                                keepdims=True)
            s0 = jax.lax.dynamic_index_in_dim(state["s"], chunk.slot, 0,
                                              keepdims=True)
        else:
            last = state["shift"] if state is not None else jnp.zeros(
                (b, 1, d), x.dtype)
            s0 = state["s"] if state is not None else jnp.zeros(
                (b, h, n, n), jnp.float32)
        prev = self._token_shift(x, last.astype(x.dtype))
        mix = params["mix"]["x"]                                      # (5, D)
        xr, xk, xv, xg, xw = (x + mix[i] * (prev - x) for i in range(5))

        r = projs["wr"].apply(params["wr"], xr, ctx).reshape(b, s, h, n)
        k = projs["wk"].apply(params["wk"], xk, ctx).reshape(b, s, h, n)
        v = projs["wv"].apply(params["wv"], xv, ctx).reshape(b, s, h, n)
        g = jax.nn.silu(projs["wg"].apply(params["wg"], xg, ctx))

        # data-dependent decay (fp32; `decay` path skipped from quantization)
        dk = params["decay"]
        wraw = dk["w0"] + jnp.tanh(xw.astype(jnp.float32) @ dk["a"]) @ dk["b"]
        w = jnp.exp(-jnp.exp(wraw)).reshape(b, s, h, n)               # (0,1)

        r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
        if chunk is not None:
            # identity update on the pad tail: decay 1 keeps S, k=0 adds nothing
            live = jnp.arange(s)[None, :, None, None] < chunk.length
            w = jnp.where(live, w, 1.0)
            k32 = jnp.where(live, k32, 0.0)
        s0 = ctx.constrain(s0, "batch", "heads", None, None)

        if state is not None and chunk is None and s == 1:
            kv = k32[:, 0, :, :, None] * v32[:, 0, :, None, :]
            o = jnp.einsum("bhn,bhnm->bhm",
                           r32[:, 0], s0 + params["bonus_u"][None, :, :, None] * kv)
            sT = w[:, 0, :, :, None] * s0 + kv
            out = o[:, None]
        else:
            out, sT = self._scan(r32, k32, v32, w, params["bonus_u"], s0)

        # per-head group norm (ln_out), then gate and project
        out = out.reshape(b, s, h, n)
        mu = jnp.mean(out, axis=-1, keepdims=True)
        var = jnp.var(out, axis=-1, keepdims=True)
        out = (out - mu) * jax.lax.rsqrt(var + 1e-5)
        out = out.reshape(b, s, d) * params["ln_out"]["scale"]
        out = (out.astype(self.dtype) * g).astype(self.dtype)
        y = projs["wo"].apply(params["wo"], out, ctx)
        new_state = None
        if chunk is not None:
            tail = jax.lax.dynamic_slice_in_dim(x, chunk.length - 1, 1, axis=1)
            new_state = {
                "s": jax.lax.dynamic_update_slice_in_dim(
                    state["s"], sT, chunk.slot, axis=0),
                "shift": jax.lax.dynamic_update_slice_in_dim(
                    state["shift"], tail.astype(state["shift"].dtype),
                    chunk.slot, axis=0)}
        elif state is not None:
            new_state = {"s": sT, "shift": x[:, -1:, :]}
        return y, new_state

    def init_state(self, batch: int) -> Dict[str, Any]:
        """Zeroed per-slot WKV state (last token + per-head accumulator)."""
        return {"s": jnp.zeros((batch, self.n_heads, self.head_dim, self.head_dim),
                               jnp.float32),
                "shift": jnp.zeros((batch, 1, self.d_model), self.dtype)}


@dataclasses.dataclass(frozen=True)
class RWKV6ChannelMix:
    """RWKV6 channel-mixing FFN: relu²(wk(x̃))·wv with a receptance gate."""

    d_model: int
    d_ff: int
    dtype: Any = jnp.float32
    name: str = "chanmix"

    def _projs(self):
        return {
            "wk": Dense(self.d_model, self.d_ff, use_bias=False, dtype=self.dtype,
                        name="wk"),
            "wv": Dense(self.d_ff, self.d_model, use_bias=False, dtype=self.dtype,
                        name="wv"),
            "wr": Dense(self.d_model, self.d_model, use_bias=False, dtype=self.dtype,
                        name="wr"),
        }

    def init(self, key) -> Params:
        """Create channel-mix interpolation and projection parameters."""
        ks = jax.random.split(key, 3)
        p = {nm: l.init(k) for (nm, l), k in zip(self._projs().items(), ks)}
        p["mix"] = {"x": jnp.full((2, self.d_model), 0.5, jnp.float32)}
        return p

    def apply(self, params: Params, x, ctx: Context,
              state: Optional[Dict[str, Any]] = None,
              chunk=None):
        """Squared-ReLU channel mix; returns output and shifted-token state.

        With ``chunk``, x is a single slot's (1, S, D) prompt chunk; the
        shift carry is gathered from / scattered back to the slot row.
        """
        ctx = ctx.scope(self.name)
        projs = self._projs()
        if chunk is not None:
            last = jax.lax.dynamic_index_in_dim(state["shift"], chunk.slot, 0,
                                                keepdims=True).astype(x.dtype)
        else:
            last = state["shift"] if state is not None else jnp.zeros(
                (x.shape[0], 1, x.shape[-1]), x.dtype)
        prev = jnp.concatenate([last, x[:, :-1]], axis=1)
        mix = params["mix"]["x"]
        xk = x + mix[0] * (prev - x)
        xr = x + mix[1] * (prev - x)
        k = projs["wk"].apply(params["wk"], xk, ctx)
        k = jnp.square(jax.nn.relu(k))
        k = ctx.constrain(k, "batch", None, "ff")
        kv = projs["wv"].apply(params["wv"], k, ctx)
        r = jax.nn.sigmoid(projs["wr"].apply(params["wr"], xr, ctx))
        y = r * kv
        if chunk is not None:
            tail = jax.lax.dynamic_slice_in_dim(x, chunk.length - 1, 1, axis=1)
            new_state = {"shift": jax.lax.dynamic_update_slice_in_dim(
                state["shift"], tail.astype(state["shift"].dtype),
                chunk.slot, axis=0)}
        elif state is not None:
            new_state = {"shift": x[:, -1:, :]}
        else:
            new_state = None
        return y, new_state
