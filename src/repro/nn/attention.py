"""Grouped-query attention with RoPE, blocked (flash-style) softmax, KV cache
and the paper-derived int8 KV-cache quantization.

Three entry modes:
  * train/prefill: blocked online-softmax attention (peak memory ~
    block_q x block_kv per head, so 32k-seq prefill fits per-device HBM),
  * decode: single-token step against a cache; float cache uses the same
    einsum path, int8 cache dispatches to the ``qdecode_attn`` Pallas kernel
    (dequant-in-VMEM, half the HBM bytes — DESIGN.md §2),
  * cross-attention (whisper decoder): kv from encoder output, no causal mask.

Two serving cache geometries share one dict contract (see the KV-cache
section below): dense per-slot slabs and the paged pool + page-table layout
(``init_paged_kv_cache``); update/append/attention dispatch on
``is_paged_cache``, and docs/serving.md diagrams the whole thing.

TP: head dims shard over the `model` mesh axis via sharding constraints on
the (B, S, H, D) activations (heads-per-device = H / tp).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import qformat
from repro.nn.layers import Dense
from repro.nn.module import Context, Params

# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """Inverse rotary frequencies ``1/theta^(2i/d)`` over half the head dim."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Blocked online-softmax attention (pure-JAX flash)
# --------------------------------------------------------------------------

def blocked_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int = 0,
    block_q: int = 1024,
    block_kv: int = 1024,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Online-softmax attention; never materializes the full score matrix."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)

    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    # pad seq dims to block multiples
    pq = (-sq) % bq
    pkv = (-skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    sq_p, skv_p = q.shape[1], k.shape[1]
    nq, nkv = sq_p // bq, skv_p // bkv

    qb = q.reshape(b, nq, bq, hkv, g, d).astype(jnp.float32) * scale
    kb = k.reshape(b, nkv, bkv, hkv, d).astype(jnp.float32)
    vb = v.reshape(b, nkv, bkv, hkv, d).astype(jnp.float32)

    valid_kv = skv if kv_len is None else kv_len

    def q_block(carry, iq):
        qi = qb[:, iq]  # (B, bq, Hkv, G, D)
        qpos = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(state, ikv):
            m, l, acc = state
            kj = kb[:, ikv]  # (B, bkv, Hkv, D)
            vj = vb[:, ikv]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj)  # (B,Hkv,G,bq,bkv)
            kpos = ikv * bkv + jnp.arange(bkv)
            mask = kpos[None, :] < valid_kv
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            else:
                mask = jnp.broadcast_to(mask, (bq, bkv))
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vj)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (B,Hkv,G,bq,D)
        return carry, out.transpose(0, 3, 1, 2, 4)  # (B,bq,Hkv,G,D)

    _, outs = jax.lax.scan(q_block, (), jnp.arange(nq))
    # outs: (nq, B, bq, Hkv, G, D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq_p, hq, d)
    return out[:, :sq].astype(q.dtype)


# --------------------------------------------------------------------------
# Flash attention with custom VJP (recompute-in-backward)
#
# The naive blocked fwd above, when differentiated, makes lax.scan save every
# per-block probability tensor P (B,Hkv,G,bq,bkv) — ≈8 GiB/layer at 4k seq —
# which defeats the point of never materializing the score matrix.  The
# custom VJP saves only (q, k, v, out, lse) and recomputes P blockwise in the
# backward (the FlashAttention-2 recipe), so residuals are O(B·S·H·D).
# --------------------------------------------------------------------------


def _flash_fwd_inner(q, k, v, q_offset, valid_kv, causal, block_q, block_kv):
    """Returns (out (B,Sq,Hq,D) f32, lse (B,Hkv,G,Sq) f32)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    bq, bkv = min(block_q, sq), min(block_kv, skv)
    pq, pkv = (-sq) % bq, (-skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    nq, nkv = q.shape[1] // bq, k.shape[1] // bkv
    qb = q.reshape(b, nq, bq, hkv, g, d).astype(jnp.float32) * scale
    kb = k.reshape(b, nkv, bkv, hkv, d).astype(jnp.float32)
    vb = v.reshape(b, nkv, bkv, hkv, d).astype(jnp.float32)

    def q_block(_, iq):
        qi = qb[:, iq]
        qpos = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(state, ikv):
            m, l, acc = state
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kb[:, ikv])
            kpos = ikv * bkv + jnp.arange(bkv)
            mask = kpos[None, :] < valid_kv
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            else:
                mask = jnp.broadcast_to(mask, (bq, bkv))
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb[:, ikv])
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return _, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, hq, d)[:, :sq]
    lse = jnp.moveaxis(lses, 0, -2).reshape(b, hkv, g, nq * bq)[..., :sq]
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def flash_attention(q, k, v, q_offset, kv_len, causal: bool,
                    block_q: int = 512, block_kv: int = 1024):
    """Online-softmax attention, O(S) memory in fwd AND bwd.

    q (B,Sq,Hq,D); k/v (B,Skv,Hkv,D); GQA via Hq = G·Hkv.
    q_offset/kv_len: int32 scalars (decode/prefill positioning + cache mask).
    """
    out, _ = _flash_fwd_inner(q, k, v, q_offset, kv_len, causal,
                              block_q, block_kv)
    return out.astype(q.dtype)


def _flash_fwd(q, k, v, q_offset, kv_len, causal, block_q, block_kv):
    out, lse = _flash_fwd_inner(q, k, v, q_offset, kv_len, causal,
                                block_q, block_kv)
    return out.astype(q.dtype), (q, k, v, out, lse, q_offset, kv_len)


def _flash_bwd(causal, block_q, block_kv, res, gout):
    q, k, v, out, lse, q_offset, valid_kv = res
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    bq, bkv = min(block_q, sq), min(block_kv, skv)
    pq, pkv = (-sq) % bq, (-skv) % bkv
    pad_q = lambda t: jnp.pad(t, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else t
    pad_kv = lambda t: jnp.pad(t, ((0, 0), (0, pkv), (0, 0), (0, 0))) if pkv else t
    qs = pad_q(q).astype(jnp.float32) * scale
    kf = pad_kv(k).astype(jnp.float32)
    vf = pad_kv(v).astype(jnp.float32)
    go = pad_q(gout).astype(jnp.float32)
    of = pad_q(out)
    nq, nkv = qs.shape[1] // bq, kf.shape[1] // bkv
    qb = qs.reshape(b, nq, bq, hkv, g, d)
    gb = go.reshape(b, nq, bq, hkv, g, d).transpose(0, 1, 3, 4, 2, 5)
    kb = kf.reshape(b, nkv, bkv, hkv, d)
    vb = vf.reshape(b, nkv, bkv, hkv, d)
    if pq:
        lse = jnp.pad(lse, ((0, 0),) * 3 + ((0, pq),))
    lseb = lse.reshape(b, hkv, g, nq, bq)
    # D_i = rowsum(dout * out)
    Dall = jnp.sum(go * of, axis=-1)                       # (B, Sq+p, Hq)
    Db = Dall.reshape(b, nq, bq, hkv, g).transpose(0, 1, 3, 4, 2)

    def q_block(carry, iq):
        dk, dv = carry
        qi = qb[:, iq]                                     # (B,bq,Hkv,G,D)
        gi = gb[:, iq]                                     # (B,Hkv,G,bq,D)
        lsei = lseb[:, :, :, iq]                           # (B,Hkv,G,bq)
        Di = Db[:, iq]                                     # (B,Hkv,G,bq)
        qpos = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(state, ikv):
            dq_i, dk, dv = state
            kj, vj = kb[:, ikv], vb[:, ikv]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj)
            kpos = ikv * bkv + jnp.arange(bkv)
            mask = kpos[None, :] < valid_kv
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            else:
                mask = jnp.broadcast_to(mask, (bq, bkv))
            s = jnp.where(mask[None, None, None], s, -1e30)
            p = jnp.exp(s - lsei[..., None])               # recomputed P
            dv_j = jnp.einsum("bhgqk,bhgqd->bkhd", p, gi)
            dp = jnp.einsum("bhgqd,bkhd->bhgqk", gi, vj)
            ds = p * (dp - Di[..., None])                  # (B,Hkv,G,bq,bkv)
            dq_i = dq_i + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kj)
            dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qi)
            dk = jax.lax.dynamic_update_slice_in_dim(
                dk, jax.lax.dynamic_slice_in_dim(dk, ikv * bkv, bkv, 1) + dk_j,
                ikv * bkv, axis=1)
            dv = jax.lax.dynamic_update_slice_in_dim(
                dv, jax.lax.dynamic_slice_in_dim(dv, ikv * bkv, bkv, 1) + dv_j,
                ikv * bkv, axis=1)
            return (dq_i, dk, dv), None

        dq0 = jnp.zeros((b, bq, hkv, g, d), jnp.float32)
        (dq_i, dk, dv), _ = jax.lax.scan(kv_step, (dq0, dk, dv),
                                         jnp.arange(nkv))
        return (dk, dv), dq_i * scale

    dk0 = jnp.zeros((b, nkv * bkv, hkv, d), jnp.float32)
    dv0 = jnp.zeros_like(dk0)
    (dk, dv), dqs = jax.lax.scan(q_block, (dk0, dv0), jnp.arange(nq))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, hq, d)[:, :sq]
    return (dq.astype(q.dtype), dk[:, :skv].astype(k.dtype),
            dv[:, :skv].astype(v.dtype), None, None)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def decode_attention(
    q: jax.Array,        # (B, 1, Hq, D)
    k: jax.Array,        # (B, Skv, Hkv, D)  float or int8
    v: jax.Array,
    kv_len: jax.Array,
    *,
    k_n=None, v_n=None,  # int8 dequant exponents (paper Qm.n grid)
    sharded: bool = False,
) -> jax.Array:
    """Single-token decode over the full cache.

    int8 caches route to the fused ``qdecode_attn`` kernel by default
    (Pallas on TPU, the jnp oracle elsewhere — kernels/ops.py dispatch):
    dequantization happens in VMEM right before the softmax update, so the
    HBM read is half/quarter the float bytes — the paper's memory win at the
    decode-bound roofline.  The einsum fallback below dequantizes the whole
    cache to f32 first; it is kept for ``sharded=True``, where the XLA
    partitioner shards the cache-length axis over `model` (KV/context
    parallelism) and combines with two tiny all-reduces — the Pallas kernel
    has no SPMD rule.  Float caches always take the einsum path.

    ``kv_len`` may be a scalar (lockstep batch) or a (B,) vector (per-slot
    continuous batching): each slot masks its own live prefix.
    """
    b, _, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if k.dtype == jnp.int8 and not sharded:
        from repro.kernels import ops as kops

        out = kops.qdecode_attn(q[:, 0].astype(jnp.float32), k, v,
                                k_n, v_n, kv_len)
        return out[:, None].astype(q.dtype)
    if k.dtype == jnp.int8:
        kf = k.astype(jnp.float32) * jnp.exp2(-k_n.astype(jnp.float32))
        vf = v.astype(jnp.float32) * jnp.exp2(-v_n.astype(jnp.float32))
    else:
        kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    qf = q[:, 0].reshape(b, hkv, g, d).astype(jnp.float32) / math.sqrt(d)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kf)
    if jnp.ndim(kv_len) == 1:
        kv_len = kv_len[:, None, None, None]
    mask = jnp.arange(skv)[None, None, None, :] < kv_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return out.reshape(b, 1, hq, d)


def paged_decode_attention(q: jax.Array, cache: Dict[str, Any],
                           *, sharded: bool = False) -> jax.Array:
    """Single-token decode over a paged cache (q (B, 1, Hq, D)).

    int8 pools route to the ``qpaged_decode_attn`` kernel (Pallas on TPU,
    the gather-dense oracle elsewhere — kernels/ops.py dispatch), which DMAs
    one pool page per grid step through a scalar-prefetched page-table
    lookup.  Float pools — and sharded meshes, where the Pallas kernel has
    no SPMD rule — densify each slot's pages with a table gather and fall
    through to the dense einsum path.
    """
    table, ln = cache["page_table"], cache["len"]
    if cache["k"].dtype == jnp.int8 and not sharded:
        from repro.kernels import ops as kops

        out = kops.qpaged_decode_attn(q[:, 0].astype(jnp.float32),
                                      cache["k"], cache["v"],
                                      cache["k_n"], cache["v_n"], table, ln)
        return out[:, None].astype(q.dtype)
    b = q.shape[0]
    mp, ps = table.shape[1], cache["k"].shape[1]
    sh = (b, mp * ps) + cache["k"].shape[2:]
    kd = jnp.take(cache["k"], jnp.maximum(table, 0), axis=0).reshape(sh)
    vd = jnp.take(cache["v"], jnp.maximum(table, 0), axis=0).reshape(sh)
    return decode_attention(q, kd, vd, ln, k_n=cache.get("k_n"),
                            v_n=cache.get("v_n"), sharded=True)


# --------------------------------------------------------------------------
# KV cache (float or paper-quantized int8; dense slab or paged pool)
# --------------------------------------------------------------------------
#
# Two geometries share one dict-pytree contract (so the scheduler's cache-tree
# walks, scan stacking and jit donation treat them alike):
#
#   dense:  k/v (slots, max_len, Hkv, D); len scalar or (slots,)
#   paged:  k/v (num_pages, page_size, Hkv, D) shared pools,
#           page_table (slots, max_pages) int32 pool indices (-1 = unmapped),
#           len (slots,)
#
# A paged slot's logical row p lives in pool page table[slot, p // page_size]
# at row p % page_size.  The serve-side block allocator (serve/paging.py)
# owns which pool pages belong to which slot; everything here just reads or
# writes *through* the table.  ``is_paged_cache`` is the dispatch predicate
# used by update/append/attention below.


def init_paged_kv_cache(
    slots: int, max_pages: int, page_size: int, num_pages: int,
    n_kv_heads: int, head_dim: int,
    *, quantized: bool, dtype=jnp.bfloat16, cache_n: int = 3,
) -> Dict[str, Any]:
    """The PagedKVCache pytree: a shared K/V page pool plus per-slot tables.

    Args:
      slots: batch slots (page-table rows) — cheap, unlike dense slots.
      max_pages: table width = the per-slot logical length ceiling in pages
        (``ceil(max_len / page_size)``).
      page_size: tokens per page.
      num_pages: pool pages *shared by all slots* — the real capacity knob:
        ``num_pages * page_size`` total resident tokens, vs the dense slab's
        ``slots * max_len`` reserved ones.
      n_kv_heads / head_dim: KV geometry per page row.
      quantized: int8 pool on the paper's Qm.n grid (k_n/v_n exponents) vs
        ``dtype`` float pool.
      dtype: float pool dtype when not quantized.
      cache_n: frozen fractional-bit exponent for the int8 grid.

    Returns:
      dict with ``k``/``v`` pools ``(num_pages, page_size, Hkv, D)``,
      ``page_table`` ``(slots, max_pages)`` int32 initialized to -1
      (unmapped), ``len`` ``(slots,)`` int32, and ``k_n``/``v_n`` when
      quantized — always per-slot (continuous batching is the point).
    """
    shape = (num_pages, page_size, n_kv_heads, head_dim)
    base = {
        "page_table": jnp.full((slots, max_pages), -1, jnp.int32),
        "len": jnp.zeros((slots,), jnp.int32),
    }
    if quantized:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_n": jnp.int32(cache_n), "v_n": jnp.int32(cache_n), **base}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype), **base}


def is_paged_cache(cache: Dict[str, Any]) -> bool:
    """True when ``cache`` is a paged pool dict (has a ``page_table``)."""
    return "page_table" in cache


def gather_kv_pages(cache: Dict[str, Any], slot: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Densify one slot's K/V: pool pages -> (max_pages*page_size, Hkv, D).

    Unmapped (-1) table entries clamp to pool page 0; the junk rows they
    produce sit past the slot's live length, which every consumer masks.
    """
    row = jax.lax.dynamic_index_in_dim(cache["page_table"],
                                       jnp.asarray(slot, jnp.int32),
                                       axis=0, keepdims=False)
    mp = row.shape[0]
    ps = cache["k"].shape[1]
    k = jnp.take(cache["k"], jnp.maximum(row, 0), axis=0)
    v = jnp.take(cache["v"], jnp.maximum(row, 0), axis=0)
    sh = (mp * ps,) + cache["k"].shape[2:]
    return k.reshape(sh), v.reshape(sh)


def paged_flat_index(row: jax.Array, pos: jax.Array, page_size: int,
                     num_pages: int) -> jax.Array:
    """Flat pool row indices for logical positions ``pos`` of one slot.

    ``row``: (max_pages,) int32 page-table row; ``pos``: (N,) int32 logical
    rows.  Position p maps to ``row[p // page_size] * page_size +
    p % page_size``; positions past the table or on unmapped (-1) entries
    map to the out-of-bounds sentinel ``num_pages * page_size``, which
    scatter-with-``mode="drop"`` discards — negative indices would *wrap*,
    so the sentinel must be positive.  The single source of truth for the
    layout (kernels/ref.py mirrors the same contract in its standalone
    oracle).
    """
    mp = row.shape[0]
    pslot = pos // page_size
    page = jnp.take(row, jnp.minimum(pslot, mp - 1))
    valid = (pslot < mp) & (page >= 0)
    return jnp.where(valid, page * page_size + pos % page_size,
                     num_pages * page_size)


def _paged_scatter_rows(pool: jax.Array, rows: jax.Array,
                        flat: jax.Array) -> jax.Array:
    """Scatter (N, Hkv, D) rows into a (P, ps, Hkv, D) pool at flat row
    indices from ``paged_flat_index``; out-of-range indices are dropped."""
    n_pool, ps = pool.shape[0], pool.shape[1]
    flat2 = pool.reshape((n_pool * ps,) + pool.shape[2:])
    return flat2.at[flat].set(rows, mode="drop").reshape(pool.shape)


def copy_kv_page(cache: Dict[str, Any], src: jax.Array, dst: jax.Array,
                 *, layer_axis: bool = False) -> Dict[str, Any]:
    """Copy pool page ``src`` onto pool page ``dst`` (K and V; COW primitive).

    The copy-on-write half of prefix sharing: when an admission would write
    into a page mapped by more than one slot (serve/scheduler.py tracks
    refcounts host-side), it allocates a private page, copies the shared
    page's rows here, and remaps its table row via :func:`set_page_row` —
    the shared original is never written.  ``layer_axis``: pools are
    ``(L, num_pages, page_size, Hkv, D)`` (scan-stacked layers); every layer
    copies the same pool page, mirroring the shared logical assignment.
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    axis = 1 if layer_axis else 0

    def cp(pool):
        page = jax.lax.dynamic_slice_in_dim(pool, src, 1, axis=axis)
        return jax.lax.dynamic_update_slice_in_dim(pool, page, dst, axis=axis)

    return dict(cache, k=cp(cache["k"]), v=cp(cache["v"]))


def set_page_row(cache: Dict[str, Any], slot: jax.Array, row: jax.Array,
                 *, layer_axis: bool = False) -> Dict[str, Any]:
    """Install a slot's page-table row (the allocator's admission write).

    ``row``: (max_pages,) int32 pool indices, -1 past the allocated extent.
    ``layer_axis``: the table is (L, slots, max_pages) (scan-stacked layers)
    — every layer gets the same logical assignment.
    """
    slot = jnp.asarray(slot, jnp.int32)
    table = cache["page_table"]
    row = jnp.asarray(row, jnp.int32)
    if layer_axis:
        upd = jnp.broadcast_to(row[None, None], (table.shape[0], 1,
                                                 row.shape[0]))
        table = jax.lax.dynamic_update_slice(table, upd,
                                             (jnp.int32(0), slot, jnp.int32(0)))
    else:
        table = jax.lax.dynamic_update_slice(table, row[None],
                                             (slot, jnp.int32(0)))
    return dict(cache, page_table=table)


def set_page_entry(cache: Dict[str, Any], slot: jax.Array, idx: jax.Array,
                   page: jax.Array, *, layer_axis: bool = False,
                   ) -> Dict[str, Any]:
    """``page_table[slot, idx] = page`` — the lazy decode-growth primitive.

    Oversubscribed admission maps only the prompt-covering pages; when a
    slot's live length crosses a page boundary mid-decode the scheduler
    allocates ONE fresh pool page and appends it to the slot's row here
    (serve/scheduler.py growth loop).  All three indices are traced int32
    scalars, so one compile serves every (slot, position, page) triple.
    ``layer_axis``: the table is (L, slots, max_pages) (scan-stacked
    layers) — every layer gets the same logical assignment.
    """
    slot = jnp.asarray(slot, jnp.int32)
    idx = jnp.asarray(idx, jnp.int32)
    table = cache["page_table"]
    upd = jnp.asarray(page, jnp.int32).reshape(1, 1)
    if layer_axis:
        upd = jnp.broadcast_to(upd[None], (table.shape[0], 1, 1))
        table = jax.lax.dynamic_update_slice(table, upd,
                                             (jnp.int32(0), slot, idx))
    else:
        table = jax.lax.dynamic_update_slice(table, upd, (slot, idx))
    return dict(cache, page_table=table)


def gather_pool_pages(cache: Dict[str, Any], pages: jax.Array,
                      *, layer_axis: bool = False) -> Dict[str, Any]:
    """Read whole pool pages out of the K/V pools: the swap-out gather.

    ``pages``: (n,) int32 pool indices (traced — one compile per padded n).
    Returns ``{"k": (n, ps, Hkv, D), "v": ...}`` (a leading layer dim when
    ``layer_axis``), raw pool dtype — int8 pages round-trip bit-exactly, so
    a swap-preempted request resumes with the *identical* quantized rows it
    was evicted with (no re-quantization drift).
    """
    axis = 1 if layer_axis else 0
    pages = jnp.asarray(pages, jnp.int32)
    return {"k": jnp.take(cache["k"], pages, axis=axis),
            "v": jnp.take(cache["v"], pages, axis=axis)}


def scatter_pool_pages(cache: Dict[str, Any], pages: jax.Array,
                       data: Dict[str, Any], *, layer_axis: bool = False,
                       ) -> Dict[str, Any]:
    """Write :func:`gather_pool_pages` data back into pool pages ``pages``:
    the swap-in restore.  Duplicate page indices (the scheduler pads the
    index vector to a power of two to bound compile shapes) are harmless —
    they carry duplicate rows of the same content."""
    pages = jnp.asarray(pages, jnp.int32)
    if layer_axis:
        k = cache["k"].at[:, pages].set(data["k"].astype(cache["k"].dtype))
        v = cache["v"].at[:, pages].set(data["v"].astype(cache["v"].dtype))
    else:
        k = cache["k"].at[pages].set(data["k"].astype(cache["k"].dtype))
        v = cache["v"].at[pages].set(data["v"].astype(cache["v"].dtype))
    return dict(cache, k=k, v=v)


def init_kv_cache(
    batch: int, max_len: int, n_kv_heads: int, head_dim: int,
    *, quantized: bool, dtype=jnp.bfloat16, cache_n: int = 3,
    per_slot_len: bool = False,
) -> Dict[str, Any]:
    """cache_n: frozen fractional-bit exponent for the int8 cache grid
    (Q4.3 => range ±16, resolution 1/8 — post-norm K/V fit comfortably).

    ``per_slot_len=True`` makes ``len`` an int32 (B,) vector so every batch
    slot advances independently — the continuous-batching scheduler's cache
    (serve/scheduler.py): admissions write one slot, decode masks per slot.
    """
    shape = (batch, max_len, n_kv_heads, head_dim)
    ln = jnp.zeros((batch,), jnp.int32) if per_slot_len else jnp.int32(0)
    if quantized:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_n": jnp.int32(cache_n),
            "v_n": jnp.int32(cache_n),
            "len": ln,
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": ln,
    }


def init_cross_cache(slots: int, enc_len: int, n_kv_heads: int, head_dim: int,
                     *, dtype=jnp.float32) -> Dict[str, Any]:
    """Per-slot cross-attention K/V cache for EncDec serving.

    ``xk``/``xv`` hold each slot's encoder K/V rows — projected ONCE at
    admission (``EncDecLM.write_cross_kv``) instead of re-projected from
    ``enc`` every decode step — and ``xlen`` the live encoder length per slot
    (0 = evicted/inert; consumers mask rows past it).  Deliberately NOT the
    ``{"k", "len"}`` shape of a self-attention KV cache, so the scheduler's
    cache-tree walkers (keyed on that pair) never mistake it for one: slot
    length bookkeeping, paged growth and NaN audits all pass it by.
    """
    shape = (slots, enc_len, n_kv_heads, head_dim)
    return {"xk": jnp.zeros(shape, dtype), "xv": jnp.zeros(shape, dtype),
            "xlen": jnp.zeros((slots,), jnp.int32)}


def _insert_rows(buf: jax.Array, new: jax.Array, idx: jax.Array) -> jax.Array:
    """Write (B, S_new, H, D) into (B, S, H, D) at position ``idx`` on axis 1.

    Scalar ``idx``: one shared offset (lockstep batch).  (B,) ``idx``: each
    slot writes at its own offset (per-slot continuous batching).
    """
    if jnp.ndim(idx) == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, new, idx, axis=1)
    return jax.vmap(
        lambda b, n, i: jax.lax.dynamic_update_slice(b, n, (i, 0, 0))
    )(buf, new, idx)


def update_kv_cache(cache: Dict[str, Any], k_new: jax.Array, v_new: jax.Array):
    """Insert (B, S_new, Hkv, D) at cache['len']; returns updated cache.

    With a per-slot ``len`` vector each slot writes at its own live offset
    (writes past ``max_len`` clamp to the last row — harmless: only inactive
    slots ever run off the end, and their output is masked by the scheduler).
    Paged caches take the single-token scatter path below: each slot's new
    row lands in pool page ``table[slot, len//ps]``; slots whose write
    position maps to an unmapped (-1) page — evicted slots whose ``len``
    keeps ticking under the decode mask — are *dropped*, not clamped, so
    they can never corrupt another slot's pages.
    """
    idx = cache["len"]
    if cache["k"].dtype == jnp.int8:
        k_new = qformat.quantize(k_new, cache["k_n"], 8)
        v_new = qformat.quantize(v_new, cache["v_n"], 8)
    else:
        k_new = k_new.astype(cache["k"].dtype)
        v_new = v_new.astype(cache["v"].dtype)
    if is_paged_cache(cache):
        if k_new.shape[1] != 1:
            raise NotImplementedError(
                "multi-token insert into a paged cache: admission goes "
                "through the chunked path (append_kv_chunk)")
        n_pool, ps = cache["k"].shape[0], cache["k"].shape[1]
        flat = jax.vmap(
            lambda row, ln: paged_flat_index(row, ln[None], ps, n_pool)[0]
        )(cache["page_table"], idx)                    # (B,) per-slot rows
        k = _paged_scatter_rows(cache["k"], k_new[:, 0], flat)
        v = _paged_scatter_rows(cache["v"], v_new[:, 0], flat)
        return dict(cache, k=k, v=v, len=idx + 1)
    k = _insert_rows(cache["k"], k_new, idx)
    v = _insert_rows(cache["v"], v_new, idx)
    return dict(cache, k=k, v=v, len=idx + k_new.shape[1])


def reset_kv_slot(cache: Dict[str, Any], slot: jax.Array,
                  *, layer_axis: bool = False) -> Dict[str, Any]:
    """Free one slot of a per-slot cache: len[slot] = 0.

    The stale K/V rows stay in place — every consumer masks positions
    ``>= len``, and the next admission overwrites them — so eviction is O(1),
    not O(S·H·D).  ``layer_axis``: len is (L, B) (scan-stacked layers).

    Paged caches additionally unmap the slot's page-table row (all entries
    back to -1): the pool pages themselves go back to the host-side
    allocator's free list (serve/paging.py) — the device never touches their
    contents, and decode writes to an unmapped slot are dropped.
    """
    ln = cache["len"]
    ln = ln.at[:, slot].set(0) if layer_axis else ln.at[slot].set(0)
    out = dict(cache, len=ln)
    if is_paged_cache(cache):
        table = cache["page_table"]
        if layer_axis:
            table = table.at[:, slot, :].set(-1)
        else:
            table = table.at[slot, :].set(-1)
        out["page_table"] = table
    return out


def write_kv_slot(big: Dict[str, Any], small: Dict[str, Any], slot: jax.Array,
                  length: jax.Array, *, layer_axis: bool = False,
                  ) -> Dict[str, Any]:
    """Copy a batch-1 prefilled kv dict into slot ``slot`` of a per-slot dict.

    ``small`` comes from a slot-targeted prefill over a fresh batch-1 cache;
    its rows past ``length`` may hold prompt-bucket padding junk — masked by
    setting len[slot] = length (the true prompt length), then progressively
    overwritten by decode.  ``layer_axis``: leaves carry a leading scan-layer
    dim (k (L,B,S,H,D), len (L,B)).
    """
    b_axis = 1 if layer_axis else 0
    k = jax.lax.dynamic_update_slice_in_dim(
        big["k"], small["k"].astype(big["k"].dtype), slot, axis=b_axis)
    v = jax.lax.dynamic_update_slice_in_dim(
        big["v"], small["v"].astype(big["v"].dtype), slot, axis=b_axis)
    ln = big["len"]
    if layer_axis:
        upd = jnp.full((ln.shape[0], 1), length, jnp.int32)
        ln = jax.lax.dynamic_update_slice_in_dim(ln, upd, slot, axis=1)
    else:
        ln = set_kv_slot_len(ln, slot, length)
    return dict(big, k=k, v=v, len=ln)


@dataclasses.dataclass(frozen=True)
class KVChunk:
    """Chunked-prefill target: one prompt chunk headed for rows
    [start, start+C) of batch slot ``slot`` in a per-slot cache.

    ``length`` is the number of valid (non-pad) tokens in the chunk — C for
    every chunk but the last, which may be partial.  All three are traced
    int32 scalars inside the serve engine's jitted mixed step, so one compile
    serves every slot, offset and prompt length (the whole point: no
    per-prompt-length jit buckets).
    """

    slot: Any
    start: Any
    length: Any


def set_kv_slot_len(ln: jax.Array, slot: jax.Array,
                    new_len: jax.Array) -> jax.Array:
    """len[slot] = new_len on a per-slot (B,) length vector, traced indices."""
    return jax.lax.dynamic_update_slice_in_dim(
        ln, jnp.asarray(new_len, jnp.int32).reshape(1), slot, axis=0)


@dataclasses.dataclass(frozen=True)
class RaggedBatch:
    """Per-token addressing for the one-forward-per-tick ragged step.

    The (1, T) token batch flattens every live slot's decode token plus the
    prefill-chunk tokens of several concurrent admission lanes; ``slots`` and
    ``positions`` ((T,) traced int32 vectors) name each token's batch slot
    and logical cache row.  ``positions[t] < 0`` marks an inert pad row:
    nothing is written, the length bump is a no-op, and the output row is
    junk that callers never gather (CausalLM's ``logit_rows`` selects only
    real rows).  Both vectors are traced, so one compile serves every mix of
    decode tokens and lane chunks at a fixed token budget T.
    """

    slots: Any
    positions: Any


def _ragged_flat_rows(table: jax.Array, slots: jax.Array, pos: jax.Array,
                      ps: int, n_pool: int) -> jax.Array:
    """Vectorized :func:`paged_flat_index` over a ragged token batch.

    Token ``t`` maps to pool row ``table[slots[t], pos[t]//ps] * ps +
    pos[t] % ps``; inert rows (pos < 0), positions past the table, and
    unmapped (-1) pages redirect to the positive out-of-bounds sentinel
    ``n_pool * ps`` that scatter-with-``mode="drop"`` discards.
    """
    mp = table.shape[1]
    lp = jnp.clip(pos, 0) // ps
    page = table[slots, jnp.minimum(lp, mp - 1)]
    valid = (pos >= 0) & (lp < mp) & (page >= 0)
    return jnp.where(valid, page * ps + jnp.clip(pos, 0) % ps, n_pool * ps)


def append_kv_ragged(cache: Dict[str, Any], k_new: jax.Array,
                     v_new: jax.Array, ragged: RaggedBatch) -> Dict[str, Any]:
    """Scatter a (1, T, Hkv, D) ragged token batch into a per-slot cache.

    Token ``t``'s K/V row lands at logical row ``ragged.positions[t]`` of
    slot ``ragged.slots[t]`` (int8 caches quantize-on-write onto the paper
    grid); inert rows (position < 0) are dropped.  ``len[slot]`` rises to
    ``max(len[slot], positions+1)`` over the slot's tokens — the scatter-max
    keeps pad rows (slot 0, position -1 -> max with 0) inert.  The pure-jnp
    sibling of the fused write inside ``kernels.qragged_attn``.
    """
    if cache["k"].dtype == jnp.int8:
        k_new = qformat.quantize(k_new, cache["k_n"], 8)
        v_new = qformat.quantize(v_new, cache["v_n"], 8)
    else:
        k_new = k_new.astype(cache["k"].dtype)
        v_new = v_new.astype(cache["v"].dtype)
    slots = jnp.asarray(ragged.slots, jnp.int32)
    pos = jnp.asarray(ragged.positions, jnp.int32)
    if is_paged_cache(cache):
        n_pool, ps = cache["k"].shape[0], cache["k"].shape[1]
        flat = _ragged_flat_rows(cache["page_table"], slots, pos, ps, n_pool)
    else:
        b, s = cache["k"].shape[0], cache["k"].shape[1]
        flat = jnp.where((pos >= 0) & (pos < s), slots * s + jnp.clip(pos, 0),
                         b * s)
    k = _paged_scatter_rows(cache["k"], k_new[0], flat)
    v = _paged_scatter_rows(cache["v"], v_new[0], flat)
    ln = cache["len"].at[slots].max(pos + 1)
    return dict(cache, k=k, v=v, len=ln)


def ragged_attention(q: jax.Array, cache: Dict[str, Any],
                     ragged: RaggedBatch) -> jax.Array:
    """Ragged queries (1, T, Hq, D) over a per-slot cache whose rows already
    hold the batch (``append_kv_ragged``): token ``t`` attends positions
    ``<= ragged.positions[t]`` of slot ``ragged.slots[t]`` — full prefix
    plus the causally visible part of its own chunk.  Densifies each token's
    slot (a per-token gather), so it is the jnp path behind
    ``kernels.ops.qragged_attn``'s fused version (float caches, sharded
    runs); int8 caches dequantize on the paper's pow2 grid.  Inert rows
    (position < 0) see nothing and emit exact zeros.
    """
    b, t, hq, d = q.shape
    hkv = cache["k"].shape[2]
    g = hq // hkv
    slots = jnp.asarray(ragged.slots, jnp.int32)
    pos = jnp.asarray(ragged.positions, jnp.int32)
    if is_paged_cache(cache):
        table = cache["page_table"]
        mp, ps = table.shape[1], cache["k"].shape[1]
        rows = jnp.maximum(table[slots], 0)              # (T, max_pages)
        sh = (t, mp * ps) + cache["k"].shape[2:]
        kt = jnp.take(cache["k"], rows, axis=0).reshape(sh)
        vt = jnp.take(cache["v"], rows, axis=0).reshape(sh)
        mapped = jnp.repeat(table[slots] >= 0, ps, axis=1)
    else:
        kt = cache["k"][slots]                           # (T, S, Hkv, D)
        vt = cache["v"][slots]
        mapped = jnp.ones((t, kt.shape[1]), bool)
    if kt.dtype == jnp.int8:
        kt = kt.astype(jnp.float32) * jnp.exp2(-cache["k_n"].astype(jnp.float32))
        vt = vt.astype(jnp.float32) * jnp.exp2(-cache["v_n"].astype(jnp.float32))
    else:
        kt, vt = kt.astype(jnp.float32), vt.astype(jnp.float32)
    s = kt.shape[1]
    qg = q[0].reshape(t, hkv, g, d).astype(jnp.float32) / math.sqrt(d)
    scores = jnp.einsum("thgd,tshd->thgs", qg, kt)
    vis = (jnp.arange(s)[None, :] <= pos[:, None]) & mapped
    p = jax.nn.softmax(jnp.where(vis[:, None, None, :], scores, -1e30),
                       axis=-1)
    p = jnp.where(jnp.any(vis, axis=-1)[:, None, None, None], p, 0.0)
    out = jnp.einsum("thgs,tshd->thgd", p, vt)
    return out.reshape(1, t, hq, d).astype(q.dtype)


def append_kv_chunk(cache: Dict[str, Any], k_new: jax.Array, v_new: jax.Array,
                    chunk: KVChunk) -> Dict[str, Any]:
    """Write a (1, C, Hkv, D) prompt chunk in place into ``chunk.slot``'s
    cache rows [start, start+C) and set len[slot] = start + chunk.length.

    The pure-jnp sibling of the fused write inside ``kernels.qchunk_attn``
    (int8 caches quantize-on-write onto the paper grid; float caches cast).
    Unlike ``update_kv_cache`` this touches exactly one slot and sets its
    length *absolutely*, so decode steps that bumped the mid-prefill slot's
    length with masked junk rows are simply overwritten — the admission path
    needs no batch-1 scratch cache and no ``write_kv_slot`` copy.
    """
    if cache["k"].dtype == jnp.int8:
        k_new = qformat.quantize(k_new, cache["k_n"], 8)
        v_new = qformat.quantize(v_new, cache["v_n"], 8)
    else:
        k_new = k_new.astype(cache["k"].dtype)
        v_new = v_new.astype(cache["v"].dtype)
    slot = jnp.asarray(chunk.slot, jnp.int32)
    start = jnp.asarray(chunk.start, jnp.int32)
    if is_paged_cache(cache):
        # scatter the chunk's rows through the slot's page-table row; rows
        # landing on unmapped pages redirect to an out-of-bounds sentinel
        # (never the case for admitted slots — the allocator covers the
        # chunk-padded extent — but droppable junk beats silent corruption).
        # Prefix-sharing invariant: every page this write touches must be
        # privately mapped (refcount 1).  Refcounts live host-side, so the
        # scheduler asserts it at the dispatch site (_assert_private_write)
        # after copy-on-write has remapped any shared divergence page
        # (copy_kv_page + set_page_row).
        row = jax.lax.dynamic_index_in_dim(cache["page_table"], slot,
                                           axis=0, keepdims=False)
        n_pool, ps = cache["k"].shape[0], cache["k"].shape[1]
        flat = paged_flat_index(row, start + jnp.arange(k_new.shape[1]),
                                ps, n_pool)
        k = _paged_scatter_rows(cache["k"], k_new[0], flat)
        v = _paged_scatter_rows(cache["v"], v_new[0], flat)
    else:
        zero = jnp.int32(0)
        at = (slot, start, zero, zero)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, at)
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, at)
    ln = set_kv_slot_len(cache["len"], slot, chunk.start + chunk.length)
    return dict(cache, k=k, v=v, len=ln)


def chunk_attention(q: jax.Array, cache: Dict[str, Any], slot: jax.Array,
                    start: jax.Array, *, block_kv: int = 128) -> jax.Array:
    """Chunk queries (1, C, Hq, D) over slot ``slot`` of a per-slot cache
    whose rows [start, start+C) already hold the chunk (``append_kv_chunk``):
    query c attends positions <= start + c — causal within the chunk, full
    prefix before it.  Reads only the target slot's rows; int8 caches
    dequantize on the paper's pow2 grid.  The jnp path behind
    ``kernels.ops.qchunk_attn``'s fused version (float caches, sharded runs).

    Blocked online softmax with a *dynamic* trip count: only KV blocks up to
    the last visible row (start + C - 1) are visited, so a chunk's attention
    work matches one-shot causal prefill (sums to P²/2 over a prompt)
    instead of rescanning the whole max_len cache every chunk.

    Paged caches densify the target slot first (``gather_kv_pages``) and run
    the same loop over the gathered view — one slot's pages, not the pool.
    """
    b, c, hq, d = q.shape
    hkv = cache["k"].shape[2]
    g = hq // hkv
    slot = jnp.asarray(slot, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    if is_paged_cache(cache):
        kc, vc = gather_kv_pages(cache, slot)
    else:
        kc = jax.lax.dynamic_index_in_dim(cache["k"], slot, axis=0,
                                          keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(cache["v"], slot, axis=0,
                                          keepdims=False)
    s = kc.shape[0]
    quantized = kc.dtype == jnp.int8
    if quantized:
        k_scale = jnp.exp2(-cache["k_n"].astype(jnp.float32))
        v_scale = jnp.exp2(-cache["v_n"].astype(jnp.float32))
    qg = q[0].reshape(c, hkv, g, d).transpose(1, 2, 0, 3).astype(jnp.float32) \
        / math.sqrt(d)                                   # (Hkv, G, C, D)
    qc_idx = jnp.arange(c)[None, None, :, None]
    bkv = min(block_kv, s)
    n_blocks = (start + c + bkv - 1) // bkv              # dynamic trip count

    def body(state):
        i, m, l, acc = state
        # clamped offset keeps the slice in bounds; the >= i*bkv mask keeps
        # re-read rows from being double-counted on the clamped last block
        off = jnp.minimum(i * bkv, s - bkv)
        kb = jax.lax.dynamic_slice_in_dim(kc, off, bkv, axis=0)
        vb = jax.lax.dynamic_slice_in_dim(vc, off, bkv, axis=0)
        if quantized:
            kb = kb.astype(jnp.float32) * k_scale
            vb = vb.astype(jnp.float32) * v_scale
        else:
            kb, vb = kb.astype(jnp.float32), vb.astype(jnp.float32)
        pos = (off + jnp.arange(bkv))[None, None, None, :]
        sb = jnp.einsum("hgcd,khd->hgck", qg, kb)
        visible = (pos >= i * bkv) & (pos <= start + qc_idx)
        sb = jnp.where(visible, sb, -1e30)
        m_new = jnp.maximum(m, jnp.max(sb, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sb - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("hgck,khd->hgcd", p, vb)
        return i + 1, m_new, l_new, acc_new

    m0 = jnp.full((hkv, g, c, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((hkv, g, c, 1), jnp.float32)
    a0 = jnp.zeros((hkv, g, c, d), jnp.float32)
    _, _, l, acc = jax.lax.while_loop(
        lambda st: st[0] < n_blocks, body, (jnp.int32(0), m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)                    # (Hkv, G, C, D)
    out = out.transpose(2, 0, 1, 3).reshape(1, c, hq, d)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# The attention layer
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Attention:
    """Multi-head attention: GQA, RoPE, and every serving cache path
    (dense/paged, fp32/int8 Qm.n KV, decode/chunk/ragged) behind one module.
    """
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    use_qkv_bias: bool = False
    use_out_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True
    dtype: Any = jnp.float32
    name: str = "attn"

    @property
    def _q_dim(self):
        return self.n_heads * self.head_dim

    @property
    def _kv_dim(self):
        return self.n_kv_heads * self.head_dim

    def _projs(self):
        mk = lambda o, nm, bias: Dense(self.d_model, o, use_bias=bias,
                                       dtype=self.dtype, name=nm)
        return {
            "wq": mk(self._q_dim, "wq", self.use_qkv_bias),
            "wk": mk(self._kv_dim, "wk", self.use_qkv_bias),
            "wv": mk(self._kv_dim, "wv", self.use_qkv_bias),
            "wo": Dense(self._q_dim, self.d_model, use_bias=self.use_out_bias,
                        dtype=self.dtype, name="wo"),
        }

    def init(self, key) -> Params:
        """Create the q/k/v/o projection parameters."""
        ks = jax.random.split(key, 4)
        projs = self._projs()
        return {nm: layer.init(k) for (nm, layer), k in zip(projs.items(), ks)}

    def project_kv(self, params: Params, kv_in: jax.Array, ctx: Context,
                   ) -> Tuple[jax.Array, jax.Array]:
        """Project ``kv_in`` (B, S, d_model) to K/V exactly as ``apply`` would.

        The cross-attention cache writer (``EncDecLM.write_cross_kv``) runs
        this once per slot at admission; ``apply(cross_cache=...)`` then reads
        the projected rows every decode step instead of re-projecting ``enc``.
        Shares the module scope with ``apply`` so quant-stat paths line up.
        """
        ctx = ctx.scope(self.name)
        projs = self._projs()
        b, skv, _ = kv_in.shape
        k = projs["wk"].apply(params["wk"], kv_in, ctx).reshape(
            b, skv, self.n_kv_heads, self.head_dim)
        v = projs["wv"].apply(params["wv"], kv_in, ctx).reshape(
            b, skv, self.n_kv_heads, self.head_dim)
        return k, v

    def apply(
        self,
        params: Params,
        x: jax.Array,  # (B, S, d_model)
        ctx: Context,
        *,
        positions: Optional[jax.Array] = None,
        cache: Optional[Dict[str, Any]] = None,
        kv_source: Optional[jax.Array] = None,  # cross-attention
        cross_cache: Optional[Dict[str, Any]] = None,
        decode: bool = False,
        chunk: Optional[KVChunk] = None,
        ragged: Optional[RaggedBatch] = None,
    ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
        """Attend over ``x``; with ``cache`` set, run the decode / chunk /
        ragged serving path selected by the keyword arguments.

        ``cross_cache`` is the cached-cross-attention read path: a dict
        ``{"xk"/"xv": (slots, S_enc, Hkv, D), "xlen": (slots,)}`` whose rows
        were projected once at admission.  Only the query/output projections
        run — the per-step K/V re-projection of ``enc`` (and its RoPE-free
        flash over S_enc) drops out, which is the EncDec serving FLOPs win.
        """
        ctx = ctx.scope(self.name)
        projs = self._projs()
        b, s, _ = x.shape

        if cross_cache is not None:
            q = projs["wq"].apply(params["wq"], x, ctx).reshape(
                b, s, self.n_heads, self.head_dim)
            q = ctx.constrain(q, "batch", None, "heads", None)
            if chunk is not None:
                # one slot's prompt chunk: flash over that slot's cached rows
                # (flash_attention takes a scalar kv_len, so gather first)
                slot = jnp.asarray(chunk.slot, jnp.int32)
                kr = jax.lax.dynamic_index_in_dim(cross_cache["xk"], slot,
                                                  axis=0, keepdims=True)
                vr = jax.lax.dynamic_index_in_dim(cross_cache["xv"], slot,
                                                  axis=0, keepdims=True)
                xl = jax.lax.dynamic_index_in_dim(cross_cache["xlen"], slot,
                                                  axis=0, keepdims=False)
                out = flash_attention(q, kr.astype(q.dtype), vr.astype(q.dtype),
                                      jnp.int32(0), xl, False)
            else:
                # decode / tokens-as-batch: every batch row is one slot's
                # single token; per-row xlen masks each slot's live S_enc
                if s != 1:
                    raise NotImplementedError(
                        "cached cross-attention expects single-token rows "
                        "(decode / tokens-as-batch) or a chunk")
                out = decode_attention(q, cross_cache["xk"], cross_cache["xv"],
                                       cross_cache["xlen"]).astype(q.dtype)
            out = ctx.constrain(out, "batch", None, "heads", None)
            y = projs["wo"].apply(params["wo"],
                                  out.reshape(b, s, self._q_dim), ctx)
            return y, None

        q = projs["wq"].apply(params["wq"], x, ctx).reshape(b, s, self.n_heads, self.head_dim)
        kv_in = x if kv_source is None else kv_source
        skv = kv_in.shape[1]
        k = projs["wk"].apply(params["wk"], kv_in, ctx).reshape(b, skv, self.n_kv_heads, self.head_dim)
        v = projs["wv"].apply(params["wv"], kv_in, ctx).reshape(b, skv, self.n_kv_heads, self.head_dim)

        q = ctx.constrain(q, "batch", None, "heads", None)
        k = ctx.constrain(k, "batch", None, "kv_heads", None)
        v = ctx.constrain(v, "batch", None, "kv_heads", None)

        if positions is None:
            if ragged is not None:         # per-token rows; pads clamp to 0
                positions = jnp.maximum(
                    jnp.asarray(ragged.positions, jnp.int32), 0)[None, :]
            elif chunk is not None:        # chunk rows sit at start..start+C-1
                positions = chunk.start + jnp.arange(s)
            elif cache is not None and decode:
                ln = cache["len"]
                if jnp.ndim(ln) == 1:      # per-slot offsets -> (B, S)
                    positions = ln[:, None] + jnp.arange(s)[None, :]
                else:
                    positions = ln + jnp.arange(s)
            else:
                positions = jnp.arange(s)
        if self.use_rope and kv_source is None:
            q = apply_rope(q, positions, self.rope_theta)
            k = apply_rope(k, positions, self.rope_theta)

        new_cache = None
        if cache is not None and kv_source is None:
            if ragged is not None:
                # one ragged forward: every token writes its own cache row
                # and attends its own slot's prefix — decode tokens and
                # several prefill lanes in a single kernel launch.
                if jnp.ndim(cache["len"]) != 1:
                    raise NotImplementedError(
                        "the ragged step targets a per-slot cache "
                        "(init_cache(per_slot_len=True))")
                from repro.kernels import ops as kops

                slots = jnp.asarray(ragged.slots, jnp.int32)
                posv = jnp.asarray(ragged.positions, jnp.int32)
                if cache["k"].dtype == jnp.int8 and ctx.mesh is None \
                        and kops._mode() != "ref":
                    # fused Pallas path: quantize-on-write + flash in one
                    # kernel.  One pool geometry serves both layouts: paged
                    # caches pass their pool + table as-is; a dense slab is
                    # *viewed* as a pool of (B * S/bs) pages under the
                    # identity table (a contiguous reshape, no copy).
                    if is_paged_cache(cache):
                        out, k8, v8 = kops.qragged_attn(
                            q[0].astype(jnp.float32),
                            k[0].astype(jnp.float32),
                            v[0].astype(jnp.float32), cache["k"], cache["v"],
                            cache["k_n"], cache["v_n"], cache["page_table"],
                            slots, posv)
                        new_cache = dict(cache, k=k8, v=v8)
                    else:
                        bsz, smax, hkv, hd = cache["k"].shape
                        bs_ = min(512, smax)
                        while smax % bs_:
                            bs_ -= 1
                        steps = smax // bs_
                        table = jnp.arange(bsz * steps, dtype=jnp.int32
                                           ).reshape(bsz, steps)
                        out, k8, v8 = kops.qragged_attn(
                            q[0].astype(jnp.float32),
                            k[0].astype(jnp.float32),
                            v[0].astype(jnp.float32),
                            cache["k"].reshape(bsz * steps, bs_, hkv, hd),
                            cache["v"].reshape(bsz * steps, bs_, hkv, hd),
                            cache["k_n"], cache["v_n"], table, slots, posv)
                        new_cache = dict(cache,
                                         k=k8.reshape(cache["k"].shape),
                                         v=v8.reshape(cache["v"].shape))
                    out = out[None].astype(q.dtype)
                    new_cache["len"] = cache["len"].at[slots].max(posv + 1)
                else:
                    new_cache = append_kv_ragged(cache, k, v, ragged)
                    out = ragged_attention(q, new_cache, ragged)
            elif chunk is not None:
                # chunked prefill: write the chunk in place into the target
                # slot's rows, then attend over prefix + visible chunk — no
                # batch-1 scratch cache, no write_kv_slot copy.
                if jnp.ndim(cache["len"]) != 1:
                    raise NotImplementedError(
                        "chunked prefill targets a per-slot cache "
                        "(init_cache(per_slot_len=True))")
                from repro.kernels import ops as kops

                if cache["k"].dtype == jnp.int8 and ctx.mesh is None \
                        and kops._mode() != "ref":
                    # fused Pallas path: quantize-on-write + flash in one
                    # kernel; fp32 chunk K/V never reaches HBM.  The "ref"
                    # backend (plain CPU) instead takes the blocked jnp path
                    # below — the *_ref oracles are full-scan correctness
                    # contracts, not serving paths.  Paged caches pass the
                    # target slot's page-table row as kernel metadata.
                    if is_paged_cache(cache):
                        row = jax.lax.dynamic_index_in_dim(
                            cache["page_table"],
                            jnp.asarray(chunk.slot, jnp.int32),
                            axis=0, keepdims=False)
                        out, k8, v8 = kops.qpaged_chunk_attn(
                            q[0].astype(jnp.float32),
                            k[0].astype(jnp.float32),
                            v[0].astype(jnp.float32), cache["k"], cache["v"],
                            cache["k_n"], cache["v_n"], row, chunk.start)
                    else:
                        out, k8, v8 = kops.qchunk_attn(
                            q[0].astype(jnp.float32),
                            k[0].astype(jnp.float32),
                            v[0].astype(jnp.float32), cache["k"], cache["v"],
                            cache["k_n"], cache["v_n"], chunk.slot,
                            chunk.start)
                    out = out[None].astype(q.dtype)
                    new_cache = dict(
                        cache, k=k8, v=v8,
                        len=set_kv_slot_len(cache["len"], chunk.slot,
                                            chunk.start + chunk.length))
                else:
                    new_cache = append_kv_chunk(cache, k, v, chunk)
                    out = chunk_attention(q, new_cache, chunk.slot,
                                          chunk.start)
            elif decode and s == 1:
                new_cache = update_kv_cache(cache, k, v)
                if is_paged_cache(cache):
                    out = paged_decode_attention(
                        q, new_cache, sharded=ctx.mesh is not None,
                    ).astype(q.dtype)
                else:
                    out = decode_attention(
                        q, new_cache["k"], new_cache["v"], new_cache["len"],
                        k_n=new_cache.get("k_n"), v_n=new_cache.get("v_n"),
                        sharded=ctx.mesh is not None,
                    ).astype(q.dtype)
            else:
                if jnp.ndim(cache["len"]) == 1:
                    raise NotImplementedError(
                        "multi-token prefill into a per-slot cache: use the "
                        "chunked path (chunk=KVChunk(...)) or admit via a "
                        "batch-1 prefill + write_kv_slot (serve/scheduler)")
                new_cache = update_kv_cache(cache, k, v)
                kf = new_cache["k"]
                vf = new_cache["v"]
                if kf.dtype == jnp.int8:
                    kf = qformat.dequantize(kf, new_cache["k_n"])
                    vf = qformat.dequantize(vf, new_cache["v_n"])
                # prefill-into-cache: causal relative to the pre-update length
                out = flash_attention(
                    q, kf.astype(q.dtype), vf.astype(q.dtype),
                    cache["len"], new_cache["len"], self.causal)
        else:
            skv_len = jnp.int32(k.shape[1])
            out = flash_attention(q, k, v, jnp.int32(0), skv_len,
                                  self.causal and kv_source is None)

        out = ctx.constrain(out, "batch", None, "heads", None)
        y = projs["wo"].apply(params["wo"], out.reshape(b, s, self._q_dim), ctx)
        return y, new_cache
