"""Sharded, restart-deterministic host data pipeline.

State = (seed, step).  Batch `step` is a pure function of the two, so a
checkpoint stores two integers and a restart resumes mid-epoch exactly
(DESIGN.md §4 fault tolerance).  Under multi-host each process materializes
only its batch shard (process_index/process_count slicing); in this container
process_count == 1 so the shard is the whole batch — the slicing logic is the
same code path either way.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import numpy as np


@dataclasses.dataclass
class DataPipeline:
    """Wraps an indexable batch function with shard + device_put semantics."""

    batch_fn: Callable[[int], Dict[str, np.ndarray]]  # step -> global batch
    step: int = 0
    sharding: Optional[object] = None   # NamedSharding tree or single sharding

    def state(self) -> Dict[str, int]:
        return {"step": self.step}

    def restore(self, state: Dict[str, int]) -> None:
        self.step = int(state["step"])

    def _shard_host(self, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        pc, pi = jax.process_count(), jax.process_index()
        if pc == 1:
            return batch
        return {k: v[v.shape[0] // pc * pi: v.shape[0] // pc * (pi + 1)]
                for k, v in batch.items()}

    def __next__(self):
        batch = self._shard_host(self.batch_fn(self.step))
        self.step += 1
        if self.sharding is not None:
            if isinstance(self.sharding, dict):
                return {k: jax.device_put(v, self.sharding[k])
                        for k, v in batch.items()}
            return {k: jax.device_put(v, self.sharding) for k, v in batch.items()}
        return batch

    def __iter__(self):
        return self


def markov_batch_fn(vocab: int, batch: int, seq: int, *, seed: int = 0,
                    ) -> Callable[[int], Dict[str, np.ndarray]]:
    """Step-indexed version of data.synthetic.lm_token_batches."""
    base = np.random.default_rng(seed)
    v_eff = min(vocab, 4096)
    trans = base.dirichlet(np.full(64, 0.1), size=v_eff).astype(np.float32)
    targets = base.integers(0, v_eff, size=(v_eff, 64))

    def batch_fn(step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((seed * 1_000_003 + step) & 0x7FFFFFFF)
        toks = np.zeros((batch, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, v_eff, size=batch)
        u = rng.random((batch, seq)).astype(np.float32)
        for t in range(seq):
            prev = toks[:, t]
            cdf = np.cumsum(trans[prev], axis=-1)
            pick = (u[:, t, None] < cdf).argmax(-1)
            toks[:, t + 1] = targets[prev, pick]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    return batch_fn
