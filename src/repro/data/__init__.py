from repro.data.synthetic import (  # noqa: F401
    lm_token_batches,
    make_classification_dataset,
)
from repro.data.pipeline import DataPipeline  # noqa: F401
