"""Synthetic datasets, statistically matched to the paper's three benchmarks.

This container is offline (DESIGN.md §7.2): UCI-HAR / SMNIST / GTSRB are
replaced by class-conditional generators with the same shapes, channel counts
and class counts, built so that class structure lives at several scales
(per-class base frequency + channel mixing + noise).  A float model reaches
high accuracy quickly, and — the property the paper's claims C1–C4 are about —
quantization degrades it through *value-grid* error, not through dataset
quirks.  Absolute paper accuracies are not claimed; relative float/int16/int8
behaviour is.

Also provides the LM token stream used by the big-arch examples: a Zipf-ish
unigram mix with Markov structure so cross-entropy has learnable signal.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from repro.configs.microai_resnet import DATASETS


def make_classification_dataset(
    name: str, *, n_train: int = 2048, n_test: int = 512, seed: int = 0,
    normalize: bool = True, extra_noise: float = 0.0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (x_train, y_train, x_test, y_test), channels-last float32.

    Class c draws a smooth class template (sinusoid bank with class-specific
    frequencies/phases for 1D; oriented gratings for 2D) plus per-sample jitter
    and noise — the same "classes differ in spectral content" structure that
    makes UCI-HAR/SMNIST/GTSRB solvable by small convnets.
    """
    ds = DATASETS[name]
    rng = np.random.default_rng(seed)
    n_total = n_train + n_test
    classes = ds.classes

    if ds.ndim == 1:
        samples, channels = ds.in_shape
        t = np.linspace(0.0, 1.0, samples, dtype=np.float32)
        # class templates: k sinusoids with class-dependent freq per channel
        freqs = rng.uniform(1.0, 14.0, size=(classes, channels, 3)).astype(np.float32)
        phases = rng.uniform(0, 2 * np.pi, size=(classes, channels, 3)).astype(np.float32)
        amps = rng.uniform(0.4, 1.2, size=(classes, channels, 3)).astype(np.float32)
        y = rng.integers(0, classes, size=n_total)
        x = np.zeros((n_total, samples, channels), np.float32)
        for i in range(n_total):
            c = y[i]
            jitter = 1.0 + 0.08 * rng.standard_normal((channels, 3)).astype(np.float32)
            # per-channel sum of 3 class-specific sinusoids
            wave = np.sin(2 * np.pi * (freqs[c] * jitter)[..., None] * t
                          + phases[c][..., None])            # (ch, 3, T)
            x[i] = (wave * (amps[c] * jitter)[..., None]).sum(1).T
        x += 0.35 * rng.standard_normal(x.shape).astype(np.float32)
    else:
        h, w, channels = ds.in_shape
        yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
        yy, xx = yy / h - 0.5, xx / w - 0.5
        theta = rng.uniform(0, np.pi, size=classes).astype(np.float32)
        freq = rng.uniform(2.0, 8.0, size=classes).astype(np.float32)
        color = rng.uniform(-1.0, 1.0, size=(classes, channels)).astype(np.float32)
        y = rng.integers(0, classes, size=n_total)
        x = np.zeros((n_total, h, w, channels), np.float32)
        for i in range(n_total):
            c = y[i]
            th = theta[c] + 0.1 * rng.standard_normal()
            u = xx * np.cos(th) + yy * np.sin(th)
            grating = np.sin(2 * np.pi * freq[c] * u
                             + rng.uniform(0, 2 * np.pi)).astype(np.float32)
            x[i] = grating[..., None] * color[c][None, None, :]
        x += 0.3 * rng.standard_normal(x.shape).astype(np.float32)

    if extra_noise:
        # "hard mode": pushes the float model off the accuracy ceiling so the
        # int8-vs-int16 separation (paper C2/C4) is measurable
        x += extra_noise * rng.standard_normal(x.shape).astype(np.float32)
    if normalize:  # z-score of the training split (paper Sec. 6)
        mu = x[:n_train].mean(axis=0, keepdims=True)
        sd = x[:n_train].std(axis=0, keepdims=True) + 1e-6
        x = (x - mu) / sd
    y = y.astype(np.int32)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]


def mixup(x: np.ndarray, y_onehot: np.ndarray, rng: np.random.Generator,
          alpha: float = 0.2) -> Tuple[np.ndarray, np.ndarray]:
    """Mixup (paper Sec. 6 uses it during training)."""
    lam = rng.beta(alpha, alpha)
    perm = rng.permutation(x.shape[0])
    return lam * x + (1 - lam) * x[perm], lam * y_onehot + (1 - lam) * y_onehot[perm]


def lm_token_batches(vocab: int, batch: int, seq: int, *, seed: int = 0,
                     n_batches: int = 0) -> Iterator[dict]:
    """Markov-structured token stream: learnable, deterministic per (seed, step).

    Each batch is generated from fold_in(seed, step) so the pipeline state in
    a checkpoint is just the step counter (restart-safe, DESIGN.md §4).
    """
    base = np.random.default_rng(seed)
    v_eff = min(vocab, 4096)
    trans = base.dirichlet(np.full(64, 0.1), size=v_eff).astype(np.float32)
    targets = base.integers(0, v_eff, size=(v_eff, 64))
    step = 0
    while n_batches == 0 or step < n_batches:
        rng = np.random.default_rng((seed * 1_000_003 + step) & 0x7FFFFFFF)
        toks = np.zeros((batch, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, v_eff, size=batch)
        u = rng.random((batch, seq)).astype(np.float32)
        for t in range(seq):
            prev = toks[:, t]
            cdf = np.cumsum(trans[prev], axis=-1)
            pick = (u[:, t, None] < cdf).argmax(-1)
            toks[:, t + 1] = targets[prev, pick]
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        step += 1
