"""Fault-tolerant checkpointing: atomic, versioned, async, reshard-on-restore.

Layout:  <dir>/step_<N>/<leaf-path>.npy + manifest.json
Writes go to ``step_<N>.tmp`` and are renamed into place only after every
leaf and the manifest have been fsync'd — a preempted writer can never
produce a half checkpoint that restore would pick up (restore scans only
completed dirs).  ``keep`` old checkpoints are retained.

``save_async`` snapshots to host memory synchronously (cheap) and writes on a
background thread, so the train loop is blocked only for the device→host
copy.  ``restore`` takes a *target* tree (arrays or ShapeDtypeStructs with
shardings) and device_puts each leaf onto the target sharding — this is what
makes **elastic restarts** work: a checkpoint written on a 512-chip mesh
restores onto 256 chips (or 1 CPU) by simply passing the new target specs.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_LEAF_SEP = "."


def _keystr(path) -> str:
    # jax >= 0.5 spells this keystr(path, simple=True, separator=_LEAF_SEP);
    # build the same "a.b.0.c" form by hand so 0.4.x wheels work too.
    parts = []
    for k in path:
        if hasattr(k, "key"):       # DictKey / FlattenedIndexKey
            parts.append(str(k.key))
        elif hasattr(k, "idx"):     # SequenceKey
            parts.append(str(k.idx))
        elif hasattr(k, "name"):    # GetAttrKey
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return _LEAF_SEP.join(parts)


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_keystr(path)] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()
        self._pending: Optional[Future] = None

    # ---- write -------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        # Drain any in-flight async write first: two writers racing on the
        # same step's tmp dir TOCTOU each other (seen when the final sync
        # save lands on a step save_async already picked up).
        self.wait()
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        return self._write(step, host)

    def save_async(self, step: int, tree) -> Future:
        self.wait()
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)  # snapshot
        self._pending = self._pool.submit(self._write, step, host)
        return self._pending

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_tree) -> str:
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_tree)
        manifest = {"step": step, "leaves": {}}
        for key, leaf in flat.items():
            arr = np.asarray(leaf)
            fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                       "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        with self._lock:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)   # atomic commit
            self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---- read --------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target):
        """Load into the structure (and shardings) of `target`.

        `target` leaves may be arrays (restored onto their shardings) or
        ShapeDtypeStructs carrying a .sharding (elastic reshard path).
        """
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_target = _flatten(target)
        loaded = {}
        for key, tgt in flat_target.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint {step} missing leaf {key!r}")
            arr = np.load(os.path.join(d, meta["file"]))
            sharding = getattr(tgt, "sharding", None)
            if sharding is not None and not isinstance(
                    sharding, jax.sharding.SingleDeviceSharding):
                loaded[key] = jax.device_put(arr.astype(tgt.dtype), sharding)
            else:
                loaded[key] = jax.numpy.asarray(arr.astype(tgt.dtype))
        # reassemble in target's treedef order
        paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        leaves = [loaded[_keystr(p)] for p, _ in paths]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, target):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target)
