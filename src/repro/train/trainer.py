"""Train/eval/calibration step builders (QAT-aware, mesh-aware).

``make_train_step`` returns a jit-able pure function
``(state, batch) -> (state, metrics)`` where state = {params, opt, step}:

  * the Context threads the active QuantPolicy (OFF → float training,
    QAT → fake-quant forward + STE backward with per-step range reassessment,
    exactly paper Sec. 4.3),
  * microbatched gradient accumulation (``microbatch_split > 1``) runs the
    batch through an inner ``lax.scan`` — the standard activation-memory lever
    recorded in §Perf,
  * under a mesh, sharding constraints inside the model keep the DP/TP/EP
    layout; gradients inherit param shardings (FSDP ⇒ ZeRO: grads and
    optimizer state are sharded the same way params are).

``make_dp_shardmap_train_step`` is the explicit-collective variant used by
the int8 gradient-compression feature (psum is manual inside shard_map).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import QMode, QuantPolicy
from repro.nn.module import Context

TrainState = Dict[str, Any]  # {"params": tree, "opt": tree, "step": int32}


def init_train_state(model, optimizer, key) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(model, optimizer, lr_schedule, *,
                    policy: Optional[QuantPolicy] = None,
                    mesh=None, axis_rules=None,
                    microbatch_split: int = 1,
                    int8_weight_gather: bool = False,
                    loss_scale: float = 1.0) -> Callable:
    """``int8_weight_gather``: materialize an int8 copy of every GEMM weight
    inside the step (STE backward, f32/bf16 master untouched) so FSDP
    all-gathers move int8 — the paper's quantizer applied to the wire."""
    policy = policy or QuantPolicy.float32()

    def loss_fn(params, batch, step, rng):
        if int8_weight_gather:
            from repro.core.integerize import fake_int8_weights

            params = fake_int8_weights(params, mesh=mesh, rules=axis_rules)
        ctx = Context(policy=policy, train=True, rng=rng, mesh=mesh,
                      axis_rules=axis_rules)
        loss, mets = model.loss(params, batch, ctx)
        return loss * loss_scale, mets

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch) -> tuple:
        params, opt, step = state["params"], state["opt"], state["step"]
        rng = jax.random.fold_in(jax.random.PRNGKey(0), step)

        if microbatch_split > 1:
            def micro(carry, mb):
                gacc, lacc, aacc = carry
                (l, mets), g = grad_fn(params, mb, step, rng)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                return (gacc, lacc + l, aacc + mets["accuracy"]), None

            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatch_split,
                                    x.shape[0] // microbatch_split,
                                    *x.shape[1:]), batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, acc), _ = jax.lax.scan(
                micro, (g0, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatch_split, grads)
            loss, acc = loss / microbatch_split, acc / microbatch_split
            mets = {"accuracy": acc}
        else:
            (loss, mets), grads = grad_fn(params, batch, step, rng)

        if loss_scale != 1.0:
            grads = jax.tree_util.tree_map(lambda g: g / loss_scale, grads)
            loss = loss / loss_scale
        lr = lr_schedule(step) if callable(lr_schedule) else lr_schedule
        new_params, new_opt = optimizer.update(grads, opt, params, lr)
        new_state = {"params": new_params, "opt": new_opt, "step": step + 1}
        metrics = {"loss": loss, "lr": jnp.asarray(lr, jnp.float32), **mets}
        return new_state, metrics

    return train_step


def make_eval_step(model, *, policy: Optional[QuantPolicy] = None,
                   qstate=None, mesh=None, axis_rules=None) -> Callable:
    policy = policy or QuantPolicy.float32()

    def eval_step(params, batch):
        ctx = Context(policy=policy, train=False, qstate=qstate, mesh=mesh,
                      axis_rules=axis_rules)
        loss, mets = model.loss(params, batch, ctx)
        return {"loss": loss, **mets}

    return eval_step


def make_calib_fn(model, policy: QuantPolicy) -> Callable:
    """apply_fn for repro.core.ptq.calibrate: records activation ranges."""

    def apply_fn(params, batch, ctx):
        return model.loss(params, batch, ctx)

    return apply_fn


def calibrate_model(model, params, batches, policy: QuantPolicy):
    """Run CALIB forward passes over `batches`; return frozen exponents."""
    from repro.core import ptq

    calib_policy = policy.with_mode(QMode.CALIB)

    @jax.jit
    def step(p, batch):
        ctx = Context(policy=calib_policy, train=False)
        model.loss(p, batch, ctx)
        return ctx.stats

    acc: Dict[str, jax.Array] = {}
    for batch in batches:
        stats = step(params, batch)
        for k, v in stats.items():
            acc[k] = jnp.maximum(acc[k], v) if k in acc else v
    return ptq.ranges_to_qstate(acc, policy)


# --------------------------------------------------------------------------
# Explicit-DP shard_map train step with int8 gradient compression
# --------------------------------------------------------------------------


def make_dp_shardmap_train_step(model, optimizer, lr_schedule, mesh, *,
                                policy: Optional[QuantPolicy] = None,
                                compress_bits: int = 0,
                                axis_name: str = "data") -> Callable:
    """Pure-DP training over `axis_name` with manual psum — enables the
    paper-grid int8 gradient all-reduce (dist/compress.py).

    state gains an "err" tree (error feedback) when compression is on.
    Params are replicated; batch is sharded on dim 0.
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist.compress import compressed_grad_allreduce

    policy = policy or QuantPolicy.float32()

    def loss_fn(params, batch, step):
        ctx = Context(policy=policy, train=True,
                      rng=jax.random.fold_in(jax.random.PRNGKey(0), step))
        loss, mets = model.loss(params, batch, ctx)
        return loss, mets

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step_body(state, batch):
        params, opt, step = state["params"], state["opt"], state["step"]
        (loss, mets), grads = grad_fn(params, batch, step)
        if compress_bits:
            # err leaves carry a leading per-shard axis (see train_step);
            # locally that axis is size 1 — peel it for the compressor.
            err_local = jax.tree_util.tree_map(lambda e: e[0], state["err"])
            grads, new_err = compressed_grad_allreduce(
                grads, axis_name, bits=compress_bits, error_state=err_local)
            new_err = jax.tree_util.tree_map(lambda e: e[None], new_err)
        else:
            grads = jax.tree_util.tree_map(
                lambda g: jax.lax.pmean(g, axis_name), grads)
            new_err = None
        loss = jax.lax.pmean(loss, axis_name)
        acc = jax.lax.pmean(mets["accuracy"], axis_name)
        lr = lr_schedule(step) if callable(lr_schedule) else lr_schedule
        new_params, new_opt = optimizer.update(grads, opt, params, lr)
        out = {"params": new_params, "opt": new_opt, "step": step + 1}
        if new_err is not None:
            out["err"] = new_err
        return out, {"loss": loss, "accuracy": acc}

    def train_step(state, batch):
        world = 1
        for a in (axis_name if isinstance(axis_name, tuple) else (axis_name,)):
            world *= int(mesh.shape[a])
        if compress_bits and "err" not in state:
            # Error-feedback residuals are genuinely *per-shard* state (each
            # shard quantizes its own gradient), so they get a leading
            # device axis sharded over `axis_name` — declaring them
            # replicated would let any fetch/reshard pick one shard's
            # residual and silently clobber the others.
            state = dict(state, err=jax.tree_util.tree_map(
                lambda p: jnp.zeros((world,) + p.shape, jnp.float32),
                state["params"]))
        sspec = jax.tree_util.tree_map(lambda _: P(), state)
        if compress_bits:
            sspec["err"] = jax.tree_util.tree_map(
                lambda _: P(axis_name), state["err"])
        bspec = jax.tree_util.tree_map(lambda _: P(axis_name), batch)
        fn = jax.shard_map(step_body, mesh=mesh, in_specs=(sspec, bspec),
                           out_specs=(sspec, jax.tree_util.tree_map(
                               lambda _: P(), {"loss": 0, "accuracy": 0})),
                           check_vma=False)
        return jax.jit(fn)(state, batch)

    return train_step
