from repro.train.checkpoint import CheckpointManager  # noqa: F401
from repro.train.trainer import (  # noqa: F401
    TrainState,
    make_calib_fn,
    make_eval_step,
    make_train_step,
)
