"""End-to-end MicroAI flow (the paper's Fig. 3): general float training →
int8 quantization-aware fine-tuning (Sec. 4.3) → activation calibration →
full-integer deployment (Sec. 5.8) → on-"target" evaluation + the Appendix-E
cycle/energy cost model for the MCU target.

    PYTHONPATH=src python examples/qat_deploy_integer.py
"""
import jax
import jax.numpy as jnp

from repro.core import integerize, ptq
from repro.core.cost_model import (inference_energy_uwh, inference_seconds,
                                   resnet6_ops)
from repro.core.policy import QMode, QuantPolicy
from repro.nn.module import Context

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import accuracy, dataset, train_resnet  # noqa: E402

FILTERS = 16


def main():
    # 1. general float training (paper: "training" step)
    print("[1/5] float32 training...")
    model, params, test = train_resnet("uci-har", filters=FILTERS, iters=400)
    print(f"      float32 accuracy: {accuracy(model, params, test):.4f}")

    # 2. QAT fine-tune at int8 (paper: post-processing QuantizationAwareTraining)
    print("[2/5] int8 QAT fine-tune...")
    policy = QuantPolicy.int8_qat()
    _, qat_params, _ = train_resnet("uci-har", filters=FILTERS, iters=200,
                                    policy=policy, lr=0.01,
                                    init_params=params)
    eval_pol = QuantPolicy(mode=QMode.EVAL, weight_bits=8, act_bits=8)
    print(f"      int8 QAT accuracy (fake-quant): "
          f"{accuracy(model, qat_params, test, eval_pol):.4f}")

    # 3. activation-range calibration (scale factors frozen, Sec. 4.1.4)
    print("[3/5] calibrating activation ranges...")
    x_te, _ = test
    calib = eval_pol.with_mode(QMode.CALIB)

    @jax.jit
    def calib_step(p, xb):
        ctx = Context(policy=calib, train=False)
        model.apply(p, xb, ctx)
        return ctx.stats

    stats = {}
    for i in range(4):
        st = calib_step(qat_params, x_te[i * 32:(i + 1) * 32])
        for k, v in st.items():
            stats[k] = jnp.maximum(stats[k], v) if k in stats else v
    qstate = ptq.ranges_to_qstate(stats, eval_pol)

    # 4. integerize: the KerasCNN2C deployment step (float -> int8 + exponents)
    print("[4/5] integerizing (deployment conversion)...")
    iparams = integerize.integerize(qat_params, eval_pol, qstate)
    rom = integerize.model_rom_bytes(iparams)
    print(f"      deployed ROM: {rom} bytes "
          f"(float32 was {integerize.model_rom_bytes(qat_params)})")

    # 5. full-integer inference — int8 operands, int32 accumulators, shifts
    print("[5/5] integer-engine inference...")
    xq = integerize.quantize_input(x_te, qstate, "resnet6/conv1/in", 8)
    ctx = Context(policy=eval_pol.with_mode(QMode.INTEGER), train=False,
                  qstate=qstate)
    out = model.apply(iparams, xq, ctx)
    acc_int = float(jnp.mean(jnp.argmax(out, -1) == test[1]))
    print(f"      INTEGER-engine accuracy: {acc_int:.4f}")

    ops = resnet6_ops(FILTERS, 128, 9)
    for board in ("nucleo-l452re-p", "sparkfun-edge"):
        t = inference_seconds(ops, board)
        e = inference_energy_uwh(t, board)
        print(f"      {board}: {t*1e3:.1f} ms/inference, {e:.4f} uWh "
              f"(Appendix-E cycle model)")


if __name__ == "__main__":
    main()
