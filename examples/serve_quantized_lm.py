"""Serving a language model with the paper's quantization at the TPU layer:
int8 weight-only storage (HBM ÷4) + int8 KV cache on the Qm.n grid.

Uses the smollm-135m *smoke* config so it runs on this CPU container; on a
real fleet the same code path serves the full configs (see launch/serve.py
and the decode-cell dry-runs).

    PYTHONPATH=src python examples/serve_quantized_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.models.registry import get_config
from repro.serve.engine import ServeEngine


def main():
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, cfg.vocab,
                                 dtype=jnp.int32)

    for name, kw in [("float32 weights + float KV", {}),
                     ("int8 weights (wq_matmul path)", {"weight_quant": True}),
                     ("int8 KV cache (paper grid)", {"quantized_kv": True}),
                     ("int8 weights + int8 KV", {"weight_quant": True,
                                                 "quantized_kv": True})]:
        eng = ServeEngine(model=model, params=params, max_len=44,
                          batch_slots=4, **kw)
        t0 = time.time()
        out = eng.generate(prompts, 32, seed=0)
        out.block_until_ready()
        print(f"{name:35s} 4x32 tokens in {time.time()-t0:5.2f}s "
              f"first-10: {out[0,:10].tolist()}")


if __name__ == "__main__":
    main()
