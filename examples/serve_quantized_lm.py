"""Serving a language model with the paper's quantization at the TPU layer:
int8 weight-only storage (HBM ÷4) + int8 KV cache on the Qm.n grid — first
as one lockstep batch, then under staggered traffic via the
continuous-batching scheduler (queued admissions into freed slots, per-slot
EOS/length eviction).

Uses the smollm-135m *smoke* config so it runs on this CPU container; on a
real fleet the same code path serves the full configs (see launch/serve.py
and the decode-cell dry-runs).

    PYTHONPATH=src python examples/serve_quantized_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_config
from repro.serve import Request, ServeEngine


def main():
    cfg = get_config("smollm-135m-smoke")
    model = cfg.build(dtype=jnp.float32, remat="off")
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, cfg.vocab,
                                 dtype=jnp.int32)

    print("== lockstep generate() across the quantized deployment variants")
    for name, kw in [("float32 weights + float KV", {}),
                     ("int8 weights (wq_matmul path)", {"weight_quant": True}),
                     ("int8 KV cache (paper grid)", {"quantized_kv": True}),
                     ("int8 weights + int8 KV", {"weight_quant": True,
                                                 "quantized_kv": True})]:
        eng = ServeEngine(model=model, params=params, max_len=44,
                          batch_slots=4, **kw)
        t0 = time.time()
        out = eng.generate(prompts, 32, seed=0)
        out.block_until_ready()
        print(f"{name:35s} 4x32 tokens in {time.time()-t0:5.2f}s "
              f"first-10: {out[0,:10].tolist()}")

    print("\n== continuous batching: 8 staggered requests through 4 slots")
    eng = ServeEngine(model=model, params=params, max_len=44, batch_slots=4,
                      weight_quant=True, quantized_kv=True)
    rng = np.random.default_rng(0)
    requests = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, size=12),
                        max_new=8 if i % 2 == 0 else 32,
                        arrival=2 * i)
                for i in range(8)]
    results, stats = eng.scheduler().run(requests)
    for rid in sorted(results):
        r = results[rid]
        print(f"req {rid}: arrival t={r.arrival:2d} admitted t={r.admitted_at:2d} "
              f"finished t={r.finished_at:2d} ({len(r.tokens)} tokens)")
    s = stats.summary()
    print(f"steady {s['steady_tok_s']:.0f} tok/s | occupancy "
          f"{s['occupancy']:.2f} | p50/p99 latency "
          f"{s['p50_latency_steps']:.0f}/{s['p99_latency_steps']:.0f} steps | "
          f"cache {s['peak_cache_bytes']/1024:.0f} KiB (int8 KV)")


if __name__ == "__main__":
    main()
