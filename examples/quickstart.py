"""Quickstart: the paper's pipeline in one page.

Train the paper's ResNetv1-6 in float32 on a (synthetic) UCI-HAR workload,
then post-training-quantize to int16 (paper's Q7.9) and int8, and compare
accuracy + model ROM — reproducing the paper's headline trade-off.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import integerize
from repro.core.policy import QMode, QuantPolicy

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import accuracy, train_resnet  # noqa: E402


def main():
    print("training float32 ResNetv1-6 (filters=16) on synthetic UCI-HAR...")
    model, params, test = train_resnet("uci-har", filters=16, iters=400)

    acc_f32 = accuracy(model, params, test)
    acc_i16 = accuracy(model, params, test, QuantPolicy.int16_ptq())
    acc_i8 = accuracy(model, params, test,
                      QuantPolicy(mode=QMode.EVAL, weight_bits=8, act_bits=8))

    rom_f32 = integerize.model_rom_bytes(params)
    i16 = integerize.integerize(params, QuantPolicy.int16_ptq())
    i8 = integerize.integerize(
        params, QuantPolicy(mode=QMode.EVAL, weight_bits=8, act_bits=8))

    print(f"\n{'':>10} {'accuracy':>9} {'ROM bytes':>10} {'vs f32':>7}")
    print(f"{'float32':>10} {acc_f32:9.4f} {rom_f32:10d} {'1.00x':>7}")
    print(f"{'int16 PTQ':>10} {acc_i16:9.4f} {integerize.model_rom_bytes(i16):10d}"
          f" {rom_f32/integerize.model_rom_bytes(i16):6.2f}x")
    print(f"{'int8 PTQ':>10} {acc_i8:9.4f} {integerize.model_rom_bytes(i8):10d}"
          f" {rom_f32/integerize.model_rom_bytes(i8):6.2f}x")
    print("\npaper claims: int16 ≈ float32 (C1); ROM ÷2 / ÷4 (C3)")


if __name__ == "__main__":
    main()
