"""End-to-end LM training driver (deliverable b): train a language model for
a few hundred steps with checkpointing, then QAT-style int8 serving.

By default uses the smoke config (CPU-sized); pass --arch smollm-135m on a
real accelerator to train the full ~135M-parameter model — identical code.

    PYTHONPATH=src python examples/train_lm_e2e.py [--steps 300]
"""
import argparse
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m-smoke")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--qat", action="store_true")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckdir:
        argv = ["--arch", args.arch, "--steps", str(args.steps),
                "--batch", "8", "--seq", "128", "--ckpt-dir", ckdir,
                "--ckpt-every", "100", "--log-every", "25"]
        if args.qat:
            argv.append("--qat")
        state = train_main(argv)
    print("final step:", int(state["step"]))


if __name__ == "__main__":
    main()
