"""Markdown link checker for the CI docs lane.

Scans the given markdown files (default: README.md and docs/**/*.md) for
inline links/images and verifies that every *relative* target exists on
disk (anchors are stripped; http(s)/mailto links are skipped — CI must not
depend on external sites being up).  Exits 1 listing the dead links.

    python tools/check_links.py [files...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links [text](target) and images ![alt](target); stops at the first
# closing paren, which is fine for repo-relative paths
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP = ("http://", "https://", "mailto:", "ftp://")


def check_file(path: Path, root: Path) -> list:
    """Return (file, target) pairs whose relative targets do not exist."""
    dead = []
    for m in _LINK.finditer(path.read_text(encoding="utf-8")):
        target = m.group(1)
        if target.startswith(_SKIP):
            continue
        target = target.split("#", 1)[0]
        if not target:          # pure in-page anchor
            continue
        base = root if target.startswith("/") else path.parent
        if not (base / target.lstrip("/")).exists():
            dead.append((str(path), target))
    return dead


def main(argv=None) -> int:
    """CLI entry point; returns the process exit code."""
    argv = sys.argv[1:] if argv is None else argv
    root = Path(__file__).resolve().parent.parent
    files = [Path(a) for a in argv] if argv else (
        [root / "README.md"] + sorted((root / "docs").glob("**/*.md")))
    dead = []
    for f in files:
        dead += check_file(f, root)
    for src, target in dead:
        print(f"DEAD LINK {src}: {target}")
    if not dead:
        print(f"ok: {len(files)} file(s), no dead relative links")
    return 1 if dead else 0


if __name__ == "__main__":
    raise SystemExit(main())
